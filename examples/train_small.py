"""Training driver: a few hundred steps on a small LM, full production
path (sharded step, grad accumulation, checkpoints, watchdog, resume).

Default config is CPU-sized (~4M params) so a few hundred steps finish in
minutes; ``--d-model 768 --layers 12 --heads 12 --d-ff 3072`` is the
~100M-parameter configuration for real hardware (same code path).

Run: PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs.base import TrainConfig
from repro.data import SyntheticLM, make_batches
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo
from repro.runtime import fault_tolerance as ft
from repro.runtime import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    base = model_zoo.get_config("deepseek-7b")          # llama-style dense
    cfg = dataclasses.replace(
        base, name="small-lm", num_layers=args.layers,
        d_model=args.d_model, num_heads=args.heads,
        num_kv_heads=args.heads, head_dim=args.d_model // args.heads,
        d_ff=args.d_ff, vocab_size=args.vocab, remat=False)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    tc = TrainConfig(steps=args.steps, learning_rate=1e-3,
                     warmup_steps=max(args.steps // 20, 5),
                     checkpoint_every=max(args.steps // 4, 10))
    mesh = make_host_mesh()
    src = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      batch_size=args.batch)

    shutdown = ft.GracefulShutdown().install()
    watchdog = ft.StepWatchdog(on_straggler=lambda ev: print(
        f"[watchdog] slow step: {ev.dt:.2f}s (EMA {ev.ema:.2f}s)"))
    # resume from the newest checkpoint if one exists (fault tolerance)
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(args.ckpt_dir)
    like = train_loop.abstract_state(cfg, tc)
    state, start = ft.resume_or_init(
        mgr, lambda: train_loop.init_state(cfg, tc), like,
        shardings=train_loop.state_shardings(like, mesh))
    if start:
        print(f"resuming from step {start}")

    data = make_batches(src, start_step=start)
    state, history = train_loop.train(
        cfg, tc, mesh, data, ckpt_dir=args.ckpt_dir, log_every=10,
        shutdown=shutdown, watchdog=watchdog, state=state,
        start_step=start)
    if len(history) >= 2:
        print(f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
              f"over {args.steps} steps")


if __name__ == "__main__":
    main()
