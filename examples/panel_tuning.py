"""Panel tuning walkthrough — the paper's §3.3 sweep as an API.

Shows: (1) the napkin-math plan for a GEMM under different panel widths
(lever 1 — the ~2x mis-tuning cliff), (2) the bit-exact-gated autotune
sweep that fixes the deployed (block_n, block_k) pair, (3) the dispatch
policy resolving the paper's twelve shapes into plans (``gemm.plan``),
and (4) the mesh-scale panel feasibility check for the
all-gather⇄matmul overlap.

Run: PYTHONPATH=src python examples/panel_tuning.py
"""
from repro import gemm as G
from repro.core import autotune, scheduler
from repro.models.model_zoo import PAPER_GEMM_SHAPES, PAPER_M

M, N, K = PAPER_M, 2048, 2048        # the paper's QKV shape

print(f"panel plans for QKV ({M}x{N}x{K}), 8 cores:")
print(f"{'block_n':>8} {'panels':>7} {'occup':>6} {'pred_ms':>8} "
      f"{'vmem_kb':>8}")
for bn in (64, 128, 256, 512, 1024, 2048):
    p = scheduler.plan(M, N, K, block_m=128, block_n=bn, block_k=512,
                       num_cores=8)
    print(f"{bn:>8} {p.panels:>7} {p.occupancy:>6.2f} "
          f"{p.t_pred*1e3:>8.4f} {p.vmem//1024:>8}")

print("\nbit-exact-gated sweep over the paper's twelve shapes:")
shapes = [(PAPER_M, n, k) for _, _, n, k in PAPER_GEMM_SHAPES]
for r in autotune.sweep(shapes, num_cores=8)[:3]:
    print(f"  block_n={r.block_n:<5} block_k={r.block_k:<5} "
          f"t_pred={r.t_pred*1e3:.3f}ms vmem={r.vmem//1024}KB "
          f"bit_exact={r.bit_exact}")

print("\ndispatch policy over the twelve paper shapes (gemm.plan):")
for (model, op, n, k), row in zip(
        PAPER_GEMM_SHAPES,
        G.policy_table([(PAPER_M, n, k)
                        for _, _, n, k in PAPER_GEMM_SHAPES])):
    print(f"  {model:<15} {op:<8} N={n:<6} K={k:<6} -> {row['lever']:<12}"
          f" blocks=({row['block_n']},{row['block_k']})"
          f" prepack={row['prepack']}")

print("\nmesh-scale panels (N=2048 over 16 model shards):")
for bn in (64, 128, 256):
    info = scheduler.mesh_panels(2048, model_shards=16, block_n=bn)
    print(f"  block_n={bn:<4} panels/shard="
          f"{info['kernel_panels_per_shard']} "
          f"overlap_feasible={info['overlap_feasible']}")
