"""End-to-end serving driver — the paper's deployment scenario.

Loads a model, packs every projection weight once (untimed model-load
phase, paper §3.2), then serves a queue of mixed-length requests two
ways over the SAME packed engine:

  * the legacy phase-locked loop (``serve_chunked``): sequential static
    batches, every slot waiting for its chunk's slowest request;
  * real continuous batching (``serve``): slot refill mid-generation,
    paged KV cache, chunked prefill admission (docs/serving.md).

and reports useful generated tokens/s plus per-request latency
percentiles for the continuous pool — the framework-native analogue of
the paper's llama.cpp integration (§4.7), where the pre-packed path
lifted full-forward throughput 291→420 tok/s.

Run: PYTHONPATH=src python examples/serve_batched.py [--arch deepseek-7b]
     [--requests 12] [--prompt-len 64] [--max-new 16] [--batch-slots 4]
"""
import argparse
import time

import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo
from repro.runtime.serve_loop import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b",
                    choices=model_zoo.list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--no-fusion", action="store_true",
                    help="serve with horizontal QKV/gate-up fusion OFF "
                         "(A/B the fused GEMM path in place)")
    args = ap.parse_args()

    cfg = model_zoo.reduced_config(model_zoo.get_config(args.arch))
    if cfg.modality != "text":
        raise SystemExit("pick a text arch for the serving demo")
    mesh = make_host_mesh()
    params = model_zoo.build(cfg)
    rng = np.random.default_rng(0)
    requests = [rng.integers(0, cfg.vocab_size,
                             rng.integers(8, args.prompt_len + 1))
                .astype(np.int32) for _ in range(args.requests)]
    # heavy-tailed generation budgets: mostly short, some long
    mns = [int(rng.integers(args.max_new // 2, args.max_new + 1))
           if rng.random() < 0.25 else int(rng.integers(2, 6))
           for _ in range(args.requests)]
    useful = sum(mns)
    max_len = args.prompt_len + args.max_new
    max_len += (-max_len) % args.page_size

    t0 = time.perf_counter()
    eng = Engine(cfg, params, mesh=mesh, max_len=max_len, packed=True,
                 fuse=not args.no_fusion)
    print(f"model load + pack (untimed): {time.perf_counter() - t0:.2f}s  "
          f"[fused GEMMs {'off' if args.no_fusion else 'on'}]")

    # warm both paths' traces (compile is part of model load, not serving)
    warm = requests[:2]
    eng.serve_chunked(warm, batch_slots=args.batch_slots,
                      prompt_len=args.prompt_len, max_new_tokens=2)
    eng.serve(warm, batch_slots=args.batch_slots, max_new_tokens=2,
              prefill_chunk=args.prefill_chunk, page_size=args.page_size)

    t0 = time.perf_counter()
    eng.serve_chunked(requests, batch_slots=args.batch_slots,
                      prompt_len=args.prompt_len, max_new_tokens=mns)
    t_old = time.perf_counter() - t0
    print(f"{'phase-locked (legacy)':24s} {useful / t_old:8,.0f} useful "
          f"tok/s  ({useful} tokens, {t_old:.2f}s)")

    t0 = time.perf_counter()
    outs, stats = eng.serve(requests, batch_slots=args.batch_slots,
                            max_new_tokens=mns,
                            prefill_chunk=args.prefill_chunk,
                            page_size=args.page_size)
    t_new = time.perf_counter() - t0
    print(f"{'continuous batching':24s} {useful / t_new:8,.0f} useful "
          f"tok/s  ({len(outs)} requests, {t_new:.2f}s, "
          f"{t_old / t_new:.2f}x)")
    qw95 = stats.percentile("queue_wait_s", 95) * 1e3
    tf95 = stats.percentile("ttft_s", 95) * 1e3
    print(f"  queue wait p95 {qw95:.1f} ms | TTFT p95 {tf95:.1f} ms "
          f"(dispatch-side; pass sync_per_step=True for exact latency)")


if __name__ == "__main__":
    main()
