"""End-to-end serving driver — the paper's deployment scenario.

Loads a model, packs every projection weight once (untimed model-load
phase, paper §3.2), then serves a queue of batched requests through the
slot-pool engine, reporting prefill/decode tokens-per-second for the
packed engine vs the per-call engine over identical requests — the
framework-native analogue of the paper's llama.cpp integration (§4.7),
where the pre-packed path lifted full-forward throughput 291→420 tok/s.

Run: PYTHONPATH=src python examples/serve_batched.py [--arch deepseek-7b]
     [--requests 12] [--prompt-len 128] [--max-new 16]
"""
import argparse
import time

import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo
from repro.runtime.serve_loop import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b",
                    choices=model_zoo.list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    args = ap.parse_args()

    cfg = model_zoo.reduced_config(model_zoo.get_config(args.arch))
    if cfg.modality != "text":
        raise SystemExit("pick a text arch for the serving demo")
    mesh = make_host_mesh()
    params = model_zoo.build(cfg)
    rng = np.random.default_rng(0)
    requests = [rng.integers(0, cfg.vocab_size,
                             rng.integers(8, args.prompt_len + 1))
                .astype(np.int32) for _ in range(args.requests)]

    for packed in (True, False):
        t0 = time.perf_counter()
        eng = Engine(cfg, params, mesh=mesh, max_len=args.prompt_len
                     + args.max_new, packed=packed)
        load_s = time.perf_counter() - t0
        outs, stats = eng.serve(requests, batch_slots=args.batch_slots,
                                prompt_len=args.prompt_len,
                                max_new_tokens=args.max_new)
        label = "packed (proposed)" if packed else "per-call (baseline)"
        print(f"{label:22s} load {load_s:5.2f}s | "
              f"prefill {stats.prefill_tps:8,.0f} tok/s | "
              f"decode {stats.decode_tps:8,.0f} tok/s | "
              f"{len(outs)} requests served")


if __name__ == "__main__":
    main()
