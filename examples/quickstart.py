"""Quickstart: the paper's two levers in five minutes.

  1. pack a weight once at load (lever 2) and GEMM against it;
  2. compare with the stateless per-call path and the raw XLA dot;
  3. verify the bit-exactness discipline;
  4. run a small end-to-end model forward with packed projections.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import bitexact, packing, panel_gemm as pg
from repro.models import model_zoo
from repro.runtime.serve_loop import Engine

rng = np.random.default_rng(0)

# --- the paper's QKV prefill GEMM: C[128, 2048] = A[128, 2048] @ B ------
x = jnp.asarray(rng.standard_normal((128, 2048)), jnp.float32)
w_nk = jnp.asarray(rng.standard_normal((2048, 2048)), jnp.float32)  # [N,K]

# lever 2: pack once at model load (transpose from llama.cpp layout, pad,
# block-align).  Every later call pays only the compute loop.
pw = packing.pack(w_nk, transposed=True)
y_packed = pg.gemm(x, pw)

# the stateless baseline re-packs on EVERY call (cblas/BNNSMatMul role):
y_percall = pg.gemm_percall(x, w_nk, transposed=True)

# the shape-agnostic dot (Accelerate-dispatch role):
y_xla = pg.gemm_xla(x, w_nk, transposed=True)

bitexact.assert_bit_identical(np.asarray(y_packed), np.asarray(y_percall),
                              "packed vs per-call")
print("packed == per-call bitwise:", True)
print("max|packed - xla| (fp32 reorder only): "
      f"{bitexact.max_abs_diff_sampled(y_packed, y_xla, 997):.2e}")

# --- a whole model through the packed path ------------------------------
cfg = model_zoo.reduced_config(model_zoo.get_config("deepseek-7b"))
params = model_zoo.build(cfg)
engine = Engine(cfg, params, max_len=128, packed=True)
tokens, stats = engine.generate(
    jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    max_new_tokens=8)
print(f"generated {tokens.shape} tokens; prefill {stats.prefill_tps:,.0f} "
      f"tok/s, decode {stats.decode_tps:,.0f} tok/s (CPU smoke scale)")
