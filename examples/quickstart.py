"""Quickstart: the paper's two levers in five minutes, via plan/execute.

  1. resolve a dispatch plan for a shape (the policy picks the lever);
  2. pack a weight once at load (lever 2) and execute against it;
  3. compare with the stateless per-call plan and the raw XLA dot;
  4. verify the bit-exactness discipline;
  5. run a small end-to-end model forward with packed projections.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro import gemm as G
from repro.core import bitexact
from repro.models import model_zoo
from repro.runtime.serve_loop import Engine

rng = np.random.default_rng(0)

# --- the paper's QKV prefill GEMM: C[128, 2048] = A[128, 2048] @ B ------
x = jnp.asarray(rng.standard_normal((128, 2048)), jnp.float32)
w_nk = jnp.asarray(rng.standard_normal((2048, 2048)), jnp.float32)  # [N,K]

# the policy resolves the shape once: K >= N -> fine panels here
plan = G.plan(128, 2048, 2048, transposed=True)
print("policy plan:", plan.describe())

# lever 2: pack once at model load (transpose from llama.cpp layout, pad,
# block-align).  Every later call pays only the compute loop.
pw = G.pack_for_plan(plan, w_nk)
y_packed = G.execute(plan, x, pw)

# the stateless baseline re-packs on EVERY call (cblas/BNNSMatMul role):
y_percall = G.execute(plan, x, w_nk)

# the shape-agnostic dot (Accelerate-dispatch role):
p_xla = G.plan(128, 2048, 2048, backend="xla", pack=G.PACK_NONE,
               transposed=True)
y_xla = G.execute(p_xla, x, w_nk)

bitexact.assert_bit_identical(np.asarray(y_packed), np.asarray(y_percall),
                              "packed vs per-call")
print("packed == per-call bitwise:", True)
print("max|packed - xla| (fp32 reorder only): "
      f"{bitexact.max_abs_diff_sampled(y_packed, y_xla, 997):.2e}")
print("plan cache:", G.plan_cache_info())

# --- horizontal fusion + fused epilogue (one pass above the inner loop) --
from repro.core import packing  # noqa: E402

w_gate = jnp.asarray(rng.standard_normal((2048, 2048)), jnp.float32)
w_up = jnp.asarray(rng.standard_normal((2048, 2048)), jnp.float32)
# the pack blocks reserve VMEM for the glu store phase (two weight tiles
# + two accumulators), so pack and plan agree — model_zoo does this for
# every fused group at load
glu = G.EpilogueSpec(glu="silu")
bn, bk = G.pack_blocks(2 * 2048, 2048, epilogue=glu)
pw_gu = packing.pack_fused([w_gate, w_up], block_n=bn, block_k=bk)
p_glu = G.plan_for_packed(128, pw_gu, epilogue=glu)
x2 = jnp.asarray(rng.standard_normal((128, 2048)), jnp.float32)
h = G.execute(p_glu, x2, pw_gu)                  # silu(gate) * up, one GEMM
unfused = jnp.asarray(
    jax.jit(lambda a: (jax.nn.silu(a @ w_gate) * (a @ w_up)))(x2))
bitexact.assert_bit_identical(np.asarray(h), unfused, "fused glu vs 2 GEMMs")
print("fused gate-up (1 GEMM, glu epilogue) == unfused (2 GEMMs + 2 ops):",
      True)

# --- a whole model through the packed path ------------------------------
cfg = model_zoo.reduced_config(model_zoo.get_config("deepseek-7b"))
params = model_zoo.build(cfg)
engine = Engine(cfg, params, max_len=128, packed=True)
tokens, stats = engine.generate(
    jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    max_new_tokens=8)
print(f"generated {tokens.shape} tokens; prefill {stats.prefill_tps:,.0f} "
      f"tok/s, decode {stats.decode_tps:,.0f} tok/s (CPU smoke scale)")
