"""Overlapped collective matmuls — paper lever 1 at mesh scale.

The paper's Fig. 2 lesson: a coarse column panel serializes the machine
(one AMX block idle); panels fine enough to give every compute unit work
recover the 2-block aggregate.  The distributed analogue: a GEMM whose
operand needs an all-gather can either (a) all-gather THEN matmul — the
collective and the MXU serialize, the mesh-scale "coarse panel" — or
(b) decompose the GEMM into one panel per shard and rotate shards around
the ring with `ppermute`, so step i's compute hides step i+1's transfer
(the "collective matmul" of Wang et al. 2023, which XLA's
latency-hiding-scheduler also derives when the panels exist for it to
schedule).  These shard_map implementations make the decomposition
explicit and testable; the dry-run's HLO shows `collective-permute` ops
interleaved with per-panel dots instead of one monolithic all-gather.

All three are bit-stable per panel: each output tile is produced by
exactly one dot (ag_matmul) or a fixed-order chain of adds (matmul_rs),
matching the kernel's blocked-oracle discipline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map


def _ring(axis: str, size: int, fwd: bool = True):
    if fwd:
        return [(i, (i + 1) % size) for i in range(size)]
    return [((i + 1) % size, i) for i in range(size)]


def ag_matmul(x, w, *, mesh: Mesh, axis: str = "model"):
    """y = all_gather(x, K-axis) @ w, overlapped.

    x: [M, K/s] sharded over `axis` on K; w: [K, N/s] sharded over `axis`
    on N (column-parallel layer).  Each device computes its N-panel of the
    full y by accumulating K-panels as they arrive around the ring:
    y_local[M, N/s] = Σ_i x_i @ w[K_i, local].  Compute of panel i overlaps
    the ppermute bringing panel i+1.
    """
    s = mesh.shape[axis]
    perm = _ring(axis, s)

    def body(x_blk, w_full):
        # w_full: [K, N/s] local; x_blk: [M, K/s] — this device's K panel.
        idx = jax.lax.axis_index(axis)
        kb = x_blk.shape[-1]

        def step(c, _):
            acc, blk, i = c
            src = (idx - i) % s                 # whose K-panel we now hold
            wk = jax.lax.dynamic_slice_in_dim(w_full, src * kb, kb, axis=0)
            nxt = jax.lax.ppermute(blk, axis, perm)   # prefetch next panel
            acc = acc + jnp.dot(blk, wk,
                                preferred_element_type=jnp.float32)
            return (acc, nxt, i + 1), None

        acc0 = jnp.zeros(x_blk.shape[:-1] + (w_full.shape[-1],),
                         jnp.float32)
        (acc, _, _), _ = jax.lax.scan(step, (acc0, x_blk, 0), None,
                                      length=s)
        return acc.astype(x_blk.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis)),   # x: K-shard, w: N-shard
        out_specs=P(None, axis),
        check_vma=False,
    )(x, w)


def matmul_rs(x, w, *, mesh: Mesh, axis: str = "model"):
    """y = reduce_scatter(x @ w, N-axis), overlapped (row-parallel layer).

    x: [M, K/s] sharded over `axis` on K; w: [K/s, N] sharded on K.
    Each device owns partial sums for ALL of N; the ring rotates the
    accumulator so each hop adds the local contribution for the panel
    that will finally land on its owner — transfer of panel j overlaps
    compute of panel j+1.  Output: [M, N/s].
    """
    s = mesh.shape[axis]
    perm = _ring(axis, s)

    def body(x_blk, w_blk):
        idx = jax.lax.axis_index(axis)
        nb = w_blk.shape[-1] // s

        def wpanel(j):
            return jax.lax.dynamic_slice_in_dim(w_blk, j * nb, nb, axis=1)

        def step(c, _):
            acc, i = c
            # the accumulator held at scan step i still needs (s-1-i)
            # forward hops, so its final owner — whose panel we add — is
            # idx + (s-1-i) ≡ idx - 1 - i (mod s)
            j = (idx - 1 - i) % s
            acc = acc + jnp.dot(x_blk, wpanel(j),
                                preferred_element_type=jnp.float32)
            acc = jax.lax.ppermute(acc, axis, perm)
            return (acc, i + 1), None

        acc0 = jnp.zeros((x_blk.shape[0], nb), jnp.float32)
        (acc, _), _ = jax.lax.scan(step, (acc0, 0), None, length=s - 1)
        acc = acc + jnp.dot(x_blk, wpanel(idx),
                            preferred_element_type=jnp.float32)
        return acc.astype(x_blk.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, axis),
        check_vma=False,
    )(x, w)


def psum_bf16(x, axis: str):
    """Gradient-compression all-reduce: bf16 on the wire, fp32 result.

    Halves cross-pod (DCN) gradient-sync bytes; the fp32 master update in
    the optimizer keeps convergence (EXPERIMENTS.md §Perf records the
    collective-term delta).
    """
    return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("mesh_axes",))
def _noop(x, mesh_axes=None):
    return x
