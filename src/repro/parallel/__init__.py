"""Distribution: sharding rules, overlapped collectives, pipeline stages."""
from repro.parallel import collectives, sharding
from repro.parallel.sharding import (
    activation_sharder, batch_spec, cache_shardings, cache_specs, fit_spec,
    param_shardings, param_specs,
)

__all__ = [
    "collectives", "sharding", "activation_sharder", "batch_spec",
    "cache_shardings", "cache_specs", "fit_spec", "param_shardings",
    "param_specs",
]
