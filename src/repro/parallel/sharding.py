"""Sharding rules: logical parameter/activation axes → mesh axes.

Posture (DESIGN.md §4): batch→(pod, data); tensor-parallel dims
(vocab / flattened heads / d_ff / experts / ssm_inner)→model; parameter
d_model dims→data (**FSDP** — params and optimizer state are sharded over
the data axis and all-gathered per layer inside the scan, which is what
fits deepseek-v3-671b in 16 GB/chip).  The `pod` axis composes with `data`
for the batch only, so weights replicate across pods and the only
cross-pod (DCN) collective in a train step is the gradient all-reduce.

Every rule is divisibility-guarded: a dim that a mesh axis does not divide
falls back to replication on that dim (e.g. hymba's 25 heads — the
flattened 25*64=1600 projection dim shards; the (B,S,25,64) activation
does not, and GSPMD inserts the resharding, which the dry-run's collective
parse then prices).  This mirrors production logical-axis-rule systems
(MaxText et al.) rather than hand-placing every array.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Activation logical axes → mesh axes (used by activation_sharder).
ACT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "d_model": None,          # activations replicate d_model (params don't)
    "vocab": "model",
    "heads": "model",
    "kv_seq": None,
    "expert_group": ("pod", "data"),   # MoE dispatch groups ≙ batch shards
    "experts": "model",                # EP: buffers redistribute via a2a
}

# Parameter-name → PartitionSpec for the per-layer array (the leading
# stacked-layer dim, when present, is prepended as None automatically).
# Specs may name axes a given dim cannot host; the divisibility guard
# drops them per-array.
PARAM_RULES = {
    # embeddings / head
    "embed": P("model", "data"),          # (vocab, d_model)
    "lm_head": P("data", "model"),        # (d_model, vocab)
    # attention (flattened projections)
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wqkv": P("data", "model"),           # fused [K, Nq+Nk+Nv] pack
    "wo": P("model", "data"),
    # MLA
    "w_dq": P("data", "model"),
    "w_uq": P("data", "model"),           # (q_lora, h*(nope+rope))
    "w_dkv": P("data", "model"),
    "w_kr": P("data", "model"),
    "w_uk": P("data", "model"),           # (kv_lora, h*nope)
    "w_uv": P("data", "model"),
    # dense / shared-expert FFN
    "w_gate": P("data", "model"),
    "w_up": P("data", "model"),
    "w_gate_up": P("data", "model"),      # fused [K, 2F] glu pack
    "w_down": P("model", "data"),
    # MLA fused down-projections [K, q_lora + kv_lora + rope]
    "w_dqkr": P("data", "model"),
    # MoE (EP: experts over model)
    "router": P("data", None),
    "wi_gate": P("model", "data", None),  # (E, d, f)
    "wi_up": P("model", "data", None),
    # mamba
    "in_proj": P("data", "model"),
    "conv_w": P(None, "model"),
    "out_proj": P("model", "data"),
}
# moe down-proj shares the "wo" key inside p["moe"]; disambiguated by rank.
_MOE_WO = P("model", None, "data")

_VEC_KEYS = {  # 1-D per-layer vectors: replicate
    "ln1", "ln2", "ln1_post", "ln2_post", "final_norm", "norm",
    "ln_attn_out", "ln_ssm_out", "a_log", "d_skip", "dt_bias", "conv_b",
}


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return math.prod(axis_size(mesh, n) for n in name)
    return mesh.shape[name] if name in mesh.shape else 1


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that do not divide their dim (replicate fallback).

    For composite entries like ("pod", "data"), keeps the longest prefix
    whose product divides the dim.
    """
    out = []
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            out.append(None)
            continue
        names = entry if isinstance(entry, (tuple, list)) else (entry,)
        names = [n for n in names if n in mesh.shape]
        kept = []
        prod = 1
        for n in names:
            if shape[d] % (prod * mesh.shape[n]) == 0:
                kept.append(n)
                prod *= mesh.shape[n]
            else:
                break
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept
                                                      else None))
    out += [None] * (len(shape) - len(out))
    return P(*out)


def _path_names(path) -> list[str]:
    out = []
    for e in path:
        for attr in ("key", "name", "idx"):
            if hasattr(e, attr):
                out.append(str(getattr(e, attr)))
                break
        else:
            out.append(str(e))
    return out


def _spec_for(names: list[str], shape, mesh: Mesh, stacked: bool) -> P:
    # Rule lookup: last path component with a rule (so PackedWeight.data
    # under "wq" resolves to the "wq" rule).
    rule_name = next((n for n in reversed(names)
                      if n in PARAM_RULES or n in _VEC_KEYS), None)
    core_ndim = len(shape) - (1 if stacked else 0)
    if rule_name in _VEC_KEYS or rule_name is None or core_ndim <= 1:
        return fit_spec(P(*([None] * len(shape))), shape, mesh)
    if rule_name == "wo" and "moe" in names:
        base = _MOE_WO
    else:
        base = PARAM_RULES[rule_name]
    if stacked:
        base = P(None, *base)
    return fit_spec(base, shape, mesh)


def param_specs(params_tree, mesh: Mesh):
    """PartitionSpec pytree for a params pytree (arrays, ShapeDtypeStructs,
    or PackedWeight leaves).  Arrays under params["layers"] are
    scan-stacked (leading L dim → None)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        names = _path_names(path)
        stacked = "layers" in names and hasattr(leaf, "ndim") \
            and leaf.ndim >= 2
        # PackedWeight static fields (ints) flatten away; leaves here are
        # arrays / ShapeDtypeStructs only.
        specs.append(_spec_for(names, leaf.shape, mesh, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_tree, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def serve_param_specs(params_tree, mesh: Mesh, *,
                      hbm_budget: int = 16 * 2**30,
                      reserve_fraction: float = 0.5):
    """Serving placement — §Perf iteration C1.

    FSDP (d_model over data) is an OPTIMIZER-state compromise; at
    inference there is no optimizer state, and keeping it makes every
    decode step all-gather the weights (measured: the dominant collective
    on every decode cell).  Deployment rule: if TP-only weights fit in
    ``reserve_fraction`` of HBM (rest reserved for KV cache +
    activations), replicate over the data axes; otherwise keep the FSDP
    specs (deepseek-v3-671b: 84 GB/chip TP-only — stays sharded).

    This is the paper's lever-2 thinking applied to placement: pay once
    at model load (more resident bytes) to delete per-call work (the
    gather) — exactly the pre-pack trade.
    """
    specs = param_specs(params_tree, mesh)

    def drop_data(spec):
        def keep(entry):
            if entry is None:
                return None
            names = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(n for n in names if n not in ("data", "pod"))
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return P(*(keep(e) for e in spec))

    replicated = jax.tree.map(drop_data, specs,
                              is_leaf=lambda x: isinstance(x, P))
    # per-device bytes under the replicated plan
    leaves = jax.tree_util.tree_flatten(params_tree)[0]
    spec_leaves = jax.tree.leaves(replicated,
                                  is_leaf=lambda x: isinstance(x, P))
    per_dev = 0
    for leaf, spec in zip(leaves, spec_leaves):
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            shards *= math.prod(mesh.shape[n] for n in names)
        per_dev += (math.prod(leaf.shape)
                    * np.dtype(leaf.dtype).itemsize) // max(shards, 1)
    if per_dev <= hbm_budget * reserve_fraction:
        return replicated
    return specs


def serve_param_shardings(params_tree, mesh: Mesh, **kw):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        serve_param_specs(params_tree, mesh, **kw),
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------------ cache
def cache_specs(cache_tree, mesh: Mesh, cfg=None):
    """Specs for a decode cache pytree (layer-stacked leading dim).

    k/v: (L, B, T, Hkv, D) — batch over (pod, data); kv_heads over model
    when divisible, else head_dim over model (DESIGN.md §4).  SSM state:
    (L, B, H, P, N) — heads over model when divisible, else head_dim.
    """
    def spec(path, aval):
        name = path[-1]
        shape = aval.shape
        if name == "index":
            return P()
        if name in ("k", "v"):
            base = P(None, ("pod", "data"), None, "model", None)
            if shape[3] % max(axis_size(mesh, "model"), 1) != 0:
                base = P(None, ("pod", "data"), None, None, "model")
        elif name == "pos":
            base = P(None, ("pod", "data"), None)
        elif name in ("ckv", "krope"):                 # MLA latent cache
            base = P(None, ("pod", "data"), None, None)
        elif name == "state":
            base = P(None, ("pod", "data"), "model", None, None)
            if shape[2] % max(axis_size(mesh, "model"), 1) != 0:
                base = P(None, ("pod", "data"), None, "model", None)
        elif name == "conv":
            base = P(None, ("pod", "data"), None, "model")
        else:
            base = P(*([None] * len(shape)))
        return fit_spec(base, shape, mesh)

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        return spec(path, node)
    return walk((), cache_tree)


def cache_shardings(cache_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cache_tree, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# -------------------------------------------------------------- activations
def batch_spec(batch_size: int, mesh: Mesh, extra_dims: int = 1) -> P:
    """Input-batch spec: largest (pod, data) prefix dividing batch_size."""
    return fit_spec(P(("pod", "data"), *([None] * extra_dims)),
                    (batch_size,) + (1,) * extra_dims, mesh)


def activation_sharder(mesh: Mesh, *, drop_axes: frozenset = frozenset()):
    """shard(x, *logical_names) → with_sharding_constraint under `mesh`.

    ``drop_axes``: mesh axes to omit from every constraint — used inside
    partial-manual shard_map regions, where the manual axes (data/pod)
    must not appear in auto sharding constraints.
    """
    def _filter(entry):
        if entry is None:
            return None
        names = entry if isinstance(entry, (tuple, list)) else (entry,)
        kept = tuple(n for n in names if n not in drop_axes)
        return kept if len(kept) > 1 else (kept[0] if kept else None)

    def shard(x, *names):
        spec = fit_spec(P(*(_filter(ACT_RULES.get(n)) for n in names)),
                        x.shape, mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    return shard


def count_shards(tree, mesh: Mesh) -> dict:
    """Diagnostics: bytes per device under the computed shardings."""
    specs = param_specs(tree, mesh)
    total = 0
    per_dev = 0
    for aval, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(specs, is_leaf=lambda x:
                                          isinstance(x, P))):
        nbytes = math.prod(aval.shape) * np.dtype(aval.dtype).itemsize
        shards = math.prod(axis_size(mesh, e) for e in spec
                           if e is not None)
        total += nbytes
        per_dev += nbytes // max(shards, 1)
    return {"global_bytes": total, "bytes_per_device": per_dev}
