"""Plan/execute GEMM dispatch — the one surface for every GEMM this repo
runs (replaces the three ad-hoc entry points in ``core/panel_gemm``).

    from repro import gemm

    p = gemm.plan(m, n, k)                 # shape-resolved policy + cache
    pw = gemm.pack_for_plan(p, w)          # pay the pack once (lever 2)
    y = gemm.execute(p, x, pw)             # per call: compute loop only

See ``docs/gemm_api.md`` for the policy table, cache semantics, backend
registry, the quantized ``weight_format`` plans (docs/quantization.md),
and the migration table for the REMOVED ``core.panel_gemm`` shims.
"""
from repro.gemm.backends import (Backend, UnknownBackendError,
                                 default_backend, get_backend,
                                 list_backends, register_backend,
                                 unregister_backend, use_backend)
from repro.gemm.execute import (PlanMismatchError, execute, lead_m,
                                pack_for_plan, split_fused, validate_plan)
from repro.gemm.plan import (EpilogueSpec, GemmPlan, LEVER_FINE_PANELS,
                             LEVER_PREPACK, PACK_NONE, PACK_PERCALL,
                             PACK_PREPACKED)
from repro.gemm.plan_store import (PlanStore, StoreInfo, SCHEMA_VERSION,
                                   active_plan_store, as_plan_store,
                                   host_fingerprint, no_plan_store,
                                   plan_store_info, set_plan_store,
                                   use_plan_store)
from repro.gemm.policy import (DECODE_M_BUCKETS, DECODE_SPLIT_K_CANDIDATES,
                               DEFAULT_NUM_CORES, PREFILL_M_BUCKETS,
                               bucket_m, decode_lane, in_decode_lane,
                               pack_blocks, plan, plan_cache_clear,
                               plan_cache_info, plan_for_packed,
                               policy_table, sparse_threshold,
                               store_key, vmem_clamped_count)
from repro.kernels.panel_gemm import apply_epilogue, splitk_combine

__all__ = [
    "Backend", "EpilogueSpec", "GemmPlan", "PlanMismatchError",
    "PlanStore", "StoreInfo", "SCHEMA_VERSION",
    "UnknownBackendError",
    "LEVER_FINE_PANELS", "LEVER_PREPACK", "DEFAULT_NUM_CORES",
    "PACK_NONE", "PACK_PERCALL", "PACK_PREPACKED", "PREFILL_M_BUCKETS",
    "DECODE_M_BUCKETS", "DECODE_SPLIT_K_CANDIDATES",
    "active_plan_store", "apply_epilogue", "as_plan_store", "bucket_m",
    "decode_lane", "default_backend", "execute", "get_backend",
    "host_fingerprint", "in_decode_lane", "lead_m", "list_backends",
    "no_plan_store", "pack_blocks", "pack_for_plan", "plan",
    "plan_cache_clear", "plan_cache_info", "plan_for_packed",
    "plan_store_info", "policy_table", "register_backend",
    "set_plan_store", "sparse_threshold", "split_fused",
    "splitk_combine", "store_key",
    "unregister_backend", "use_backend", "use_plan_store",
    "validate_plan", "vmem_clamped_count",
]
