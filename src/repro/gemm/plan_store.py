"""Persistent plan/autotune store — the plan cache that survives the process.

The in-process LRU in ``gemm/policy`` dies with the process, so a fleet
of serving processes pays the full cold-start tax (policy resolution,
bit-exactness gates, measured autotune sweeps) on every boot.  The
paper's sharpest deployment finding — a mis-tuned column-panel width
costs ~2x — argues those decisions are worth *measuring* once and
keeping: this module is the on-disk side of that discipline.

A :class:`PlanStore` maps a **store key** — the policy request tuple
``(m, n, k, dtype, weight_format, backend, num_cores, blocks, pack,
transposed, sharding, epilogue, fused_n_splits, decode, split_k)``,
i.e. the in-memory cache key minus ``validate`` — to a serialized
:class:`~repro.gemm.plan.GemmPlan` plus its autotune provenance
(``t_meas``, ``autotuned``).  ``policy.plan`` consults the *active*
store before running ``_resolve``: a hit skips the analytic policy, the
VMEM fit AND (for validated entries) the bit-exactness gate, so a
second process with a populated store starts hot.

Durability contract:

  * **atomic writes** — ``save()`` writes a temp file in the target
    directory and ``os.replace``s it over the store path; concurrent
    writers race to a *complete* file, never a torn one.
  * **corruption-tolerant loads** — a truncated/garbled/absent store
    file yields an EMPTY store (``invalidated`` records why) and the
    policy falls back to analytic resolution; a load never raises.
  * **invalidation** — the file header carries ``schema``
    (:data:`SCHEMA_VERSION`) and a ``host`` fingerprint (backend
    platform, device kind, jax version, kernel VMEM budget); either
    mismatching discards the stored plans, because measured winners and
    VMEM-clamped block triples do not transfer across hosts or plan
    semantics changes.

Scope plumbing (mirrors ``gemm.use_backend``): the active store is the
innermost :func:`use_plan_store` scope, else the process default set by
:func:`set_plan_store`.  ``Engine`` wraps its pack + trace bodies in
the scope so every plan its serving steps resolve goes through (and
lands in) its store.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import os
import platform
import tempfile
import threading
from typing import Any, Iterator

import jax

from repro.gemm.plan import EpilogueSpec, GemmPlan

# Bump when the GemmPlan schema / policy semantics change in a way that
# makes stored plans untrustworthy (e.g. new plan-keyed fields, kernel
# VMEM accounting changes).  A stored file with any other version is
# discarded wholesale at load.
# v2: sparse-ternary arm — plans carry density_bucket, store keys grew
# the bucket element, and the scheduler/VMEM models score sparse walks.
SCHEMA_VERSION = 2

StoreInfo = collections.namedtuple(
    "StoreInfo", ["hits", "misses", "autotuned", "entries", "path"])


def host_fingerprint() -> str:
    """The invalidation fingerprint: measured winners and VMEM-fit
    block triples are host properties, so plans never transfer across
    (platform, device kind, jax version, VMEM budget) changes."""
    from repro.kernels import panel_gemm as _kernel
    try:
        dev = jax.devices()[0]
        dev_part = f"{dev.platform}:{getattr(dev, 'device_kind', '?')}"
    except Exception:                      # no runtime yet: still usable
        dev_part = "none"
    return "|".join((platform.machine(), platform.system(), dev_part,
                     f"jax-{jax.__version__}",
                     f"vmem-{_kernel.VMEM_BUDGET}"))


# ------------------------------------------------------- (de)serialization
def _plan_to_doc(p: GemmPlan) -> dict:
    d = dataclasses.asdict(p)
    # EpilogueSpec nests as a dict via asdict already; normalize tuples
    d["fused_n_splits"] = list(p.fused_n_splits)
    return d


def _plan_from_doc(d: dict) -> GemmPlan:
    d = dict(d)
    epi = d.get("epilogue")
    d["epilogue"] = EpilogueSpec(**epi) if epi is not None else None
    d["fused_n_splits"] = tuple(int(s) for s in d.get("fused_n_splits", ()))
    p = GemmPlan(**d)
    # cheap structural sanity so one garbled entry cannot poison dispatch
    if not (p.m > 0 and p.n > 0 and p.k > 0 and p.block_m > 0
            and p.block_n > 0 and p.block_k > 0 and p.split_k >= 1):
        raise ValueError(f"implausible stored plan geometry: {d}")
    return p


class PlanStore:
    """In-memory dict of resolved plans with an on-disk JSON home.

    Thread-safe; ``lookup``/``put`` are what the policy calls on its
    store-consulting path, ``save``/``load`` are the process-boundary
    crossings.  Counters (``hits``/``misses``) are per-instance and
    per-process — they are the warm-start observability ``ServeStats``
    surfaces, independent of the in-memory plan cache's counters.
    """

    def __init__(self, path: str | os.PathLike | None = None, *,
                 host: str | None = None):
        self.path = os.fspath(path) if path is not None else None
        self.host = host if host is not None else host_fingerprint()
        self._plans: dict[str, dict] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.invalidated: str | None = None   # why a load discarded disk

    # ------------------------------------------------------------ loading
    @classmethod
    def load(cls, path: str | os.PathLike, *,
             host: str | None = None) -> "PlanStore":
        """Load a store file; NEVER raises.  A missing, truncated,
        garbled, schema-mismatched or host-mismatched file returns an
        empty store (``invalidated`` says why) — the policy then falls
        back to analytic resolution and the next ``save`` rewrites the
        file whole."""
        st = cls(path, host=host)
        try:
            with open(st.path, "r", encoding="utf-8") as f:
                doc = json.load(f)
        except FileNotFoundError:
            return st                       # fresh store: not an error
        except Exception as e:              # truncated / garbled / perms
            st.invalidated = f"corrupt store file ({type(e).__name__})"
            return st
        if not isinstance(doc, dict):
            st.invalidated = "corrupt store file (not an object)"
            return st
        if doc.get("schema") != SCHEMA_VERSION:
            st.invalidated = (f"schema {doc.get('schema')!r} != "
                              f"{SCHEMA_VERSION}")
            return st
        if doc.get("host") != st.host:
            st.invalidated = "host fingerprint mismatch"
            return st
        plans = doc.get("plans")
        if not isinstance(plans, dict):
            st.invalidated = "corrupt store file (no plans table)"
            return st
        for key, ent in plans.items():
            try:
                p = _plan_from_doc(ent["plan"])
                st._plans[key] = {
                    "plan": p,
                    "t_meas": ent.get("t_meas"),
                    "autotuned": bool(ent.get("autotuned", False)),
                }
            except Exception:
                continue                    # skip the one bad entry
        return st

    # ----------------------------------------------------------- querying
    def lookup(self, key: str) -> GemmPlan | None:
        with self._lock:
            ent = self._plans.get(key)
            if ent is None:
                self.misses += 1
                return None
            self.hits += 1
            return ent["plan"]

    def entry(self, key: str) -> dict | None:
        """The full record (plan + provenance) without counting."""
        with self._lock:
            ent = self._plans.get(key)
            return dict(ent) if ent is not None else None

    def put(self, key: str, plan: GemmPlan, *, t_meas: float | None = None,
            autotuned: bool = False) -> None:
        with self._lock:
            self._plans[key] = {"plan": plan, "t_meas": t_meas,
                                "autotuned": autotuned}

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def keys(self) -> list[str]:
        with self._lock:
            return list(self._plans)

    def info(self) -> StoreInfo:
        with self._lock:
            auto = sum(1 for e in self._plans.values() if e["autotuned"])
            return StoreInfo(self.hits, self.misses, auto,
                             len(self._plans), self.path)

    # ------------------------------------------------------------- saving
    def save(self, path: str | os.PathLike | None = None) -> str:
        """Atomically write the store: temp file in the destination
        directory, then ``os.replace`` — a reader (or a racing writer)
        sees either the old complete file or the new complete file."""
        path = os.fspath(path) if path is not None else self.path
        if path is None:
            raise ValueError("PlanStore has no path; pass save(path=...)")
        with self._lock:
            doc = {
                "schema": SCHEMA_VERSION,
                "host": self.host,
                "plans": {k: {"plan": _plan_to_doc(e["plan"]),
                              "t_meas": e["t_meas"],
                              "autotuned": e["autotuned"]}
                          for k, e in self._plans.items()},
            }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".planstore.", suffix=".tmp",
                                   dir=d)
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(doc, f, indent=1)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path


# --------------------------------------------------------- active store
# The store ``policy.plan`` consults: innermost use_plan_store scope,
# else the process default.  _OFF is the explicit "no store" scope the
# measured autotuner uses so its candidate resolutions never read (or
# pollute) the store it is about to populate.
_OFF = object()
_default_store: PlanStore | None = None
_SCOPE = threading.local()


def set_plan_store(store: PlanStore | None) -> PlanStore | None:
    """Set the process-default plan store; returns the previous one."""
    global _default_store
    prev, _default_store = _default_store, store
    return prev


def active_plan_store() -> PlanStore | None:
    stack = getattr(_SCOPE, "stack", None)
    if stack:
        top = stack[-1]
        return None if top is _OFF else top
    return _default_store


@contextlib.contextmanager
def use_plan_store(store: PlanStore | None) -> Iterator[None]:
    """Scope ``store`` as the active plan store (``use_backend``
    analogue).  ``None`` is a no-op — the ambient store (outer scope or
    process default) stays active, so wrappers can thread an optional
    store unconditionally."""
    if store is None:
        yield
        return
    stack = getattr(_SCOPE, "stack", None)
    if stack is None:
        stack = _SCOPE.stack = []
    stack.append(store)
    try:
        yield
    finally:
        stack.pop()


@contextlib.contextmanager
def no_plan_store() -> Iterator[None]:
    """Scope with NO active store — candidate resolutions inside a
    measured autotune sweep must come from the analytic policy, not the
    store being populated."""
    stack = getattr(_SCOPE, "stack", None)
    if stack is None:
        stack = _SCOPE.stack = []
    stack.append(_OFF)
    try:
        yield
    finally:
        stack.pop()


def plan_store_info() -> StoreInfo | None:
    """Counters of the active store (None when no store is active) —
    what ``ServeStats.plan_store`` snapshots."""
    st = active_plan_store()
    return st.info() if st is not None else None


def as_plan_store(store: "PlanStore | str | os.PathLike | None",
                  ) -> PlanStore | None:
    """Coerce an Engine-style ``plan_store=`` argument: a path loads
    (corruption-tolerantly), a PlanStore passes through, None is None."""
    if store is None or isinstance(store, PlanStore):
        return store
    return PlanStore.load(store)
