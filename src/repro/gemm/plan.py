"""``GemmPlan`` — the frozen, hashable description of one GEMM dispatch.

A plan is everything the paper decides *above* the inner loop, resolved
once per shape and reused on every call:

  * the operand geometry (m, n, k, dtype),
  * which backend runs the compute loop (``xla`` / ``pallas`` /
    ``interpret`` / anything registered via ``gemm.register_backend``),
  * the panel blocking (block_m, block_n, block_k) — the paper's
    (M, Nc, Kc) levers,
  * the pack decision (``prepack``: pay the weight re-layout once at
    model load, or accept the per-call pack),
  * which policy lever produced it (``lever``), and the scheduler model's
    predicted time (``t_pred``) so callers can log/compare decisions.

Plans carry no arrays: the whole object is static metadata, registered
with :func:`jax.tree_util.register_static` so it crosses jit / scan /
checkpoint boundaries as a leafless pytree and can be closed over or
passed as a static argument without retracing surprises.
"""
from __future__ import annotations

import dataclasses
import math

import jax

from repro.kernels.panel_gemm import EpilogueSpec  # noqa: F401  (re-export)

LEVER_FINE_PANELS = "fine_panels"   # K >= N: occupancy-sized column panels
LEVER_PREPACK = "prepack"           # N > K: deep-K pre-packed weight

# Pack decisions a plan can carry (how execute() treats a RAW weight —
# a PackedWeight operand has already paid its pack at load):
PACK_PREPACKED = "prepacked"   # weight should be packed once at load
PACK_PERCALL = "percall"       # transpose+pad inside the call (baseline)
PACK_NONE = "none"             # no re-layout at all (raw-dot analogue)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Shape-resolved GEMM dispatch decision (see module docstring).

    ``transposed`` records the raw-weight layout execute() will receive
    ([N, K] llama.cpp convention when True); a ``PackedWeight`` operand
    ignores it.  ``sharding_key`` keeps plans for differently-placed
    operands distinct in the cache without holding device objects.

    Fusion fields: ``epilogue`` is the statically-planned
    :class:`~repro.kernels.panel_gemm.EpilogueSpec` the store step
    applies (None = plain GEMM); ``fused_n_splits`` is the horizontal
    split map of a ``pack_fused`` weight (logical part widths — ``n`` is
    then the padded concatenated width).  ``vmem_clamped`` records that
    the policy shrank the requested blocks to honor the kernel VMEM
    budget.

    ``weight_format`` is the pack-time weight format the plan executes
    against: ``"fp32"`` (any raw/packed fp-dtype weight — ``dtype``
    carries the actual operand dtype) or a quantized format from
    ``repro.quant.FORMATS`` (``"int8"`` / ``"ternary"``), in which case
    execute() requires a ``QuantizedPackedWeight`` operand and
    dispatches the backend's dequant-fused run.  Plan-keyed: quantized
    and fp32 plans for one shape are distinct cache entries, and the
    VMEM fit uses the format's bytes-per-element.

    Decode-lane fields: ``decode`` marks a plan resolved by the decode
    policy arm (``gemm.decode_lane()`` scope — skinny block_m, forced
    prepack, split-K considered; plan-keyed so decode and prefill plans
    for one shape never alias).  ``split_k`` is the number of parallel
    K slices the reduction is cut into (1 = the classic kernel); the
    per-slice fp32 partials are combined by the deterministic
    ``splitk_combine`` tree, and the plan's VMEM fit budgets the
    partials slab.  ``split_k`` is resolved per (n, k, format) at the
    canonical decode M — never per operand M — so every decode-bucket
    plan for one weight shares one slice map and ``serve`` stays
    bit-identical to per-request ``generate``.

    Sparse-ternary field: ``density_bucket`` is ``-1`` on the dense arm
    and the pack's zero-group-fraction decile (0..9, see
    ``quant.density_bucket_of``) on a plan resolved for a
    ``SparseTernaryPackedWeight`` — plan-keyed, so the sparse and dense
    ternary arms for one shape never alias in the cache or the plan
    store.  Sparse plans execute the group-granular sparse walk (which
    ignores ``block_k``) and always carry ``split_k=1``.
    """
    m: int
    n: int
    k: int
    dtype: str
    backend: str
    block_m: int
    block_n: int
    block_k: int
    pack: str
    lever: str
    t_pred: float = float("nan")
    occupancy: float = float("nan")
    transposed: bool = False
    sharding_key: str = ""
    validated: bool = False
    epilogue: EpilogueSpec | None = None
    fused_n_splits: tuple = ()
    vmem_clamped: bool = False
    weight_format: str = "fp32"
    split_k: int = 1
    decode: bool = False
    density_bucket: int = -1

    # ----------------------------------------------------------- geometry
    @property
    def prepack(self) -> bool:
        """True when the policy wants this weight packed at model load."""
        return self.pack == PACK_PREPACKED

    @property
    def m_pad(self) -> int:
        return math.ceil(self.m / self.block_m) * self.block_m

    @property
    def n_pad(self) -> int:
        return math.ceil(self.n / self.block_n) * self.block_n

    @property
    def k_pad(self) -> int:
        return math.ceil(self.k / self.block_k) * self.block_k

    @property
    def grid(self) -> tuple[int, int, int]:
        return (self.m_pad // self.block_m, self.n_pad // self.block_n,
                self.k_pad // self.block_k)

    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.m, self.n, self.k)

    @property
    def glu(self) -> bool:
        return self.epilogue is not None and self.epilogue.glu is not None

    @property
    def quantized(self) -> bool:
        """True when this plan executes a quantized pack-time format."""
        return self.weight_format != "fp32"

    @property
    def sparse(self) -> bool:
        """True when this plan executes the compressed-ternary walk."""
        return self.density_bucket >= 0

    @property
    def n_out(self) -> int:
        """Output column count execute() returns.

        A glu epilogue combines the two column halves of the fused weight
        (output = one logical part); everything else keeps the weight's
        N (fused non-glu output carries every part — ``split_fused``
        slices it by the static split map).
        """
        if self.glu:
            return (self.fused_n_splits[0] if self.fused_n_splits
                    else self.n // 2)
        return self.n

    def describe(self) -> str:
        """One-line human summary (benchmarks / logs)."""
        epi = ""
        if self.epilogue is not None:
            epi = f", epilogue={self.epilogue}"
        if self.fused_n_splits:
            epi += f", fused={self.fused_n_splits}"
        if self.quantized:
            epi += f", weight_format={self.weight_format}"
        if self.sparse:
            epi += f", sparse(bucket={self.density_bucket})"
        if self.decode:
            epi += f", lane=decode, split_k={self.split_k}"
        elif self.split_k != 1:
            epi += f", split_k={self.split_k}"
        if self.vmem_clamped:
            epi += ", vmem_clamped"
        return (f"GemmPlan[{self.m}x{self.n}x{self.k} {self.dtype} "
                f"-> {self.backend}, blocks=({self.block_m},{self.block_n},"
                f"{self.block_k}), lever={self.lever}, pack={self.pack}"
                f"{epi}]")
