"""``GemmPlan`` — the frozen, hashable description of one GEMM dispatch.

A plan is everything the paper decides *above* the inner loop, resolved
once per shape and reused on every call:

  * the operand geometry (m, n, k, dtype),
  * which backend runs the compute loop (``xla`` / ``pallas`` /
    ``interpret`` / anything registered via ``gemm.register_backend``),
  * the panel blocking (block_m, block_n, block_k) — the paper's
    (M, Nc, Kc) levers,
  * the pack decision (``prepack``: pay the weight re-layout once at
    model load, or accept the per-call pack),
  * which policy lever produced it (``lever``), and the scheduler model's
    predicted time (``t_pred``) so callers can log/compare decisions.

Plans carry no arrays: the whole object is static metadata, registered
with :func:`jax.tree_util.register_static` so it crosses jit / scan /
checkpoint boundaries as a leafless pytree and can be closed over or
passed as a static argument without retracing surprises.
"""
from __future__ import annotations

import dataclasses
import math

import jax

LEVER_FINE_PANELS = "fine_panels"   # K >= N: occupancy-sized column panels
LEVER_PREPACK = "prepack"           # N > K: deep-K pre-packed weight

# Pack decisions a plan can carry (how execute() treats a RAW weight —
# a PackedWeight operand has already paid its pack at load):
PACK_PREPACKED = "prepacked"   # weight should be packed once at load
PACK_PERCALL = "percall"       # transpose+pad inside the call (baseline)
PACK_NONE = "none"             # no re-layout at all (raw-dot analogue)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Shape-resolved GEMM dispatch decision (see module docstring).

    ``transposed`` records the raw-weight layout execute() will receive
    ([N, K] llama.cpp convention when True); a ``PackedWeight`` operand
    ignores it.  ``sharding_key`` keeps plans for differently-placed
    operands distinct in the cache without holding device objects.
    """
    m: int
    n: int
    k: int
    dtype: str
    backend: str
    block_m: int
    block_n: int
    block_k: int
    pack: str
    lever: str
    t_pred: float = float("nan")
    occupancy: float = float("nan")
    transposed: bool = False
    sharding_key: str = ""
    validated: bool = False

    # ----------------------------------------------------------- geometry
    @property
    def prepack(self) -> bool:
        """True when the policy wants this weight packed at model load."""
        return self.pack == PACK_PREPACKED

    @property
    def m_pad(self) -> int:
        return math.ceil(self.m / self.block_m) * self.block_m

    @property
    def n_pad(self) -> int:
        return math.ceil(self.n / self.block_n) * self.block_n

    @property
    def k_pad(self) -> int:
        return math.ceil(self.k / self.block_k) * self.block_k

    @property
    def grid(self) -> tuple[int, int, int]:
        return (self.m_pad // self.block_m, self.n_pad // self.block_n,
                self.k_pad // self.block_k)

    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.m, self.n, self.k)

    def describe(self) -> str:
        """One-line human summary (benchmarks / logs)."""
        return (f"GemmPlan[{self.m}x{self.n}x{self.k} {self.dtype} "
                f"-> {self.backend}, blocks=({self.block_m},{self.block_n},"
                f"{self.block_k}), lever={self.lever}, pack={self.pack}]")
