"""Shape-resolved dispatch policy + the LRU plan cache.

The paper's structural finding (§4, Table 3): all the headroom over
Accelerate lives in two deployment-level levers chosen *per shape*:

  =============  =============================  =========================
  shape class    winning lever                  plan it resolves to
  =============  =============================  =========================
  K >= N         fine multi-thread panels       ``lever="fine_panels"``:
                 (QKV / FFN-down class — the    block_n sized for grid
                 idle-second-block failure of   occupancy by the
                 coarse panels, paper Fig. 2)   scheduler model;
                                                per-call pack acceptable
  N > K          pre-packed weights             ``lever="prepack"``:
                 (FFN-up / LM-head class —      deep-K blocks
                 the per-call transpose+pad     (Kc = 2048 analogue),
                 dominates, paper §3.2)         weight packed at load
  =============  =============================  =========================

``plan()`` resolves those levers once per ``(shape, dtype, sharding,
backend)`` and memoizes the result in a bounded LRU cache, so the policy
runs at model load / first trace, never per call — the plan-then-execute
separation of BNNS Graph, with the plan inspectable.
"""
from __future__ import annotations

import collections
import contextlib
import threading
import warnings
from typing import Any, Iterator

import jax.numpy as jnp
import numpy as np

import jax

from repro.core import bitexact, packing, scheduler
from repro.gemm import backends as _backends
from repro.gemm import plan_store as _plan_store
from repro.gemm.plan import (EpilogueSpec, GemmPlan, LEVER_FINE_PANELS,
                             LEVER_PREPACK, PACK_NONE, PACK_PERCALL,
                             PACK_PREPACKED)
from repro.kernels import panel_gemm as _kernel
from repro.obs import spans as _spans

# Occupancy target of the fine-panel lever: the paper tunes panels against
# the two AMX blocks; the TPU analogue scores candidates against this many
# parallel compute units (table5's sweep setting).
DEFAULT_NUM_CORES = 8

# Column-panel widths the fine lever considers (the paper's Nc in
# {64..512}); the prepack lever takes the sweep's deployed deep pair.
FINE_BLOCK_N_CANDIDATES = (128, 256, 512)
FINE_BLOCK_K = 512

# Split-K slice counts the decode arm scores (1 = no split).  Resolved
# per (n, k, format) at DECODE_SPLIT_M_REF — NEVER per operand M — so
# every decode-bucket plan for one weight shares one slice map: serve
# (decode at M = slots) and generate (decode at M = batch) must stay
# bit-identical, and split-K changes the accumulation order.
DECODE_SPLIT_K_CANDIDATES = (1, 2, 4, 8)
DECODE_SPLIT_M_REF = 8

_CACHE_MAXSIZE = 512

CacheInfo = collections.namedtuple(
    "CacheInfo", ["hits", "misses", "maxsize", "currsize"])

_cache: "collections.OrderedDict[tuple, GemmPlan]" = collections.OrderedDict()
_cache_lock = threading.Lock()
_hits = 0
_misses = 0
# per-key in-flight resolutions (bugfix: two threads missing on one key
# used to both run _resolve — and its bit-exactness/autotune gate —
# outside the lock, double-counting the miss; now the first thread owns
# the resolution and everyone else waits on its Event and counts a hit)
_inflight: dict[tuple, threading.Event] = {}


def plan_cache_info() -> CacheInfo:
    with _cache_lock:
        return CacheInfo(_hits, _misses, _CACHE_MAXSIZE, len(_cache))


def vmem_clamped_count() -> int:
    """How many currently-cached plans had their blocks shrunk to honor
    the kernel VMEM budget (serving observability: surfaced in
    ``GenStats``/``ServeStats`` and the benchmark reports)."""
    with _cache_lock:
        return sum(1 for p in _cache.values() if p.vmem_clamped)


def plan_cache_clear() -> None:
    """Reset the plan cache to a fresh-process state: entries, the
    hit/miss counters ``plan_cache_info`` reports (stale counters make
    warm-start store metrics unreadable), and the clamp warn-state —
    all under the cache lock, atomically with respect to ``plan()``."""
    global _hits, _misses
    with _cache_lock:
        _cache.clear()
        _hits = _misses = 0
        _vmem_warned.clear()


def _warn_key(p: GemmPlan) -> tuple:
    return (p.m, p.n, p.k, p.dtype, p.backend, p.weight_format)


def _cache_insert(key: tuple, p: GemmPlan) -> None:
    """Insert under the LRU bound.  Bugfix: when a clamped plan is
    evicted, its ``_vmem_warned`` entry is dropped too (unless another
    cached plan still maps to the same warn key) — previously the set
    grew without bound in long-lived serving with many clamped shapes,
    and a re-resolved evicted plan never re-warned."""
    with _cache_lock:
        _cache[key] = p
        _cache.move_to_end(key)
        while len(_cache) > _CACHE_MAXSIZE:
            _, old = _cache.popitem(last=False)
            if old.vmem_clamped:
                wk = _warn_key(old)
                if not any(q.vmem_clamped and _warn_key(q) == wk
                           for q in _cache.values()):
                    _vmem_warned.discard(wk)


def _dtype_name(dtype: Any) -> str:
    return jnp.dtype(dtype).name


def _sharding_key(sharding: Any) -> str:
    return "" if sharding is None else str(sharding)


# -------------------------------------------------------- decode fast lane
_LANE = threading.local()        # per-thread decode-lane scope stack


@contextlib.contextmanager
def decode_lane() -> Iterator[None]:
    """Scope marking every plan resolved inside as a DECODE dispatch.

    The serving engine wraps its decode traces (dense ``decode``, paged
    ``decode_step``, the megastep body) in this scope, exactly like
    ``use_backend``: plans resolved while tracing take the decode policy
    arm — skinny block_m, forced prepack, split-K scored against the
    combine cost — and are plan-keyed separately from prefill plans of
    the same shape.  Prefill paths (one-shot and chunked admission)
    never enter the scope, so their plans and numerics are untouched.
    """
    depth = getattr(_LANE, "depth", 0)
    _LANE.depth = depth + 1
    try:
        yield
    finally:
        _LANE.depth = depth


def in_decode_lane() -> bool:
    """True inside a :func:`decode_lane` scope (trace-time query)."""
    return getattr(_LANE, "depth", 0) > 0


# ------------------------------------------------------------ lever logic
def _fine_block_n(m: int, n: int, k: int, *, block_m: int, block_k: int,
                  num_cores: int) -> int:
    """Occupancy-sized column panel: pick the candidate width whose
    scheduler-predicted time is best (the paper's Fig. 2 sweep, online)."""
    cands = sorted({packing.fit_block(n, c) for c in FINE_BLOCK_N_CANDIDATES})

    def score(bn: int):
        p = scheduler.plan(m, n, k, block_m=block_m, block_n=bn,
                           block_k=block_k, num_cores=num_cores)
        return (p.t_pred, bn)          # tie-break toward finer panels

    return min(cands, key=score)


_vmem_warned: set = set()


def _warn_vmem_clamp(key: tuple, requested: tuple, got: tuple):
    """Satellite: a clamped block triple used to be silent unless the
    caller inspected ``plan.vmem_clamped`` — now the FIRST resolution of
    each clamped plan key warns, naming the key (cleared alongside the
    plan cache so tests can re-arm it)."""
    if key in _vmem_warned:
        return
    _vmem_warned.add(key)
    warnings.warn(
        f"gemm policy clamped the block triple {requested} -> {got} to "
        f"fit the kernel VMEM budget for plan key {key} (the plan "
        f"records this as vmem_clamped=True)", RuntimeWarning,
        stacklevel=3)


def _fit_vmem(bm: int, bn: int, bk: int, dtype: str,
              epilogue: EpilogueSpec | None,
              weight_format: str = "fp32", split_k: int = 1,
              sparse_groups: int = 0, sparse_n: int = 0):
    """Shrink the block triple until ``kernels.panel_gemm.vmem_bytes``
    fits the VMEM budget (satellite: an explicit or fused-wide triple —
    a glu epilogue doubles the weight + accumulator tiles — could
    otherwise exceed it).  Shrinks the deeper of (block_k, block_n)
    first; every candidate still divides the padded dim because padded
    dims are 128-multiples and the shrink path halves toward 128.

    ``weight_format`` re-resolves the budget for quantized packs: int8
    tiles stream 4x and ternary 16x fewer weight bytes, so block
    triples that clamp at fp32 can stand at reduced precision.
    ``split_k`` sizes the decode lane's fp32 partials slab into the
    same budget (the combine epilogue holds every slice's partial for
    one output tile).  ``sparse_groups > 0`` budgets the sparse-ternary
    walk instead of the dense K stream (the kernel's K step is pinned
    at ``GROUP_K`` regardless of block_k); ``sparse_n`` is the logical
    N the occupancy matrix spans, so its per-panel width is re-derived
    as the shrink loop narrows block_n."""
    dt = jnp.dtype(dtype)
    clamped = False
    quant = weight_format != "fp32"
    while _kernel.vmem_bytes(
            bm, bn, bk, dt, epilogue=epilogue,
            weight_format=weight_format, split_k=split_k,
            sparse_groups=sparse_groups,
            sparse_panels=(max(1, -(-sparse_n // bn)) if sparse_groups
                           else 0)) > _kernel.VMEM_BUDGET:
        if bk >= bn and bk > 128:
            bk = max(128, bk // 2)
            if quant and bk % 128:
                # quantized tiles must span whole GROUP_K scale groups;
                # 128 always divides the pack-padded K, so it is the
                # one shrink target that keeps both contracts
                bk = 128
        elif bn > 128:
            bn = max(128, bn // 2)
        elif bm > 8:
            bm = max(8, bm // 2)
        else:
            break                      # minimal blocks; nothing left
        clamped = True
    return bm, bn, bk, clamped


def _decode_split_k(n: int, k: int, k_pad: int, *, block_m: int,
                    block_n: int, block_k: int, dtype: str,
                    num_cores: int, weight_format: str,
                    epilogue: EpilogueSpec | None) -> int:
    """Score the decode arm's split-K candidates and pick the winner.

    Scored at the CANONICAL decode M (``DECODE_SPLIT_M_REF``), not the
    operand M: split-K changes the accumulation order, so the slice map
    must be a pure function of (n, k, blocks, format) — generate
    (decode at M = batch) and serve (decode at M = slots) then resolve
    the same split and stay token-for-token bit-identical.  (The block
    triple this screens against is M-independent too: the decode arm
    pins ``block_m = DECODE_BLOCK_M``.)  Candidates must cut the padded
    K into whole ``block_k`` slices (which keeps quantized slices on
    whole GROUP_K scale groups, since quantized block_k is a GROUP_K
    multiple) and must fit the VMEM budget WITH their partials slab at
    the final, post-clamp blocks — the chosen split never re-triggers
    the clamp.  ``k_pad`` is the contraction depth the operand will
    actually have at dispatch (the caller passes the raw ``k`` for an
    unpadded PACK_NONE operand on a shape-agnostic backend)."""
    best = (float("inf"), 1)
    for s in DECODE_SPLIT_K_CANDIDATES:
        if k_pad % s or (k_pad // s) % block_k:
            continue
        if s > 1 and _kernel.vmem_bytes(
                block_m, block_n, block_k, jnp.dtype(dtype),
                epilogue=epilogue, weight_format=weight_format,
                split_k=s) > _kernel.VMEM_BUDGET:
            continue
        p = scheduler.plan(DECODE_SPLIT_M_REF, n, k, block_m=block_m,
                           block_n=block_n, block_k=block_k,
                           num_cores=num_cores, split_k=s)
        # tie-break toward fewer slices (less combine traffic)
        if (p.t_pred, s) < best:
            best = (p.t_pred, s)
    return best[1]


def _resolve(m: int, n: int, k: int, *, dtype: str, backend: str,
             num_cores: int, block_m: int | None, block_n: int | None,
             block_k: int | None, pack: str | None, transposed: bool,
             sharding_key: str, validate: bool,
             epilogue: EpilogueSpec | None = None,
             fused_n_splits: tuple = (),
             weight_format: str = "fp32", decode: bool = False,
             split_k: int | None = None,
             density_bucket: int = -1) -> GemmPlan:
    sparse = density_bucket >= 0
    if sparse:
        if weight_format != "ternary":
            raise ValueError(
                f"density_bucket={density_bucket} marks the sparse-ternary "
                f"arm; it requires weight_format='ternary' "
                f"(got {weight_format!r})")
        if split_k is not None and int(split_k) != 1:
            raise ValueError(
                f"split_k={split_k} is incompatible with the sparse-ternary "
                f"walk (the group-granular grid has no reduction-side "
                f"slices); sparse plans always carry split_k=1")
        # the sparse walk streams one GROUP_K K-group per grid step and
        # combines per-group partials in group order — a split-K cut of
        # that order would change the accumulation tree, so the arm pins
        # split_k=1 at plan time rather than rejecting at dispatch
        split_k = 1
    bm = block_m or min(_kernel.DEFAULT_BLOCK_M, _rnd_up(m, 8))
    if decode and block_m is None:
        # skinny-M specialization: decode row panels are ONE 8-row
        # sublane tile for every decode M (m > 8 spans several row
        # panels) — never the 128-row prefill panel.  Pinning block_m
        # keeps the whole decode block triple, and therefore the
        # split-K choice screened against it, independent of the
        # operand M (the serve == generate parity requirement).
        bm = _kernel.DECODE_BLOCK_M
    if k >= n:                              # lever 1: fine panels
        lever = LEVER_FINE_PANELS
        default_pack = PACK_PERCALL
        bk = block_k or packing.fit_block(k, FINE_BLOCK_K)
        bn = block_n or _fine_block_n(m, n, k, block_m=bm, block_k=bk,
                                      num_cores=num_cores)
    else:                                   # lever 2: pre-pack, deep K
        lever = LEVER_PREPACK
        default_pack = PACK_PREPACKED
        bk = block_k or packing.fit_block(k, _kernel.DEFAULT_BLOCK_K)
        bn = block_n or packing.fit_block(n, _kernel.DEFAULT_BLOCK_N)
    if decode:
        # decode arm: the per-call pack the fine lever tolerates at
        # M = 128 (amortized over the row panel) is ruinous at M <= 8 —
        # decode is weight-bound, so the re-layout must be paid at load
        default_pack = PACK_PREPACKED
    if weight_format != "fp32":
        from repro.quant.formats import _check_fmt
        _check_fmt(weight_format)
        # quantization is a pack-time format: whatever lever the shape
        # resolves to, the weight must have been quantize-packed at load
        if pack is not None and pack != PACK_PREPACKED:
            raise ValueError(
                f"weight_format={weight_format!r} is a pack-time format; "
                f"it requires pack={PACK_PREPACKED!r} (got {pack!r})")
        default_pack = PACK_PREPACKED
    pack = pack or default_pack
    if pack not in (PACK_PREPACKED, PACK_PERCALL, PACK_NONE):
        raise ValueError(f"unknown pack decision {pack!r}")
    # Clamp the blocks FIRST (with an explicit split_k's partials slab
    # in the footprint), then resolve the policy split against the
    # final triple: _decode_split_k only admits candidates that fit the
    # clamped blocks, so the choice never re-triggers the clamp, and an
    # explicit split_k that the clamp made undivisible fails HERE, at
    # plan time — not as a PlanMismatchError at dispatch.
    req = (bm, bn, bk)
    sparse_groups = 0
    if sparse:
        from repro.quant.formats import GROUP_K
        kg = max(1, -(-k // GROUP_K))
        # VMEM-worst-case occupied-group count the bucket still admits
        # (bucket b certifies zero-group fraction >= b/10)
        sparse_groups = max(1, kg - (kg * density_bucket) // 10)
    bm, bn, bk, clamped = _fit_vmem(bm, bn, bk, dtype, epilogue,
                                    weight_format,
                                    1 if split_k is None else int(split_k),
                                    sparse_groups=sparse_groups,
                                    sparse_n=n)
    if clamped:
        _warn_vmem_clamp((m, n, k, dtype, backend, weight_format), req,
                         (bm, bn, bk))
    grid_backend = _backends.get_backend(backend).needs_blocks
    # the contraction depth the operand will ACTUALLY have at dispatch:
    # PACK_NONE on a shape-agnostic backend skips the re-layout, so its
    # K is never block-padded — the slice validation must use raw k or
    # a plan would pass here and reject at execute()
    k_pad = _rnd_up(k, bk) if (pack != PACK_NONE or grid_backend) else k
    if split_k is None:
        # the split lever targets the PANEL-GRID backends: occupancy is
        # a property of the kernel grid, and a shape-agnostic backend
        # (xla) has no reduction-side grid to fill — measured on the
        # CPU host the restructure there is a wash-to-loss
        # (BENCH_decode's lane_splitk context column), so the policy
        # keeps split_k=1 for it.  Explicit split_k= overrides remain
        # available on every backend.
        split_k = (_decode_split_k(n, k, k_pad, block_m=bm, block_n=bn,
                                   block_k=bk, dtype=dtype,
                                   num_cores=num_cores,
                                   weight_format=weight_format,
                                   epilogue=epilogue)
                   if decode and grid_backend else 1)
    split_k = int(split_k)
    if split_k < 1 or k_pad % split_k or (split_k > 1
                                          and (k_pad // split_k) % bk):
        raise ValueError(
            f"split_k={split_k} does not cut the dispatch-time "
            f"K={k_pad} into whole block_k={bk} slices"
            + (" (the VMEM fit clamped the requested blocks to "
               f"{(bm, bn, bk)}; request budget-fitting blocks or a "
               "compatible split)" if clamped else ""))

    weight_density = 1.0
    sparse_index_bytes = 0.0
    if sparse:
        # score the arm at the bucket's midpoint occupied fraction, and
        # charge the occupancy-bitmap + group-offset slab the walk reads
        weight_density = max(0.05, 1.0 - (density_bucket + 0.5) / 10.0)
        nb = max(1, -(-n // bn))
        sparse_index_bytes = float(nb * ((kg + 7) // 8) + 4 * kg)
    sched = scheduler.plan(m, n, k, block_m=bm, block_n=bn, block_k=bk,
                           num_cores=num_cores, split_k=split_k,
                           weight_density=weight_density,
                           sparse_index_bytes=sparse_index_bytes)
    validated = False
    if validate:
        if weight_format != "fp32":
            from repro.quant.kernels import quant_gate
            ok = quant_gate(bm, bn, bk, weight_format, epilogue=epilogue,
                            split_k=split_k, sparse=sparse)
        else:
            ok = _bitexact_gate(bm, bn, bk, epilogue=epilogue,
                                split_k=split_k)
        if not ok:
            raise RuntimeError(
                f"blocks ({bm},{bn},{bk}) failed the bit-exactness gate "
                f"(epilogue={epilogue}, weight_format={weight_format}, "
                f"split_k={split_k}) vs the unfused kernel -> op oracle "
                f"(autotune reject protocol)")
        validated = True
    return GemmPlan(m=m, n=n, k=k, dtype=dtype, backend=backend,
                    block_m=bm, block_n=bn, block_k=bk, pack=pack,
                    lever=lever, t_pred=sched.t_pred,
                    occupancy=sched.occupancy, transposed=transposed,
                    sharding_key=sharding_key, validated=validated,
                    epilogue=epilogue, fused_n_splits=fused_n_splits,
                    vmem_clamped=clamped, weight_format=weight_format,
                    split_k=split_k, decode=decode,
                    density_bucket=density_bucket)


def _rnd_up(x: int, mult: int) -> int:
    return max(mult, ((x + mult - 1) // mult) * mult)


# --------------------------------------- chunked-prefill plan-key buckets
# Static admission widths the continuous-batching pool pads prefill
# chunks to (docs/serving.md).  Plans are keyed on exact M, so a ragged
# stream of chunk tails (1..C rows) would resolve a fresh plan per
# length; padding every chunk to a bucket collapses the whole
# mixed-length request mix onto a handful of stable plan keys — after
# the first admission cycle, ``plan_cache_info().misses`` stops moving.
PREFILL_M_BUCKETS = (8, 16, 32, 64, 128)

# Decode-phase buckets: [slots, 1] decode dispatches at M = slots.  The
# prefill buckets round every M below 8 up to 8, so slot pools of width
# 1, 2 and 4 would alias into ONE plan key and pay padded rows for the
# difference; the decode buckets keep small pools exact (decode is the
# latency-bound phase — padded rows are pure waste there).
DECODE_M_BUCKETS = (1, 2, 4, 8)


def bucket_m(m: int, *, decode: bool = False) -> int:
    """Smallest static chunk bucket holding ``m`` rows.

    ``decode=True`` buckets against ``DECODE_M_BUCKETS`` first, so slot
    pools of width 1..8 each get their own plan key instead of all
    rounding up to the smallest prefill bucket (8) with padded waste.
    Beyond the last bucket: the next multiple of 128, the paper's
    prefill row panel."""
    if m < 1:
        raise ValueError(f"m={m}: need at least one row")
    if decode:
        for b in DECODE_M_BUCKETS:
            if m <= b:
                return b
    for b in PREFILL_M_BUCKETS:
        if m <= b:
            return b
    return _rnd_up(m, 128)


# --------------------------------------------------------- bit-exact gate
_gate_memo: dict[tuple, bool] = {}


def _bitexact_gate(bm: int, bn: int, bk: int, *,
                   epilogue: EpilogueSpec | None = None,
                   reduced_k_blocks: int = 2, seed: int = 0,
                   split_k: int = 1) -> bool:
    """core/autotune's reject protocol for one block triple: interpret-mode
    kernel on a reduced shape with a real K-carry must be BIT-IDENTICAL to
    the blocked oracle.  With an epilogue the oracle is the UNFUSED
    sequence — plain kernel to an fp32 accumulator, then the same jnp
    epilogue ops (``apply_epilogue``) under jit — so the gate covers
    every ``EpilogueSpec``, glu included.  ``split_k > 1`` gates the
    decode lane's split-K kernel against ``ref.gemm_splitk`` — per-slice
    blocked partials combined by the shared fixed-order tree — with the
    reduced K sized so every slice carries a real multi-block K-carry.
    Memoized per (triple, spec, split_k)."""
    key = (bm, bn, bk, epilogue, split_k)
    if key in _gate_memo:
        return _gate_memo[key]
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    glu = epilogue is not None and epilogue.glu is not None
    m_r, k_r = bm, reduced_k_blocks * bk * split_k
    n_r = 2 * bn if glu else bn
    x = jnp.asarray(rng.standard_normal((m_r, k_r)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k_r, n_r)), jnp.float32)
    if split_k > 1:
        def run(bias=None, res=None, spec=None, out_dtype=None):
            return _kernel.panel_gemm_splitk(
                x, w, bias, res, split_k=split_k, block_m=bm, block_n=bn,
                block_k=bk, epilogue=spec, out_dtype=out_dtype,
                interpret=True)

        def oracle_acc():
            return ref.gemm_splitk(x, w, bk, split_k,
                                   out_dtype=jnp.float32)
    else:
        def run(bias=None, res=None, spec=None, out_dtype=None):
            return _kernel.panel_gemm(
                x, w, bias, res, block_m=bm, block_n=bn, block_k=bk,
                epilogue=spec, out_dtype=out_dtype, interpret=True)

        def oracle_acc():
            return ref.gemm_blocked(x, w, bk, out_dtype=jnp.float32)

    if epilogue is None:
        y = run()
        oracle = oracle_acc().astype(x.dtype)
    else:
        n_out = bn if glu else n_r
        bias = (jnp.asarray(rng.standard_normal((n_r,)), jnp.float32)
                if epilogue.bias else None)
        res = (jnp.asarray(rng.standard_normal((m_r, n_out)), jnp.float32)
               if epilogue.residual else None)
        y = run(bias, res, epilogue)
        oracle = jax.jit(
            lambda a, b, r: _kernel.apply_epilogue(
                a, epilogue, bias=b, residual=r).astype(jnp.float32)
        )(oracle_acc(), bias, res)
    ok = bitexact.bit_identical(np.asarray(y), np.asarray(oracle))
    _gate_memo[key] = ok
    return ok


# ------------------------------------------------------------- public API
def _plan_key(m: int, n: int, k: int, *, dtype: Any = jnp.float32,
              backend: str | None = None,
              num_cores: int = DEFAULT_NUM_CORES,
              block_m: int | None = None, block_n: int | None = None,
              block_k: int | None = None, pack: str | None = None,
              transposed: bool = False, sharding: Any = None,
              validate: bool = False,
              epilogue: EpilogueSpec | None = None,
              fused_n_splits: tuple = (), weight_format: str = "fp32",
              decode: bool = False,
              split_k: int | None = None,
              density_bucket: int = -1) -> tuple:
    """The normalized in-memory cache key for a ``plan()`` request
    (``validate`` at index ``_KEY_VALIDATE_IDX``; the persistent store
    key is this tuple minus that element — see :func:`store_key`).
    ``density_bucket`` is appended LAST so the validate slice below and
    every persisted schema-v1 store key prefix stay position-stable."""
    backend = _backends.resolve_backend(backend)
    dtype = _dtype_name(dtype)
    skey = _sharding_key(sharding)
    if epilogue is not None and epilogue.is_noop:
        epilogue = None
    fused_n_splits = tuple(int(s) for s in fused_n_splits)
    return (int(m), int(n), int(k), dtype, backend, num_cores, block_m,
            block_n, block_k, pack, bool(transposed), skey, bool(validate),
            epilogue, fused_n_splits, weight_format, bool(decode), split_k,
            int(density_bucket))


_KEY_VALIDATE_IDX = 12

# Plan-resolution fault hook (chaos testing): ``repro.runtime.faults``
# installs its ``maybe_fire`` here at the first ``use_faults`` entry —
# a hook global rather than an import because the gemm layer must not
# import ``repro.runtime`` at module level.  Called on the plan-cache
# miss path, before the store lookup / analytic resolve; when it
# raises, the in-flight dedup below releases the key so a retrying
# caller resolves cleanly.
_FAULT_HOOK = None


def store_key(m: int, n: int, k: int, **kw) -> str:
    """The persistent-store key for a policy request: the normalized
    cache key minus ``validate`` (a validated entry serves both), as a
    deterministic string.  Same keyword surface as :func:`plan` (minus
    ``validate``); the measured autotuner commits winners under the key
    the later policy-position request (no block overrides) will ask."""
    kw.pop("validate", None)
    key = _plan_key(m, n, k, **kw)
    return repr(key[:_KEY_VALIDATE_IDX] + key[_KEY_VALIDATE_IDX + 1:])


def _store_key_of(cache_key: tuple) -> str:
    return repr(cache_key[:_KEY_VALIDATE_IDX]
                + cache_key[_KEY_VALIDATE_IDX + 1:])


def plan(m: int, n: int, k: int, *, dtype: Any = jnp.float32,
         backend: str | None = None, num_cores: int = DEFAULT_NUM_CORES,
         block_m: int | None = None, block_n: int | None = None,
         block_k: int | None = None, pack: str | None = None,
         transposed: bool = False, sharding: Any = None,
         validate: bool = False, epilogue: EpilogueSpec | None = None,
         fused_n_splits: tuple = (),
         weight_format: str = "fp32", decode: bool | None = None,
         split_k: int | None = None,
         density_bucket: int = -1) -> GemmPlan:
    """Resolve (and cache) the dispatch plan for a ``[m,k] @ [k,n]`` GEMM.

    ``backend=None`` takes the current default (``use_backend`` scope or
    the process default — never the removed ``REPRO_GEMM_IMPL`` env
    var).  Explicit ``block_*`` / ``pack`` override the policy
    (benchmark sweeps, baseline paths); ``validate=True`` runs the
    autotune bit-exactness gate on the resolved blocks (and
    ``epilogue`` / ``split_k``, if any) before the plan is issued.
    ``epilogue`` / ``fused_n_splits`` / ``weight_format`` are
    plan-keyed: fused, quantized and plain plans for one shape are
    distinct cache entries.  ``weight_format`` other than ``"fp32"``
    marks a quantized pack-time format (``repro.quant``): the VMEM fit
    uses its bytes-per-element and execute() dispatches the backend's
    dequant-fused run.

    ``decode=None`` reads the ambient :func:`decode_lane` scope (the
    serving engine's decode traces); ``True``/``False`` pin the arm
    explicitly.  Decode plans are plan-keyed separately and take the
    decode policy arm: skinny block_m, forced prepack, and ``split_k``
    resolved by :func:`_decode_split_k` unless given explicitly.

    ``density_bucket >= 0`` resolves the sparse-ternary arm for a
    ``SparseTernaryPackedWeight`` (``weight_format='ternary'`` only):
    the scheduler scores the occupied-group fraction and the index-slab
    overhead, the VMEM fit budgets the group-granular walk, ``split_k``
    is pinned at 1, and the bucket is plan-keyed so sparse and dense
    ternary plans for one shape never alias.

    When a plan store is active (``gemm.use_plan_store`` scope or the
    process default), an in-memory miss consults the store before
    ``_resolve``: a hit adopts the stored plan — skipping the analytic
    policy and, for entries committed through the bit-exactness gate,
    the gate itself — and every freshly resolved plan is recorded back
    into the store (persist with ``store.save()``).  Concurrent callers
    missing on one key share a single resolution (per-key in-flight
    dedup): the gate and the miss are paid exactly once.
    """
    global _hits, _misses
    if decode is None:
        decode = in_decode_lane()
    key = _plan_key(m, n, k, dtype=dtype, backend=backend,
                    num_cores=num_cores, block_m=block_m, block_n=block_n,
                    block_k=block_k, pack=pack, transposed=transposed,
                    sharding=sharding, validate=validate, epilogue=epilogue,
                    fused_n_splits=fused_n_splits,
                    weight_format=weight_format, decode=decode,
                    split_k=split_k, density_bucket=density_bucket)
    (m, n, k, dtype, backend, num_cores, block_m, block_n, block_k, pack,
     transposed, skey, validate, epilogue, fused_n_splits, weight_format,
     decode, split_k, density_bucket) = key
    while True:
        with _cache_lock:
            hit = _cache.get(key)
            if hit is not None:
                _hits += 1
                _cache.move_to_end(key)
                return hit
            ev = _inflight.get(key)
            if ev is None:
                ev = _inflight[key] = threading.Event()
                _misses += 1
                break                       # we own this resolution
        ev.wait()                           # another thread resolves it;
        # loop: adopt its cached plan (a hit), or — if it failed —
        # become the owner ourselves
    try:
        # the plan-cache MISS path only: hits return above without a
        # span, so plan_resolve events in a trace are exactly the plan
        # churn the serving tests watch via plan_cache_info().misses
        with _spans.span("plan_resolve", m=m, n=n, k=k, dtype=dtype,
                         backend=backend, decode=bool(decode)) as span:
            if _FAULT_HOOK is not None:
                _FAULT_HOOK("plan_resolve", m=m, n=n, k=k)
            store = _plan_store.active_plan_store()
            p = None
            if store is not None:
                sp = store.lookup(_store_key_of(key))
                if (sp is not None and sp.shape == (m, n, k)
                        and (not validate or sp.validated)):
                    p = sp
                    span.set(source="plan_store")
            if p is None:
                p = _resolve(m, n, k, dtype=dtype, backend=backend,
                             num_cores=num_cores, block_m=block_m,
                             block_n=block_n, block_k=block_k, pack=pack,
                             transposed=transposed, sharding_key=skey,
                             validate=validate, epilogue=epilogue,
                             fused_n_splits=fused_n_splits,
                             weight_format=weight_format, decode=decode,
                             split_k=split_k,
                             density_bucket=density_bucket)
                span.set(source="policy")
                if store is not None:
                    store.put(_store_key_of(key), p)
            span.set(lever=p.lever, split_k=p.split_k,
                     blocks=f"{p.block_m}x{p.block_n}x{p.block_k}")
            _cache_insert(key, p)
            return p
    finally:
        with _cache_lock:
            _inflight.pop(key, None)
        ev.set()


def _packed_sharding(pw: packing.PackedWeight):
    """The placement a packed weight actually carries, for the plan key.

    Fixes the plan_for_packed aliasing bug: packs placed with distinct
    ``NamedSharding``s used to collapse onto one ``sharding_key=""`` plan
    entry.  Tracers (plan resolution happens at trace time inside jit)
    and plain single-device arrays key as None — the placement-neutral
    default — so cache behavior is unchanged for unsharded runs.
    """
    try:
        s = pw.data.sharding
    except Exception:
        return None
    return s if isinstance(s, jax.sharding.NamedSharding) else None


def plan_for_packed(m: int, pw: packing.PackedWeight, *,
                    backend: str | None = None,
                    num_cores: int = DEFAULT_NUM_CORES,
                    validate: bool = False,
                    epilogue: EpilogueSpec | None = None,
                    decode: bool | None = None) -> GemmPlan:
    """Plan for a weight already packed at model load: the block decision
    was made when the pack happened; the plan adopts it (and still records
    which lever the policy assigns the shape).  A fused pack's static
    split map, a quantized pack's format (``QuantizedPackedWeight.fmt``
    -> ``weight_format``), and the requested ``epilogue`` ride onto the
    plan.  A quantized pack's ``dtype`` keys as the fp32 the dequant
    produces (codes are not an operand dtype).  ``decode=None`` reads
    the ambient :func:`decode_lane` scope (as :func:`plan` does).
    A ``SparseTernaryPackedWeight`` carries its ``density_bucket`` onto
    the plan, selecting the sparse arm."""
    fmt = getattr(pw, "fmt", "fp32")
    dtype = "float32" if fmt != "fp32" else pw.dtype
    return plan(m, pw.n, pw.k, dtype=dtype, backend=backend,
                num_cores=num_cores, block_n=pw.block_n,
                block_k=pw.block_k, pack=PACK_PREPACKED, validate=validate,
                sharding=_packed_sharding(pw), epilogue=epilogue,
                fused_n_splits=pw.n_splits, weight_format=fmt,
                decode=decode,
                density_bucket=getattr(pw, "density_bucket", -1))


def pack_blocks(n: int, k: int, *, m_hint: int = 128,
                block_n: int | None = None, block_k: int | None = None,
                num_cores: int = DEFAULT_NUM_CORES,
                epilogue: EpilogueSpec | None = None,
                weight_format: str = "fp32") -> tuple[int, int]:
    """The load-time pack decision, policy-resolved: (block_n, block_k)
    for a [k, n] weight.  ``m_hint`` is the serving M the plan targets
    (the paper's S = 128 prefill row panel).  ``epilogue`` lets a fused
    pack reserve VMEM for its store-phase footprint (a glu epilogue
    doubles the weight/accumulator tiles), and ``weight_format`` sizes
    the streamed tile for quantized packs, so the blocks the pack adopts
    already fit the budget the execute-time plan will enforce."""
    p = plan(m_hint, n, k, block_n=block_n, block_k=block_k,
             num_cores=num_cores, epilogue=epilogue,
             weight_format=weight_format)
    return p.block_n, p.block_k


def sparse_threshold(m: int = 128, n: int = 4096, k: int = 4096, *,
                     num_cores: int = DEFAULT_NUM_CORES) -> float:
    """Analytic break-even zero-group fraction for the sparse arm.

    Sweeps the scheduler model: the dense ternary plan at the policy's
    deep-K blocks vs the sparse walk (``block_k = GROUP_K``, weight
    traffic and compute scaled by the occupied fraction, plus the
    occupancy-bitmap + group-offset slab) — returning the smallest
    zero-group fraction (in hundredths) at which the sparse arm's
    predicted time first wins.  The model's break-even is small (the
    index slab is a few KB against MBs of weight traffic; the real cost
    is the 16x deeper grid the GROUP_K step forces, carried by the
    ``GRID_STEP_OVERHEAD`` term), so the shipped pack-time trigger
    ``quant.SPARSE_DENSITY_THRESHOLD`` (0.3) sits deliberately ABOVE
    it: packs only cross to the compressed layout when the win also
    survives measured launch overheads and the host dot kernels'
    non-monotone-in-K behavior (see the constant's comment), not just
    the napkin model.
    """
    from repro.quant.formats import GROUP_K
    bm = min(_kernel.DEFAULT_BLOCK_M, _rnd_up(m, 8))
    bn = packing.fit_block(n, _kernel.DEFAULT_BLOCK_N)
    bk = packing.fit_block(k, _kernel.DEFAULT_BLOCK_K)
    kg = max(1, -(-k // GROUP_K))
    idx = float(max(1, -(-n // bn)) * ((kg + 7) // 8) + 4 * kg)
    dense = scheduler.plan(m, n, k, block_m=bm, block_n=bn, block_k=bk,
                           num_cores=num_cores).t_pred
    for i in range(1, 100):
        gs = i / 100.0
        t = scheduler.plan(m, n, k, block_m=bm, block_n=bn,
                           block_k=GROUP_K, num_cores=num_cores,
                           weight_density=1.0 - gs,
                           sparse_index_bytes=idx).t_pred
        if t < dense:
            return gs
    return 1.0


def policy_table(shapes, *, m: int | None = None,
                 num_cores: int = DEFAULT_NUM_CORES) -> list[dict]:
    """Lever resolution for a set of ``(m, n, k)`` (or ``(n, k)`` with
    ``m=``) shapes — the paper's twelve-shape table, as data."""
    rows = []
    for s in shapes:
        if len(s) == 2 and m is None:
            raise ValueError(
                f"2-tuple shape {s} needs the m= argument (the row count "
                f"the plans target), e.g. policy_table(shapes, m=128)")
        mm, n, k = (m, *s) if len(s) == 2 else s
        p = plan(mm, n, k, num_cores=num_cores)
        rows.append({
            "M": p.m, "N": p.n, "K": p.k, "lever": p.lever,
            "prepack": p.prepack, "block_n": p.block_n,
            "block_k": p.block_k, "panels": p.grid[0] * p.grid[1],
            "occupancy": round(p.occupancy, 3),
            "pred_ms": round(p.t_pred * 1e3, 4),
        })
    return rows
