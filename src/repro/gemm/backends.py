"""Backend registry — one extension point instead of a process global.

The seed steered its three ad-hoc entry points with a module-level
``_DEFAULT_IMPL`` read from ``REPRO_GEMM_IMPL`` at import time; nothing
outside that module could add a backend or scope a choice to one engine.
Here backends are first-class registry entries:

  * ``xla``       — one shape-agnostic dot (the Accelerate-dispatch
                    analogue and the CPU-runtime default).  Ignores the
                    plan's blocking (``needs_blocks=False``), so execute()
                    skips the block padding for it.
  * ``pallas``    — the compiled panel kernel (TPU deployment path).
  * ``interpret`` — the same kernel through the Pallas interpreter:
                    kernel-validation mode, bit-identical to
                    ``kernels/ref.gemm_blocked`` by construction.

``register_backend`` is the hook extensions use (the quant subsystem's
dequant-fused runs ride the same registry as ``run_quant`` entries;
batched GEMM / remote offload are future extensions).  The
``REPRO_GEMM_IMPL`` env var is REMOVED along with the legacy
``core/panel_gemm`` shims — this surface takes ``backend=`` explicitly
or via ``use_backend(...)``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from repro.kernels import panel_gemm as _kernel

# run(x_p, w_p, *, block_m, block_n, block_k, out_dtype) -> y
RunFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class Backend:
    """``run`` executes fp-dtype operands.  ``run_quant`` (optional) is
    the dequant-fused entry for quantized packs —
    ``run_quant(x_p, codes, scales, *, weight_format, block_m, block_n,
    block_k, out_dtype, [epilogue kwargs])`` — dispatched by execute()
    only when the plan's ``weight_format`` is quantized.  A backend
    without it rejects quantized plans (registered extensions predating
    the quant subsystem keep working for fp32 plans unchanged)."""
    name: str
    run: RunFn
    needs_blocks: bool = True    # False: shape-agnostic, skip block padding
    description: str = ""
    run_quant: RunFn | None = None


_REGISTRY: dict[str, Backend] = {}
_LOCK = threading.Lock()
_STATE = threading.local()       # per-thread default-backend override stack


class UnknownBackendError(KeyError):
    pass


def register_backend(name: str, run: RunFn, *, needs_blocks: bool = True,
                     description: str = "",
                     run_quant: RunFn | None = None,
                     overwrite: bool = False) -> Backend:
    """Register a GEMM backend under ``name`` (the extension hook)."""
    with _LOCK:
        if name in _REGISTRY and not overwrite:
            raise ValueError(f"backend {name!r} already registered; "
                             f"pass overwrite=True to replace it")
        b = Backend(name=name, run=run, needs_blocks=needs_blocks,
                    description=description, run_quant=run_quant)
        _REGISTRY[name] = b
        return b


def unregister_backend(name: str) -> None:
    with _LOCK:
        if name in _BUILTIN:
            raise ValueError(f"cannot unregister builtin backend {name!r}")
        _REGISTRY.pop(name, None)


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownBackendError(
            f"unknown GEMM backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def list_backends() -> list[str]:
    return sorted(_REGISTRY)


# ----------------------------------------------------------- default choice
_FALLBACK_DEFAULT = "xla"    # CPU smoke tests / dry-runs; TPU deploys pallas


def default_backend() -> str:
    """The backend a plan gets when none is requested (innermost
    ``use_backend`` scope wins; else the process default)."""
    stack = getattr(_STATE, "stack", None)
    if stack:
        return stack[-1]
    return _FALLBACK_DEFAULT


def resolve_backend(name: str | None) -> str:
    name = name or default_backend()
    get_backend(name)            # validate early, at plan time
    return name


@contextlib.contextmanager
def use_backend(name: str | None) -> Iterator[None]:
    """Scope the default backend (e.g. one Engine tracing its steps).
    ``None`` is a no-op scope, so call sites can thread an optional."""
    if name is None:
        yield
        return
    get_backend(name)
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(name)
    try:
        yield
    finally:
        stack.pop()


# ------------------------------------------------------------ builtin runs
# Epilogue kwargs (epilogue=, bias=, residual=) are passed ONLY when the
# plan carries an EpilogueSpec, and split_k= only when the plan's
# split_k > 1, so registered backends that predate either surface keep
# working for plain plans unchanged.
def _xla_splitk_acc(x_p, w_p, split_k):
    """Slice dots + the shared fixed-order combine tree: the xla form of
    the decode lane's split-K accumulation.  Deterministic per backend;
    within-slice accumulation is XLA's dot (allclose, not bitwise, to
    the kernel's blocked partials — the standing xla-vs-kernel
    contract), while the combine order is the shared tree, so the
    result is a pure function of the slice-dot values."""
    k = x_p.shape[-1]
    ks = k // split_k
    parts = [jnp.dot(x_p[:, s * ks:(s + 1) * ks],
                     w_p[s * ks:(s + 1) * ks, :],
                     preferred_element_type=jnp.float32)
             for s in range(split_k)]
    return _kernel.splitk_combine(parts)


def _run_xla(x_p, w_p, *, block_m, block_n, block_k, out_dtype,
             epilogue=None, bias=None, residual=None, split_k=1):
    del block_m, block_n, block_k
    if split_k > 1:
        acc = _xla_splitk_acc(x_p, w_p, split_k)
    else:
        acc = jnp.dot(x_p, w_p, preferred_element_type=jnp.float32)
    if epilogue is not None:
        # same jnp ops as the kernel store phase, on the fp32 result —
        # the "fusion" here is XLA's own elementwise fusion, but the
        # numerics contract (fp32 epilogue, single final cast) is
        # identical to the Pallas path's
        acc = _kernel.apply_epilogue(acc, epilogue, bias=bias,
                                     residual=residual)
    return acc.astype(out_dtype or x_p.dtype)


def _run_pallas(x_p, w_p, *, block_m, block_n, block_k, out_dtype,
                epilogue=None, bias=None, residual=None, split_k=1,
                interpret=False):
    if split_k > 1:
        return _kernel.panel_gemm_splitk(
            x_p, w_p, bias, residual, split_k=split_k, block_m=block_m,
            block_n=block_n, block_k=block_k, out_dtype=out_dtype,
            epilogue=epilogue, interpret=interpret)
    return _kernel.panel_gemm(x_p, w_p, bias, residual, block_m=block_m,
                              block_n=block_n, block_k=block_k,
                              out_dtype=out_dtype, epilogue=epilogue,
                              interpret=interpret)


def _run_interpret(x_p, w_p, **kw):
    return _run_pallas(x_p, w_p, interpret=True, **kw)


# Dequant-fused runs (repro.quant): same trio, streaming codes + scales.
# The xla run dequantizes inside ONE jitted computation, so XLA fuses
# the cast/scale into the dot's operand path — the dequant-THEN-sgemm
# baseline (benchmarks/table8_quant.py) instead materializes the fp32
# weight as a separate dispatch, which is exactly the round-trip the
# fused path deletes.
def _run_quant_xla(x_p, codes, scales, *, weight_format, block_m, block_n,
                   block_k, out_dtype, epilogue=None, bias=None,
                   residual=None, split_k=1, sparse_layout=None):
    del block_m, block_n, block_k
    from repro.quant import formats as _F
    if sparse_layout is not None:
        # compressed-ternary lane: gather the activation columns of the
        # surviving K-groups (static slices — the group union is pack
        # metadata) and dot against the compacted dequantized panels.
        # The dense lane materializes the FULL K x N fp32 dequant; this
        # one materializes only the occupied fraction — the weight-byte
        # (and dequant-flop) cut IS the sparse win on this backend.
        assert split_k == 1, "sparse plans run split_k=1 (policy-forced)"
        from repro.quant.formats import GROUP_K
        k_groups, group_index, _bitmap, _bn = sparse_layout
        if not group_index:          # fully-zero weight
            acc = jnp.zeros((x_p.shape[0], codes.shape[-1]), jnp.float32)
        else:
            if len(group_index) == k_groups:
                x_c = x_p            # degenerate union: nothing removed
            else:
                x_c = jnp.concatenate(
                    [x_p[:, g * GROUP_K:(g + 1) * GROUP_K]
                     for g in group_index], axis=1)
            w = _F.dequantize_padded(codes, scales, weight_format)
            w = jax.lax.optimization_barrier(w)
            acc = jnp.dot(x_c, w, preferred_element_type=jnp.float32)
        if epilogue is not None:
            acc = _kernel.apply_epilogue(acc, epilogue, bias=bias,
                                         residual=residual)
        return acc.astype(out_dtype or x_p.dtype)
    if split_k > 1:
        # per-slice dequant + slice dots: each K slice's dequantized
        # panel is materialized (barriered, same rationale as below) and
        # consumed immediately, then the shared combine tree sums the
        # fp32 partials in fixed order
        kdiv = 4 if weight_format == "ternary" else 1
        from repro.quant.formats import GROUP_K
        k = x_p.shape[-1]
        ks = k // split_k
        parts = []
        for s in range(split_k):
            w_s = _F.dequantize_padded(
                codes[s * ks // kdiv:(s + 1) * ks // kdiv],
                scales[s * ks // GROUP_K:(s + 1) * ks // GROUP_K],
                weight_format)
            w_s = jax.lax.optimization_barrier(w_s)
            parts.append(jnp.dot(x_p[:, s * ks:(s + 1) * ks], w_s,
                                 preferred_element_type=jnp.float32))
        acc = _kernel.splitk_combine(parts)
    else:
        w = _F.dequantize_padded(codes, scales, weight_format)
        # keep the dequantized panels a materialized dot operand: letting
        # XLA:CPU fuse the convert/scale INTO the dot knocks it off the
        # fast library-dot path (measured 20-30% slower at wide N); the
        # barrier costs nothing numerically (values are identical bitwise)
        w = jax.lax.optimization_barrier(w)
        acc = jnp.dot(x_p, w, preferred_element_type=jnp.float32)
    if epilogue is not None:
        acc = _kernel.apply_epilogue(acc, epilogue, bias=bias,
                                     residual=residual)
    return acc.astype(out_dtype or x_p.dtype)


def _run_quant_pallas(x_p, codes, scales, *, weight_format, block_m,
                      block_n, block_k, out_dtype, epilogue=None,
                      bias=None, residual=None, split_k=1,
                      interpret=False, sparse_layout=None):
    from repro.quant import kernels as _qk
    if sparse_layout is not None:
        assert split_k == 1, "sparse plans run split_k=1 (policy-forced)"
        return _qk.sparse_quant_panel_gemm(
            x_p, codes, scales, bias, residual,
            sparse_layout=sparse_layout, block_m=block_m,
            block_n=block_n, out_dtype=out_dtype, epilogue=epilogue,
            interpret=interpret)
    if split_k > 1:
        return _qk.quant_panel_gemm_splitk(
            x_p, codes, scales, bias, residual,
            weight_format=weight_format, split_k=split_k,
            block_m=block_m, block_n=block_n, block_k=block_k,
            out_dtype=out_dtype, epilogue=epilogue, interpret=interpret)
    return _qk.quant_panel_gemm(x_p, codes, scales, bias, residual,
                                weight_format=weight_format,
                                block_m=block_m, block_n=block_n,
                                block_k=block_k, out_dtype=out_dtype,
                                epilogue=epilogue, interpret=interpret)


def _run_quant_interpret(x_p, codes, scales, **kw):
    return _run_quant_pallas(x_p, codes, scales, interpret=True, **kw)


register_backend("xla", _run_xla, needs_blocks=False,
                 description="shape-agnostic XLA dot (Accelerate analogue)",
                 run_quant=_run_quant_xla)
register_backend("pallas", _run_pallas,
                 description="compiled Pallas panel kernel (TPU deploy)",
                 run_quant=_run_quant_pallas)
register_backend("interpret", _run_interpret,
                 description="Pallas interpreter (kernel validation)",
                 run_quant=_run_quant_interpret)
_BUILTIN = frozenset(_REGISTRY)
