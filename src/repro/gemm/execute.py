"""``execute(plan, x, w)`` — run a planned GEMM; all per-call work that a
plan can remove has been removed at plan/pack time.

The weight operand may be:

  * a ``PackedWeight`` (paid once at model load — the plan's ``prepack``
    lever): per call only M-padding of the activations remains;
  * a raw array (``[K, N]``, or ``[N, K]`` when the plan was built with
    ``transposed=True``): the transpose+pad runs inside the call — the
    honest cblas/BNNSMatMul baseline the benchmarks compare against.

Numerics contract (the paper's discipline): for a given block triple the
result is bit-identical across packed / per-call operands and across the
``pallas`` / ``interpret`` backends, and bit-identical to
``kernels/ref.gemm_blocked`` at the plan's ``block_k`` — asserted by
``tests/test_gemm_api.py`` and gateable at plan time via
``plan(..., validate=True)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.gemm import backends as _backends
from repro.gemm.plan import GemmPlan, PACK_NONE
from repro.gemm.policy import _bitexact_gate


class PlanMismatchError(ValueError):
    pass


def lead_m(x: jax.Array) -> int:
    """Row count of ``x[..., K]`` flattened to 2-D — the M a plan for
    this operand must carry."""
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return m


def _check(cond: bool, msg: str):
    if not cond:
        raise PlanMismatchError(msg)


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _pad_cols(x: jax.Array, to: int) -> jax.Array:
    pad = to - x.shape[1]
    return jnp.pad(x, ((0, 0), (0, pad))) if pad else x


def execute(p: GemmPlan, x: jax.Array, w, *, out_dtype=None) -> jax.Array:
    """y[..., N] = x[..., K] @ w, dispatched per ``p`` (see module doc).

    Shapes and pack blocks are checked against the plan; ``p.dtype`` is
    cache-keying metadata, NOT an executed constraint — mixed-dtype
    operands (bf16 activations against fp32-packed weights in the
    dry-run, and vice versa) are legitimate and promote as jnp.dot
    would.  The bit-exactness gate (``validate_plan``) attests the
    block-order accumulation discipline, which holds per operand dtype.
    """
    backend = _backends.get_backend(p.backend)
    lead = x.shape[:-1]
    _check(x.shape[-1] == p.k,
           f"operand K={x.shape[-1]} vs plan K={p.k} ({p.describe()})")
    x2 = x.reshape(-1, p.k)
    m = x2.shape[0]
    _check(m == p.m, f"operand M={m} vs plan M={p.m}; plans are "
                     f"shape-resolved — re-plan for this batch")

    if isinstance(w, packing.PackedWeight):
        _check((w.k, w.n) == (p.k, p.n),
               f"packed weight {w.shape} vs plan ({p.k},{p.n})")
        _check((w.block_n, w.block_k) == (p.block_n, p.block_k),
               f"pack blocks ({w.block_n},{w.block_k}) vs plan "
               f"({p.block_n},{p.block_k}); pack with pack_for_plan()")
        w_p = w.data
    else:
        ww = w.T if p.transposed else w
        _check(ww.shape == (p.k, p.n),
               f"weight {tuple(ww.shape)} vs plan ({p.k},{p.n})")
        # The pack decision is the PLAN's, not the backend's: the percall
        # baseline pays its transpose+pad even when the compute loop runs
        # through the shape-agnostic xla dot (table3/table6 protocol).
        # PACK_NONE (the raw-dot analogue) skips it — unless the backend
        # is a panel kernel that physically needs the blocked layout.
        if p.pack != PACK_NONE or backend.needs_blocks:
            w_p = packing.pack_percall(ww, transposed=False,
                                       block_n=p.block_n,
                                       block_k=p.block_k)
        else:
            w_p = ww

    if w_p.shape[0] != p.k:          # weight K was pack-padded: pad x too
        x2 = _pad_cols(x2, w_p.shape[0])
    if backend.needs_blocks:
        x2 = _pad_rows(x2, p.block_m)

    y = backend.run(x2, w_p, block_m=p.block_m, block_n=p.block_n,
                    block_k=p.block_k, out_dtype=out_dtype)
    return y[:m, :p.n].reshape(*lead, p.n)


def pack_for_plan(p: GemmPlan, w: jax.Array, *, transposed: bool | None = None,
                  dtype=None, sharding=None) -> packing.PackedWeight:
    """Pack ``w`` once with exactly the blocking the plan will execute
    (the load-time side of the ``prepack`` lever)."""
    return packing.pack(
        w, transposed=p.transposed if transposed is None else transposed,
        block_n=p.block_n, block_k=p.block_k, dtype=dtype,
        sharding=sharding)


def validate_plan(p: GemmPlan) -> bool:
    """Run (memoized) the autotune bit-exactness gate on the plan's block
    triple: interpret-mode kernel vs ``kernels/ref.gemm_blocked``."""
    return _bitexact_gate(p.block_m, p.block_n, p.block_k)
