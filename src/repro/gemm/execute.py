"""``execute(plan, x, w)`` — run a planned GEMM; all per-call work that a
plan can remove has been removed at plan/pack time.

The weight operand may be:

  * a ``PackedWeight`` (paid once at model load — the plan's ``prepack``
    lever): per call only M-padding of the activations remains;
  * a ``QuantizedPackedWeight`` (repro.quant — quantized AND packed at
    load): the plan carries its ``weight_format`` and the backend's
    dequant-fused ``run_quant`` entry streams codes + scales;
  * a raw array (``[K, N]``, or ``[N, K]`` when the plan was built with
    ``transposed=True``): the transpose+pad runs inside the call — the
    honest cblas/BNNSMatMul baseline the benchmarks compare against.

Numerics contract (the paper's discipline): for a given block triple the
result is bit-identical across packed / per-call operands and across the
``pallas`` / ``interpret`` backends, and bit-identical to
``kernels/ref.gemm_blocked`` at the plan's ``block_k`` — asserted by
``tests/test_gemm_api.py`` and gateable at plan time via
``plan(..., validate=True)``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.gemm import backends as _backends
from repro.gemm.plan import GemmPlan, PACK_NONE
from repro.gemm.policy import _bitexact_gate
from repro.kernels.panel_gemm import EpilogueSpec  # noqa: F401 (re-export)
from repro.obs import recorder as _flight
from repro.quant.formats import (QuantizedPackedWeight,
                                 SparseTernaryPackedWeight)


class PlanMismatchError(ValueError):
    pass


def lead_m(x: jax.Array) -> int:
    """Row count of ``x[..., K]`` flattened to 2-D — the M a plan for
    this operand must carry."""
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return m


def _check(cond: bool, msg: str):
    if not cond:
        raise PlanMismatchError(msg)


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _pad_cols(x: jax.Array, to: int) -> jax.Array:
    pad = to - x.shape[1]
    return jnp.pad(x, ((0, 0), (0, pad))) if pad else x


def execute(p: GemmPlan, x: jax.Array, w, *, bias=None, residual=None,
            out_dtype=None) -> jax.Array:
    """y[..., N_out] = epilogue(x[..., K] @ w), dispatched per ``p``.

    Observability (repro.obs): when a flight recorder or manifest scope
    is active, the dispatch is recorded — eager calls into the
    recorder's ring (wall-timed; fenced with ``block_until_ready`` when
    the recorder opted in, since async dispatch otherwise times the
    enqueue), traced calls (operands are jit tracers — every serving
    step) into the trace-time manifest of the enclosing
    ``obs.manifest_scope``.  The inactive path is one module-level int
    check; the active-path branch below never touches the math.

    Shapes and pack blocks are checked against the plan; ``p.dtype`` is
    cache-keying metadata, NOT an executed constraint — mixed-dtype
    operands (bf16 activations against fp32-packed weights in the
    dry-run, and vice versa) are legitimate and promote as jnp.dot
    would.  The bit-exactness gate (``validate_plan``) attests the
    block-order accumulation discipline, which holds per operand dtype.

    Epilogue operands: ``bias`` [N] and ``residual`` [..., N_out] must be
    supplied exactly when the plan's ``EpilogueSpec`` declares them; both
    are cast to fp32 here (the epilogue contract runs on the fp32
    accumulator).  A plan with ``fused_n_splits`` returns the full
    concatenated output — slice per part with :func:`split_fused` — except
    under a glu epilogue, where the halves are combined in the store step
    and only the single ``p.n_out``-wide result comes back.
    """
    if not _flight._HOT:
        return _execute_impl(p, x, w, bias=bias, residual=residual,
                             out_dtype=out_dtype)
    rec = _flight.active_recorder()
    t0 = time.perf_counter()
    y = _execute_impl(p, x, w, bias=bias, residual=residual,
                      out_dtype=out_dtype)
    if isinstance(y, jax.core.Tracer):
        # jit-trace time: no wall clock exists per dispatch — register
        # the plan into the open manifest scope instead (once per
        # compilation; obs.report apportions tick time at export)
        _flight.on_traced(p, lead_m(x))
    elif rec is not None:
        fenced = rec.fence
        if fenced:
            jax.block_until_ready(y)
        rec.record(p, lead_m(x), wall_s=time.perf_counter() - t0,
                   fenced=fenced)
    return y


def _execute_impl(p: GemmPlan, x: jax.Array, w, *, bias=None,
                  residual=None, out_dtype=None) -> jax.Array:
    backend = _backends.get_backend(p.backend)
    spec = p.epilogue
    _check((bias is not None) == bool(spec is not None and spec.bias),
           f"bias operand vs plan epilogue {spec} ({p.describe()})")
    _check((residual is not None) == bool(spec is not None
                                          and spec.residual),
           f"residual operand vs plan epilogue {spec} ({p.describe()})")
    lead = x.shape[:-1]
    _check(x.shape[-1] == p.k,
           f"operand K={x.shape[-1]} vs plan K={p.k} ({p.describe()})")
    x2 = x.reshape(-1, p.k)
    m = x2.shape[0]
    _check(m == p.m, f"operand M={m} vs plan M={p.m}; plans are "
                     f"shape-resolved — re-plan for this batch")

    quant = isinstance(w, QuantizedPackedWeight)
    _check(quant == p.quantized,
           f"operand {'is' if quant else 'is not'} a quantized pack but "
           f"plan weight_format={p.weight_format!r} ({p.describe()}); "
           f"re-plan via plan_for_packed")
    if quant:
        _check(w.fmt == p.weight_format,
               f"pack format {w.fmt!r} vs plan "
               f"weight_format={p.weight_format!r}")
        sparse = isinstance(w, SparseTernaryPackedWeight)
        _check(sparse == p.sparse,
               f"operand {'is' if sparse else 'is not'} a sparse-ternary "
               f"pack but plan density_bucket={p.density_bucket} "
               f"({p.describe()}); re-plan via plan_for_packed")
        if sparse:
            _check(w.density_bucket == p.density_bucket,
                   f"pack density_bucket={w.density_bucket} vs plan "
                   f"density_bucket={p.density_bucket}; the pack was "
                   f"re-quantized since the plan resolved — re-plan")
    if isinstance(w, packing.PackedWeight):
        _check((w.k, w.n) == (p.k, p.n),
               f"packed weight {w.shape} vs plan ({p.k},{p.n})")
        _check((w.block_n, w.block_k) == (p.block_n, p.block_k),
               f"pack blocks ({w.block_n},{w.block_k}) vs plan "
               f"({p.block_n},{p.block_k}); pack with pack_for_plan()")
        _check(w.n_splits == p.fused_n_splits,
               f"pack splits {w.n_splits} vs plan {p.fused_n_splits}")
        w_p = w.data
    else:
        _check(not p.fused_n_splits and not p.glu,
               "fused plans execute against pack_fused weights only "
               "(a raw concat cannot keep the parts block-aligned)")
        ww = w.T if p.transposed else w
        _check(ww.shape == (p.k, p.n),
               f"weight {tuple(ww.shape)} vs plan ({p.k},{p.n})")
        # The pack decision is the PLAN's, not the backend's: the percall
        # baseline pays its transpose+pad even when the compute loop runs
        # through the shape-agnostic xla dot (table3/table6 protocol).
        # PACK_NONE (the raw-dot analogue) skips it — unless the backend
        # is a panel kernel that physically needs the blocked layout.
        if p.pack != PACK_NONE or backend.needs_blocks:
            w_p = packing.pack_percall(ww, transposed=False,
                                       block_n=p.block_n,
                                       block_k=p.block_k)
        else:
            w_p = ww

    # padded geometry: a ternary pack stores four K rows per codes row,
    # so the codes' leading dim is NOT the padded contraction depth
    k_pad = w.k_pad if quant else w_p.shape[0]
    n_pad = w_p.shape[1]
    if k_pad != p.k:                 # weight K was pack-padded: pad x too
        x2 = _pad_cols(x2, k_pad)
    if backend.needs_blocks:
        x2 = _pad_rows(x2, p.block_m)

    out_cols = n_pad // 2 if p.glu else n_pad
    epi_kw = {}
    if p.split_k > 1:
        # decode lane: the K slices must be whole (and, for kernel
        # backends, whole-block) — the policy guarantees this for plans
        # it resolved; explicit split_k overrides are checked here
        _check(k_pad % p.split_k == 0
               and (k_pad // p.split_k) % p.block_k == 0,
               f"split_k={p.split_k} does not cut padded K={k_pad} into "
               f"whole block_k={p.block_k} slices ({p.describe()})")
        epi_kw["split_k"] = p.split_k
    if spec is not None:
        b2 = r2 = None
        if bias is not None:
            if p.fused_n_splits:
                # per-part biases, padded into the pack's column layout
                parts = (list(bias) if isinstance(bias, (tuple, list))
                         else None)
                _check(parts is not None
                       and len(parts) == len(p.fused_n_splits),
                       f"fused plan needs one bias per part "
                       f"{p.fused_n_splits}")
                padded = []
                for b, ni in zip(parts, p.fused_n_splits):
                    b = jnp.asarray(b, jnp.float32).reshape(-1)
                    _check(b.shape[0] == ni,
                           f"bias width {b.shape[0]} vs part width {ni}")
                    padded.append(jnp.pad(b, (0, (-ni) % p.block_n)))
                b2 = jnp.concatenate(padded)
            else:
                b2 = jnp.asarray(bias, jnp.float32).reshape(-1)
                _check(b2.shape[0] == p.n,
                       f"bias width {b2.shape[0]} vs plan N={p.n}")
            b2 = jnp.pad(b2, (0, n_pad - b2.shape[0]))
        if residual is not None:
            r2 = residual.reshape(-1, residual.shape[-1])
            _check(r2.shape == (m, p.n_out),
                   f"residual {tuple(r2.shape)} vs plan ({m},{p.n_out})")
            r2 = _pad_cols(r2.astype(jnp.float32), out_cols)
            if backend.needs_blocks:
                r2 = _pad_rows(r2, p.block_m)
        epi_kw.update(epilogue=spec, bias=b2, residual=r2)

    if quant:
        run_q = backend.run_quant
        _check(run_q is not None,
               f"backend {p.backend!r} has no dequant-fused run "
               f"(register_backend(..., run_quant=)); it cannot execute "
               f"weight_format={p.weight_format!r} plans")
        if isinstance(w, SparseTernaryPackedWeight):
            # static metadata tuple — hashable, so jit-traced dispatch
            # keys the compiled sparse walk per compressed layout
            epi_kw["sparse_layout"] = w.sparse_layout
        y = run_q(x2, w_p, w.scales, weight_format=p.weight_format,
                  block_m=p.block_m, block_n=p.block_n,
                  block_k=p.block_k, out_dtype=out_dtype, **epi_kw)
    else:
        y = backend.run(x2, w_p, block_m=p.block_m, block_n=p.block_n,
                        block_k=p.block_k, out_dtype=out_dtype, **epi_kw)
    return y[:m, :p.n_out].reshape(*lead, p.n_out)


def split_fused(p: GemmPlan, y: jax.Array) -> tuple:
    """Slice a fused execute()'s output into its logical parts.

    The split map is static: part ``i`` starts at the sum of the earlier
    parts' PADDED widths (each padded to ``p.block_n`` at pack time) and
    is ``p.fused_n_splits[i]`` columns wide.  XLA fuses these slices into
    the consumers, so the split costs nothing at run time.
    """
    if not p.fused_n_splits:
        raise ValueError(f"plan carries no fused split map: "
                         f"{p.describe()}")
    if p.glu:
        raise ValueError("glu plans combine their halves in the kernel; "
                         "there is nothing to split")
    outs, off = [], 0
    for ni in p.fused_n_splits:
        outs.append(y[..., off:off + ni])
        off += -(-ni // p.block_n) * p.block_n
    return tuple(outs)


def pack_for_plan(p: GemmPlan, w: jax.Array, *, transposed: bool | None = None,
                  dtype=None, sharding=None) -> packing.PackedWeight:
    """Pack ``w`` once with exactly the blocking the plan will execute
    (the load-time side of the ``prepack`` lever)."""
    return packing.pack(
        w, transposed=p.transposed if transposed is None else transposed,
        block_n=p.block_n, block_k=p.block_k, dtype=dtype,
        sharding=sharding)


def validate_plan(p: GemmPlan) -> bool:
    """Run (memoized) the autotune bit-exactness gate on the plan's block
    triple — and its epilogue, if any: the fused interpret-mode kernel
    must be bit-identical to the unfused ``kernel -> jnp epilogue``
    sequence (plain plans keep the ``kernels/ref.gemm_blocked`` oracle;
    split-K plans gate against ``kernels/ref.gemm_splitk`` — per-slice
    blocked partials combined by the shared fixed-order tree).

    A QUANTIZED plan swaps the bit-exact gate for the two-part quant
    contract (docs/quantization.md): (1) the error-ledger tolerance gate
    — if the ledger holds an entry for this (n, k, format) whose
    measured max-rel error vs the fp32 oracle exceeds the format's
    declared tolerance, the plan is REJECTED; (2) the structural gate —
    the dequant-fused interpret kernel must stay bit-identical to
    ``gemm_blocked`` (``gemm_splitk`` for split-K plans) over the
    dequantized panels, so the tolerance spent on the format is never
    silently spent twice by the kernel.
    """
    if p.quantized:
        from repro.quant import ledger as _ledger
        from repro.quant.kernels import quant_gate
        ent = _ledger.lookup(p.n, p.k, p.weight_format)
        if ent is not None and not ent.within_tol:
            return False
        return quant_gate(p.block_m, p.block_n, p.block_k,
                          p.weight_format, epilogue=p.epilogue,
                          split_k=p.split_k, sparse=p.sparse)
    return _bitexact_gate(p.block_m, p.block_n, p.block_k,
                          epilogue=p.epilogue, split_k=p.split_k)
