"""Blocked (flash) causal attention for TPU — the prefill hot-spot.

Same structural discipline as panel_gemm: the online-softmax state
(m, l, acc) is VMEM scratch carried across the innermost ("arbitrary")
KV grid dimension, zero/neg-inf-initialised at kv_block == 0 (skip-Z
analogue) and stored once at the last KV block (STZ analogue).

Supports: causal masking, sliding window, tanh logit softcap (gemma2),
GQA via pre-repeated KV heads (wrapper in ops.py maps kv->q heads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128

_NEG_INF = -1e30  # finite stand-in; avoids exp(-inf - -inf) NaNs


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  nkv: int, block_q: int, block_kv: int, kv_len: int,
                  q_offset: int, causal: bool, window, softcap, scale):
    jq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = jq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + q_offset
    k_pos = jk * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)

    # Block-level skip: entirely-masked (q, kv) tiles do no work.
    run = True
    if causal:
        run = (jk * block_kv) <= (jq * block_q + q_offset + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bkv, d]
        v = v_ref[0].astype(jnp.float32)            # [bkv, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                          # [bq, 128]
        m_cur = jnp.max(s, axis=-1, keepdims=True)   # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])        # [bq, 1]
        p = jnp.exp(s - m_new[:, :1])                        # [bq, bkv]
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=-1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(jk == nkv - 1)
    def _store():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows -> 0
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale",
                     "block_q", "block_kv", "interpret"))
def flash_attention(
    q: jax.Array,      # [BH, Sq, D]   (batch*heads flattened, kv pre-repeated)
    k: jax.Array,      # [BH, Skv, D]
    v: jax.Array,      # [BH, Skv, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    scale = scale if scale is not None else float(d) ** -0.5

    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_kv
    nkv = skv_p // block_kv

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, nkv=nkv, block_q=block_q, block_kv=block_kv,
            kv_len=skv, q_offset=skv - sq, causal=causal, window=window,
            softcap=softcap, scale=scale),
        grid=(bh, sq_p // block_q, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
