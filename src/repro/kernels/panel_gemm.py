"""Panel GEMM — the paper's kernel, adapted to the TPU MXU.

Paper (M1 AMX)                      → here (TPU, Pallas)
------------------------------------------------------------------
Goto–BLIS 3-level blocking          → (block_m, block_n, block_k) BlockSpec
   A-slice sized to the 128 KB L1   →   blocks sized to fit ~16 MB VMEM,
                                        MXU-aligned (multiples of 128 lanes)
column panel width Nc               → block_n (grid granularity over N)
K-blocking depth Kc                 → block_k (grid depth over K)
skip-Z at (pc==0, kk==0)            → @pl.when(k == 0) zero-init of the
                                      fp32 VMEM accumulator
LDZ/STZ carry of Z across pc        → accumulator scratch carried across the
                                      innermost ("arbitrary") K grid dim;
                                      output written once at k == nk-1
4-way FMA32 ILP across Z banks      → the MXU consumes the whole (bm, bn)
                                      tile; ILP is the hardware's problem —
                                      exactly the paper's point: the inner
                                      loop is fixed, the levers are above it.

The kernel expects its B operand ALREADY in the packed layout produced by
``repro.core.packing`` ([K_pad, N_pad], row-major, block-aligned).  The
pack is paid once at model load (paper lever 2); this kernel is the
per-call "compute loop only" path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

# Deployed block pair (the (Nc, Kc) analogue), fixed by the offline sweep
# in core/autotune.py under the bit-exactness gate (winner over the twelve
# paper shapes; re-derived in benchmarks/table5_panel_sweep.py).  The deep
# K block mirrors the paper's Kc = 2,048 — affordable only because the
# weight is pre-packed (paper §3.3); packing.fit_block shrinks it per
# weight when K is not block-divisible.  See EXPERIMENTS.md §Perf.
DEFAULT_BLOCK_M = 128     # the paper's M = S = 128 prefill row panel
DEFAULT_BLOCK_N = 512     # column-panel width (lever-1 knob)
DEFAULT_BLOCK_K = 2048    # K-blocking depth (lever-2-unlocked knob)

# v5e VMEM budget the blocks must respect (bytes); checked by vmem_bytes().
VMEM_BUDGET = 16 * 1024 * 1024


def vmem_bytes(block_m: int, block_n: int, block_k: int,
               in_dtype=jnp.float32) -> int:
    """Static VMEM footprint model for one grid step (double-buffered ins)."""
    isz = jnp.dtype(in_dtype).itemsize
    x = block_m * block_k * isz
    w = block_k * block_n * isz
    acc = block_m * block_n * 4          # fp32 accumulator scratch
    out = block_m * block_n * isz
    return 2 * (x + w) + acc + out       # 2x: pipelined double buffering


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    """One (i, j, k) grid step: acc[i,j] += x[i,k] @ w[k,j].

    The Z-discipline of the paper, verbatim in Pallas terms: the accumulator
    is zeroed only at k == 0 (skip-Z analogue) and the output is stored only
    at the last K step (STZ).  Without the @pl.when guards, one (i, j)
    tile's partial sums leak into the next — the exact silent-drift bug the
    paper calls correctness-critical.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"),
)
def panel_gemm(
    x: jax.Array,               # [M_pad, K_pad]  activations (pre-padded)
    w: jax.Array,               # [K_pad, N_pad]  packed weight panels
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C[M_pad, N_pad] = x @ w via MXU panel tiles.

    Shapes must be pre-padded to block multiples (the pack does this once at
    load for w; ops.py pads x per call — cheap, M=128 at prefill).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{n},{k}) not aligned to blocks "
        f"({block_m},{block_n},{block_k}); pack first")
    nk = k // block_k
    out_dtype = out_dtype or x.dtype

    return pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk),
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
