"""Panel GEMM — the paper's kernel, adapted to the TPU MXU.

Paper (M1 AMX)                      → here (TPU, Pallas)
------------------------------------------------------------------
Goto–BLIS 3-level blocking          → (block_m, block_n, block_k) BlockSpec
   A-slice sized to the 128 KB L1   →   blocks sized to fit ~16 MB VMEM,
                                        MXU-aligned (multiples of 128 lanes)
column panel width Nc               → block_n (grid granularity over N)
K-blocking depth Kc                 → block_k (grid depth over K)
skip-Z at (pc==0, kk==0)            → @pl.when(k == 0) zero-init of the
                                      fp32 VMEM accumulator
LDZ/STZ carry of Z across pc        → accumulator scratch carried across the
                                      innermost ("arbitrary") K grid dim;
                                      output written once at k == nk-1
4-way FMA32 ILP across Z banks      → the MXU consumes the whole (bm, bn)
                                      tile; ILP is the hardware's problem —
                                      exactly the paper's point: the inner
                                      loop is fixed, the levers are above it.

The kernel expects its B operand ALREADY in the packed layout produced by
``repro.core.packing`` ([K_pad, N_pad], row-major, block-aligned).  The
pack is paid once at model load (paper lever 2); this kernel is the
per-call "compute loop only" path.

Fused epilogue (the lever ABOVE the store): the baseline kernel flushes
the fp32 accumulator to HBM only for XLA to re-read it for bias /
activation / residual.  ``EpilogueSpec`` instead applies those ops on the
fp32 VMEM accumulator inside the ``k == nk-1`` store step (the STZ
analogue), so the tile leaves VMEM exactly once, already finished.  The
``glu`` variant carries TWO accumulators over the K grid — gate and up
column panels of a horizontally fused weight — and stores
``act(gate) * up``: one pass streams x once for both projections and the
[M, 2F] intermediate never exists in HBM.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

# Deployed block pair (the (Nc, Kc) analogue), fixed by the offline sweep
# in core/autotune.py under the bit-exactness gate (winner over the twelve
# paper shapes; re-derived in benchmarks/table5_panel_sweep.py).  The deep
# K block mirrors the paper's Kc = 2,048 — affordable only because the
# weight is pre-packed (paper §3.3); packing.fit_block shrinks it per
# weight when K is not block-divisible.  See EXPERIMENTS.md §Perf.
DEFAULT_BLOCK_M = 128     # the paper's M = S = 128 prefill row panel
DEFAULT_BLOCK_N = 512     # column-panel width (lever-1 knob)
DEFAULT_BLOCK_K = 2048    # K-blocking depth (lever-2-unlocked knob)

# Skinny-M specialization for the decode fast lane: [slots, 1] decode
# rows pad to one 8-row sublane tile instead of the 128-row prefill
# panel (gemm.policy's decode arm plans against this).
DECODE_BLOCK_M = 8

# v5e VMEM budget the blocks must respect (bytes); checked by vmem_bytes().
VMEM_BUDGET = 16 * 1024 * 1024


# ------------------------------------------------------------- epilogue
_EPI_ACTS = ("silu", "gelu", "tanh")


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """Statically-planned epilogue applied on the fp32 accumulator at the
    kernel's single store step (see module docstring).

    Application order: bias add -> glu combine (``act(gate) * up`` over
    the two column halves) OR plain activation -> tanh softcap ->
    residual add -> one cast + store.  ``bias`` / ``residual`` are flags;
    the operands themselves ride the call (``execute(..., bias=,
    residual=)``).  All ops run in fp32 — for fp32 operands the fused
    result is bit-identical to the unfused ``kernel -> XLA op`` sequence
    (the gate ``gemm.validate_plan`` runs per spec).
    """
    bias: bool = False
    act: str | None = None          # "silu" | "gelu" | "tanh"
    softcap: float | None = None    # cap * tanh(x / cap)
    residual: bool = False
    glu: str | None = None          # activation of the gate half

    def __post_init__(self):
        for name in (self.act, self.glu):
            if name is not None and name not in _EPI_ACTS:
                raise ValueError(f"unknown epilogue activation {name!r}; "
                                 f"choose from {_EPI_ACTS}")
        if self.act is not None and self.glu is not None:
            raise ValueError("act and glu are mutually exclusive (glu "
                             "already applies its activation to the gate)")

    @property
    def is_noop(self) -> bool:
        return not (self.bias or self.act or self.softcap is not None
                    or self.residual or self.glu)


_GELU_C = 0.7978845608028654        # sqrt(2 / pi)


def _gelu_tanh(x):
    # jax.nn.gelu's internals get rewritten differently inside the Pallas
    # interpreter vs plain XLA (bit drift ~5e-7); this explicit tanh
    # formulation lowers identically in both, which the fused-vs-unfused
    # bitwise contract needs.  Every epilogue-capable path (kernel, xla
    # backend, unfused layers) routes gelu through here.
    return 0.5 * x * (1.0 + jnp.tanh(_GELU_C * (x + 0.044715 * (x * x * x))))


def act_fn(name: str):
    """The repo-wide activation table (see ``_gelu_tanh`` for why gelu is
    hand-rolled).  Shared by the kernel epilogue, the XLA epilogue path,
    and the unfused ``models.layers`` ops, so fused == unfused holds
    bitwise for fp32.  Unknown names raise — a typo'd ``cfg.act`` must
    not silently compute tanh."""
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return _gelu_tanh
    if name == "tanh":
        return jnp.tanh
    raise ValueError(f"unknown activation {name!r}; choose from "
                     f"{_EPI_ACTS}")


_act_fn = act_fn


def _finish(spec: EpilogueSpec, acc, residual):
    """Post-activation epilogue steps, shared by kernel and reference.

    Softcap multiplies by the host-computed reciprocal instead of
    dividing: XLA rewrites ``x / const`` to ``x * (1/const)`` outside
    Pallas but not inside the interpreter, which would break the
    fused-vs-unfused bitwise contract."""
    if spec.softcap is not None:
        acc = spec.softcap * jnp.tanh(acc * (1.0 / spec.softcap))
    if spec.residual:
        acc = acc + residual
    return acc


def apply_epilogue_glu(g: jax.Array, u: jax.Array, spec: EpilogueSpec, *,
                       bias_g=None, bias_u=None, residual=None):
    """The glu epilogue on pre-split gate/up fp32 accumulators — the ONE
    definition shared by the kernel store step (two accumulator tiles)
    and the xla backend (two half dots), so both are bit-identical to
    the full-width reference."""
    g = g.astype(jnp.float32)
    u = u.astype(jnp.float32)
    if spec.bias:
        g = g + bias_g.astype(jnp.float32)
        u = u + bias_u.astype(jnp.float32)
    acc = _act_fn(spec.glu)(g) * u
    res = residual.astype(jnp.float32) if spec.residual else None
    return _finish(spec, acc, res)


def apply_epilogue(acc: jax.Array, spec: EpilogueSpec, *, bias=None,
                   residual=None) -> jax.Array:
    """Reference epilogue on a full fp32 accumulator array.

    This is THE semantics the fused kernel store step must match bitwise:
    the same jnp ops, in the same order, in fp32.  Backends that cannot
    fuse (xla) call this on the dot's fp32 result; the bit-exactness gate
    compares the fused kernel against ``unfused kernel -> this``.  For a
    ``glu`` spec ``acc`` is full width and the halves are combined here.
    """
    acc = acc.astype(jnp.float32)
    if spec.glu is not None:
        half = acc.shape[-1] // 2
        b = bias.astype(jnp.float32) if spec.bias else None
        return apply_epilogue_glu(
            acc[..., :half], acc[..., half:], spec,
            bias_g=b[..., :half] if spec.bias else None,
            bias_u=b[..., half:] if spec.bias else None,
            residual=residual)
    if spec.bias:
        acc = acc + bias.astype(jnp.float32)
    if spec.act is not None:
        acc = _act_fn(spec.act)(acc)
    res = None
    if spec.residual:
        res = residual.astype(jnp.float32)
    return _finish(spec, acc, res)


def splitk_combine(parts) -> jax.Array:
    """Deterministic fixed-order pairwise tree sum of split-K partials.

    ``parts``: a list of fp32 ``[M, N]`` partials (or a stacked
    ``[split_k, M, N]`` array), one per K slice, in slice order.  The
    combine order is a STATIC pairwise tree — (p0+p1)+(p2+p3)... — so
    the result is a pure function of the partial values, independent of
    which backend produced them: the Pallas kernel, the interpreter,
    the xla slice-dot run and the ``ref.gemm_splitk`` oracle all route
    through this one definition, which is what makes split-K results
    bitwise-reproducible per backend and kernel == oracle bitwise.
    """
    if not isinstance(parts, (list, tuple)):
        parts = [parts[i] for i in range(parts.shape[0])]
    parts = list(parts)
    assert parts, "splitk_combine needs at least one partial"
    while len(parts) > 1:
        parts = [parts[i] + parts[i + 1] if i + 1 < len(parts)
                 else parts[i] for i in range(0, len(parts), 2)]
    return parts[0]


def vmem_bytes(block_m: int, block_n: int, block_k: int,
               in_dtype=jnp.float32, *,
               epilogue: EpilogueSpec | None = None,
               weight_format: str = "fp32",
               split_k: int = 1,
               sparse_groups: int = 0,
               sparse_panels: int = 0) -> int:
    """Static VMEM footprint model for one grid step (double-buffered ins).

    A ``glu`` epilogue streams two weight tiles and carries two fp32
    accumulators.  The bias/residual operand tiles are budgeted
    UNCONDITIONALLY: a weight is packed once but may execute under
    different epilogues (w_down runs with and without the fused residual
    add), so the footprint a pack's blocks are clamped against must be
    the worst execute-time footprint — otherwise plan-time clamping
    could shrink below the pack's blocks and every execute would raise
    PlanMismatchError.

    ``weight_format`` sizes the STREAMED weight tile: a quantized pack
    streams int8 codes (1 B/elem) or 2-bit ternary bytes (0.25 B/elem)
    plus a per-column fp32 scale row, so quantized plans fit deeper /
    wider blocks in the same budget (repro.quant).

    ``split_k > 1`` budgets the decode lane's per-slice fp32 partials
    slab (``[split_k, block_m, block_n]``): the combine epilogue reads
    every slice's partial for one output tile, so the whole slab must
    be resident alongside the streaming tiles.

    ``sparse_groups > 0`` budgets the compressed-ternary walk instead
    of the dense K stream: the grid's K axis is the occupied-group list,
    so one step streams a ``(block_m, GROUP_K)`` x tile, a packed
    ``(GROUP_K/4, block_n)`` code tile and a single fp32 scale row —
    ``block_k`` is ignored — plus the scalar-prefetched group-offset
    index (int32 per occupied slot) and the ``sparse_panels``-wide
    occupancy matrix, resident once (not double-buffered).
    """
    isz = jnp.dtype(in_dtype).itemsize
    if weight_format == "fp32":
        x = block_m * block_k * isz
        w = block_k * block_n * isz
        scales = 0
    else:
        from repro.quant.formats import GROUP_K, weight_itemsize
        bk_eff = GROUP_K if sparse_groups > 0 else block_k
        x = block_m * bk_eff * isz
        w = int(bk_eff * block_n * weight_itemsize(weight_format))
        # per-(column, K-group) fp32 scale slab for this tile
        scales = max(1, bk_eff // GROUP_K) * block_n * 4
    acc = block_m * block_n * 4          # fp32 accumulator scratch
    out = block_m * block_n * isz
    glu = epilogue is not None and epilogue.glu is not None
    if glu:
        w *= 2
        scales *= 2
        acc *= 2
    # worst-case epilogue operand headroom (fp32 bias row + residual tile)
    extra = block_n * 4 * (2 if glu else 1) + block_m * block_n * 4
    if split_k > 1:     # decode lane: per-slice fp32 partials slab
        extra += split_k * block_m * block_n * 4
    if sparse_groups > 0:   # sparse walk: group index + occupancy matrix
        extra += 4 * sparse_groups * (1 + max(1, sparse_panels))
    return 2 * (x + w + scales) + acc + out + extra   # 2x: double buffering


def _gemm_kernel(x_ref, w_ref, *refs, nk: int,
                 spec: EpilogueSpec | None = None):
    """One (i, j, k) grid step: acc[i,j] += x[i,k] @ w[k,j].

    The Z-discipline of the paper, verbatim in Pallas terms: the accumulator
    is zeroed only at k == 0 (skip-Z analogue) and the output is stored only
    at the last K step (STZ).  Without the @pl.when guards, one (i, j)
    tile's partial sums leak into the next — the exact silent-drift bug the
    paper calls correctness-critical.

    ``refs`` trail the optional epilogue operands: [bias], [residual],
    then o_ref and the accumulator scratch.  The epilogue runs INSIDE the
    STZ step, on the fp32 accumulator, before the single cast+store.
    """
    refs = list(refs)
    acc_ref = refs.pop()
    o_ref = refs.pop()
    bias_ref = refs.pop(0) if spec is not None and spec.bias else None
    res_ref = refs.pop(0) if spec is not None and spec.residual else None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():
        acc = acc_ref[...]
        if spec is not None:
            if spec.bias:
                acc = acc + bias_ref[...]          # [1, bn] broadcasts
            if spec.act is not None:
                acc = _act_fn(spec.act)(acc)
            acc = _finish(spec, acc, res_ref[...] if res_ref is not None
                          else None)
        o_ref[...] = acc.astype(o_ref.dtype)


def _gemm_glu_kernel(x_ref, wg_ref, wu_ref, *refs, nk: int,
                     spec: EpilogueSpec):
    """GLU variant: TWO accumulators ride the K grid — the gate and up
    column panels of one horizontally fused weight (``core.packing
    .pack_fused``).  x is loaded once per step and feeds both dots; the
    store step combines ``act(gate) * up`` on the fp32 accumulators, so
    the [M, 2F] intermediate never reaches HBM."""
    refs = list(refs)
    acc_u_ref = refs.pop()
    acc_g_ref = refs.pop()
    o_ref = refs.pop()
    bg_ref = refs.pop(0) if spec.bias else None
    bu_ref = refs.pop(0) if spec.bias else None
    res_ref = refs.pop(0) if spec.residual else None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_g_ref[...] = jnp.zeros_like(acc_g_ref)
        acc_u_ref[...] = jnp.zeros_like(acc_u_ref)

    x = x_ref[...]
    acc_g_ref[...] += jnp.dot(x, wg_ref[...],
                              preferred_element_type=jnp.float32)
    acc_u_ref[...] += jnp.dot(x, wu_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        acc = apply_epilogue_glu(
            acc_g_ref[...], acc_u_ref[...], spec,
            bias_g=bg_ref[...] if bg_ref is not None else None,
            bias_u=bu_ref[...] if bu_ref is not None else None,
            residual=res_ref[...] if res_ref is not None else None)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret",
                     "out_dtype", "epilogue"),
)
def panel_gemm(
    x: jax.Array,               # [M_pad, K_pad]  activations (pre-padded)
    w: jax.Array,               # [K_pad, N_pad]  packed weight panels
    bias: jax.Array | None = None,       # [N_pad] fp32 (iff epilogue.bias)
    residual: jax.Array | None = None,   # [M_pad, N_out_pad] fp32
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    out_dtype=None,
    epilogue: EpilogueSpec | None = None,
    interpret: bool = False,
) -> jax.Array:
    """C[M_pad, N_pad] = epilogue(x @ w) via MXU panel tiles.

    Shapes must be pre-padded to block multiples (the pack does this once at
    load for w; ops.py pads x per call — cheap, M=128 at prefill).  With a
    ``glu`` epilogue ``w`` holds [gate | up] column halves (each half
    block-aligned) and the output is [M_pad, N_pad // 2].
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{n},{k}) not aligned to blocks "
        f"({block_m},{block_n},{block_k}); pack first")
    nk = k // block_k
    out_dtype = out_dtype or x.dtype
    spec = epilogue
    if spec is not None and spec.is_noop:
        spec = None
    glu = spec is not None and spec.glu is not None
    n_out = n // 2 if glu else n
    if glu:
        assert n % 2 == 0 and n_out % block_n == 0, (
            f"glu epilogue needs block-aligned column halves; got N={n} "
            f"with block_n={block_n} — pack with pack_fused")
    assert (bias is not None) == bool(spec is not None and spec.bias)
    assert (residual is not None) == bool(spec is not None and spec.residual)

    ops = [x, w]
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    half_tiles = n_out // block_n
    if glu:        # up panel: same array, column-offset index map
        ops.append(w)
        in_specs.append(pl.BlockSpec(
            (block_k, block_n), lambda i, j, kk: (kk, j + half_tiles)))
    if spec is not None and spec.bias:
        b2 = bias.reshape(1, n).astype(jnp.float32)
        ops.append(b2)
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        if glu:
            ops.append(b2)
            in_specs.append(pl.BlockSpec(
                (1, block_n), lambda i, j, kk: (0, j + half_tiles)))
    if spec is not None and spec.residual:
        assert residual.shape == (m, n_out), (
            f"residual {residual.shape} vs output ({m},{n_out})")
        ops.append(residual.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((block_m, block_n),
                                     lambda i, j, kk: (i, j)))

    if glu:
        kernel = functools.partial(_gemm_glu_kernel, nk=nk, spec=spec)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((block_m, block_n), jnp.float32)]
    else:
        kernel = functools.partial(_gemm_kernel, nk=nk, spec=spec)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]

    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n_out // block_n, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n_out), out_dtype),
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*ops)


# ----------------------------------------------------------- split-K lane
def _splitk_kernel(x_ref, w_ref, o_ref, acc_ref, *, nks: int):
    """One (s, i, j, kk) grid step of the split-K partials pass:
    acc[s,i,j] += x[i, s*nks + kk] @ w[s*nks + kk, j].

    The Z-discipline is per SLICE: the accumulator zeroes at the first
    K block of the slice and the slice's fp32 partial is stored (never
    cast) at its last — the combine tree runs outside, shared with
    every backend.  ``s`` is a PARALLEL grid dimension: at decode
    (M <= 8, one row panel) the (i, j) grid exposes almost no parallel
    output panels, and ``s`` restores occupancy on the reduction side —
    the paper's fine-panel lever, generalized to K.
    """
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kk == nks - 1)
    def _store():
        o_ref[...] = acc_ref[...][None]


@functools.partial(
    jax.jit,
    static_argnames=("split_k", "block_m", "block_n", "block_k",
                     "interpret", "out_dtype", "epilogue"),
)
def panel_gemm_splitk(
    x: jax.Array,               # [M_pad, K_pad]  activations (pre-padded)
    w: jax.Array,               # [K_pad, N_pad]  packed weight panels
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    *,
    split_k: int,
    block_m: int = DECODE_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    out_dtype=None,
    epilogue: EpilogueSpec | None = None,
    interpret: bool = False,
) -> jax.Array:
    """C = epilogue(splitk_combine(per-slice x @ w)) — the decode lane.

    Grid ``(s, i, j, kk)``: ``split_k`` K slices accumulate independent
    fp32 partials (all three leading dims parallel), combined by the
    deterministic :func:`splitk_combine` tree; the epilogue then runs
    on the combined fp32 accumulator via the shared
    :func:`apply_epilogue` (so fused == unfused stays bit-identical,
    glu included).  Bit-identical to ``ref.gemm_splitk`` at the same
    ``(block_k, split_k)`` — gated by ``gemm.validate_plan``.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert split_k >= 1 and k % split_k == 0, (
        f"K={k} not divisible by split_k={split_k}")
    ks = k // split_k
    assert m % block_m == 0 and n % block_n == 0 and ks % block_k == 0, (
        f"shapes ({m},{n},{k}) / slice depth {ks} not aligned to blocks "
        f"({block_m},{block_n},{block_k}); pack first")
    nks = ks // block_k
    out_dtype = out_dtype or x.dtype
    spec = epilogue
    if spec is not None and spec.is_noop:
        spec = None
    glu = spec is not None and spec.glu is not None
    n_out = n // 2 if glu else n
    if glu:
        assert n % 2 == 0 and n_out % block_n == 0, (
            f"glu epilogue needs block-aligned column halves; got N={n} "
            f"with block_n={block_n} — pack with pack_fused")
    assert (bias is not None) == bool(spec is not None and spec.bias)
    assert (residual is not None) == bool(spec is not None
                                          and spec.residual)

    partials = pl.pallas_call(
        functools.partial(_splitk_kernel, nks=nks),
        grid=(split_k, m // block_m, n // block_n, nks),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda s, i, j, kk: (i, s * nks + kk)),
            pl.BlockSpec((block_k, block_n),
                         lambda s, i, j, kk: (s * nks + kk, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda s, i, j, kk: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((split_k, m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
    acc = splitk_combine(partials)
    if spec is not None:
        acc = apply_epilogue(acc, spec, bias=bias, residual=residual)
    return acc.astype(out_dtype)
