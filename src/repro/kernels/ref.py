"""Pure-jnp oracles for the kernels.

Two references, mirroring the paper's two comparison baselines:

* ``gemm_xla``      — the "Accelerate" analogue: whatever XLA's dot does.
                      Used for allclose checks and as the runtime fallback.
* ``gemm_blocked``  — accumulates K in the SAME block order as the Pallas
                      kernel (sequential fp32 partial sums over block_k
                      slabs).  The kernel must be BIT-IDENTICAL to this
                      oracle — the paper's max-abs-diff = 0e+00 discipline.
                      (fp32 summation order differs from gemm_xla, so
                      kernel-vs-xla is allclose, not bitwise; the paper hits
                      the same issue with BNNS Graph and reports the diff.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_xla(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference GEMM: XLA dot, fp32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def gemm_blocked(x: jax.Array, w: jax.Array, block_k: int,
                 out_dtype=None) -> jax.Array:
    """K-blocked GEMM in the kernel's exact accumulation order."""
    m, k = x.shape
    _, n = w.shape
    assert k % block_k == 0
    out_dtype = out_dtype or x.dtype
    acc = jnp.zeros((m, n), jnp.float32)
    for kk in range(0, k, block_k):
        acc = acc + jnp.dot(
            x[:, kk:kk + block_k], w[kk:kk + block_k, :],
            preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def gemm_splitk(x: jax.Array, w: jax.Array, block_k: int, split_k: int,
                out_dtype=None) -> jax.Array:
    """Split-K oracle: the decode fast lane's exact accumulation order.

    K is cut into ``split_k`` contiguous slices; each slice accumulates
    its own fp32 partial in ``gemm_blocked`` order (the per-slice kernel
    discipline), and the partials are combined by the SAME deterministic
    fixed-order pairwise tree the kernel epilogue and the xla backend
    use (``panel_gemm.splitk_combine``).  ``panel_gemm_splitk`` must be
    BIT-IDENTICAL to this — the paper's max-abs-diff = 0e+00 discipline,
    extended to the reduction dimension.  ``split_k == 1`` degenerates
    to ``gemm_blocked`` exactly.
    """
    from repro.kernels.panel_gemm import splitk_combine
    m, k = x.shape
    assert k % split_k == 0, f"K={k} not divisible by split_k={split_k}"
    ks = k // split_k
    assert ks % block_k == 0, (
        f"slice depth {ks} not divisible by block_k={block_k}")
    out_dtype = out_dtype or x.dtype
    parts = [gemm_blocked(x[:, s * ks:(s + 1) * ks],
                          w[s * ks:(s + 1) * ks, :], block_k,
                          out_dtype=jnp.float32)
             for s in range(split_k)]
    return splitk_combine(parts).astype(out_dtype)


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              scale=None):
    """Reference multi-head attention.  q,k,v: [B, S, H, D] / [B, T, Hkv, D].

    GQA: H may be a multiple of Hkv (kv heads are repeated).
    window: sliding-window size (None = full); softcap: tanh logit cap.
    """
    b, s, h, d = q.shape
    _, t, hkv, _ = k.shape
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(s)[:, None] + (t - s)   # align cache offset
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)
