"""Chunked SSD (Mamba-2 state-space duality) as a Pallas TPU kernel.

The jnp implementation (models/ssm.ssd_chunked) materializes the
intra-chunk quadratic blocks (the [l, l] decay matrix and C·Bᵀ scores)
in HBM — the dominant memory term on SSM/hybrid train cells (§Roofline).
This kernel keeps them in VMEM, exactly like the flash-attention kernel
keeps its score blocks resident: HBM sees only x/a/b/c reads and y
writes, one pass.

Layout: grid = (B·H, T/l) with the chunk axis innermost ("arbitrary");
the inter-chunk state [P, N] lives in a VMEM scratch carried across
chunk steps (zeroed at chunk 0 — the panel-GEMM Z-discipline again).
Group-shared B/C are read through the BlockSpec index_map (no
materialized repeat to H heads).

Per (bh, c) step, VMEM working set ≈ l·P + 2·l·N + l·l + P·N floats —
l=128, P=64, N=128 ⇒ ~0.3 MB, comfortably under budget
(vmem_bytes() below).

Oracle: models/ssm.ssd_chunked (pure jnp); parity asserted in
tests/test_ssd_kernel.py across shapes/dtypes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_CHUNK = 128


def vmem_bytes(l: int, p: int, n: int) -> int:
    work = l * p + 2 * l * n + l * l + p * n + l * p
    return 2 * work * 4


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x_c = x_ref[0].astype(jnp.float32)          # [l, P]
    a_c = a_ref[0].astype(jnp.float32)          # [l]
    b_c = b_ref[0].astype(jnp.float32)          # [l, N]
    c_c = c_ref[0].astype(jnp.float32)          # [l, N]
    l = a_c.shape[0]

    a_cum = jnp.cumsum(a_c)                     # [l]
    # intra-chunk decay: L[i,j] = exp(sum_{j<k<=i} a_k), lower-triangular
    seg = a_cum[:, None] - a_cum[None, :]       # [l, l]
    tril = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    lmat = jnp.where(tril, jnp.exp(seg), 0.0)

    cb = jnp.dot(c_c, b_c.T, preferred_element_type=jnp.float32)
    y_diag = jnp.dot(cb * lmat, x_c, preferred_element_type=jnp.float32)

    state = state_ref[...]                      # [P, N]
    y_off = jnp.dot(c_c, state.T,
                    preferred_element_type=jnp.float32) \
        * jnp.exp(a_cum)[:, None]               # [l, P]... see note

    # state update: decay the carry, add this chunk's contribution
    decay = jnp.exp(a_cum[-1] - a_cum)          # [l]
    chunk_state = jnp.dot(x_c.T, b_c * decay[:, None],
                          preferred_element_type=jnp.float32)  # [P, N]
    state_ref[...] = state * jnp.exp(a_cum[-1]) + chunk_state

    o_ref[0] = (y_diag + y_off).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, a, b, c, *, chunk: int = DEFAULT_CHUNK,
        interpret: bool = False):
    """y = chunked-SSD(x, a, b, c), heads flattened.

    x: [BH, T, P] (dt-premultiplied); a: [BH, T] (= A·dt, ≤ 0);
    b, c: [BH, T, N] (groups pre-broadcast by the caller's index_map or
    repeat).  T must be a chunk multiple.  Returns y: [BH, T, P].
    Final state is recomputed by the caller when needed (serving uses
    the jnp path; this kernel is the training/prefill hot loop).
    """
    bh, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    return pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, a, b, c)
