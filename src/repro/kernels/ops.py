"""Jit'd convenience wrappers around the Pallas kernels.

``repro.gemm`` (plan/execute) is the deployment surface
(packed/per-call/xla paths); these wrappers expose the raw kernels with
shape massaging for tests, benchmarks, and the attention layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import panel_gemm as _pg
from repro.kernels import ref as _ref


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "interpret"))
def gemm(x: jax.Array, w: jax.Array, *,
         block_m: int = _pg.DEFAULT_BLOCK_M,
         block_n: int = _pg.DEFAULT_BLOCK_N,
         block_k: int = _pg.DEFAULT_BLOCK_K,
         interpret: bool = False) -> jax.Array:
    """GEMM on arbitrary (M, K) x (K, N): pads to blocks, calls the kernel,
    slices back."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = (min(block_m, _rnd(m)), min(block_n, _rnd(n)),
                  min(block_k, _rnd(k)))
    xp = jnp.pad(x, (((-m) % bm and (0, (-m) % bm)) or (0, 0),
                     ((-k) % bk and (0, (-k) % bk)) or (0, 0)))
    wp = jnp.pad(w, (((-k) % bk and (0, (-k) % bk)) or (0, 0),
                     ((-n) % bn and (0, (-n) % bn)) or (0, 0)))
    y = _pg.panel_gemm(xp, wp, block_m=bm, block_n=bn, block_k=bk,
                       interpret=interpret)
    return y[:m, :n]


def _rnd(x: int, mult: int = 128) -> int:
    """Round up to the MXU lane multiple (small test shapes stay small)."""
    return max(mult, ((x + mult - 1) // mult) * mult)


def mha(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
        block_q: int = _fa.DEFAULT_BLOCK_Q,
        block_kv: int = _fa.DEFAULT_BLOCK_KV,
        interpret: bool = False):
    """Multi-head attention on [B, S, H, D] with GQA kv [B, T, Hkv, D]."""
    b, s, h, d = q.shape
    _, t, hkv, _ = k.shape
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                            softcap=softcap, scale=scale, block_q=block_q,
                            block_kv=block_kv, interpret=interpret)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


# Re-export oracles next to the wrappers for test convenience.
ref_gemm = _ref.gemm_xla
ref_gemm_blocked = _ref.gemm_blocked
ref_attention = _ref.attention
