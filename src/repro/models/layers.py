"""Shared building blocks.  Every projection goes through ``linear`` so the
whole stack can be switched between raw weights (training) and the paper's
pre-packed path (inference) — see models/model_zoo.pack_for_inference."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro import gemm as _gemm
from repro.core.packing import PackedWeight


def dot_dtype(native):
    """Operand dtype for bf16 einsums with fp32 accumulation.

    The TPU MXU consumes bf16 natively (and upcasting operands costs HBM
    round-trips — §Perf C3); the XLA:CPU thunk runtime cannot EXECUTE
    some bf16×bf16→f32 dots.  Real CPU execution therefore upcasts; the
    dry-run (compile-only, TPU-targeted) forces native via
    REPRO_MXU_DOTS=1, and REPRO_MXU_DOTS=0 forces fp32 everywhere.
    """
    force = os.environ.get("REPRO_MXU_DOTS")
    if force == "1":
        return native
    if force == "0" or jax.default_backend() == "cpu":
        return jnp.float32
    return native


def linear(x: jax.Array, w) -> jax.Array:
    """x[..., K] @ w[K, N].  w may be a raw array or a PackedWeight
    (pre-packed once at model load — paper lever 2).

    Packed weights dispatch through the plan/execute API: the plan is
    resolved at trace time (shape-keyed LRU cache, so prefill and decode
    each resolve once) on the backend of the enclosing
    ``gemm.use_backend`` scope (e.g. the serving Engine's).
    """
    if isinstance(w, PackedWeight):
        p = _gemm.plan_for_packed(_gemm.lead_m(x), w)
        return _gemm.execute(p, x, w)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(
        jnp.float32)
    return (x * s).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """Rotary embedding.  x: [..., S, H, D] (D even), positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down, act: str = "silu"):
    a = linear(x, w_gate)
    a = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a, approximate=True)
    return linear(a * linear(x, w_up), w_down)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def embed_tokens(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits [..., V] fp32-softmaxed."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def init_dense(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else shape[0] ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
