"""Shared building blocks.  Every projection goes through ``linear`` so the
whole stack can be switched between raw weights (training) and the paper's
pre-packed path (inference) — see models/model_zoo.pack_for_inference."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro import gemm as _gemm
from repro.core.packing import PackedWeight
from repro.kernels.panel_gemm import act_fn as _act_fn


def dot_dtype(native):
    """Operand dtype for bf16 einsums with fp32 accumulation.

    The TPU MXU consumes bf16 natively (and upcasting operands costs HBM
    round-trips — §Perf C3); the XLA:CPU thunk runtime cannot EXECUTE
    some bf16×bf16→f32 dots.  Real CPU execution therefore upcasts; the
    dry-run (compile-only, TPU-targeted) forces native via
    REPRO_MXU_DOTS=1, and REPRO_MXU_DOTS=0 forces fp32 everywhere.
    """
    force = os.environ.get("REPRO_MXU_DOTS")
    if force == "1":
        return native
    if force == "0" or jax.default_backend() == "cpu":
        return jnp.float32
    return native


def linear(x: jax.Array, w, bias=None, *, softcap: float | None = None,
           residual=None, out_dtype=None) -> jax.Array:
    """x[..., K] @ w[K, N] (+ fused epilogue).  w may be a raw array, a
    PackedWeight (pre-packed once at model load — paper lever 2), or a
    QuantizedPackedWeight (quantized at pack time — the plan picks up
    its format and dispatches the dequant-fused path, repro.quant).

    Packed weights dispatch through the plan/execute API: the plan is
    resolved at trace time (shape-keyed LRU cache, so prefill and decode
    each resolve once) on the backend of the enclosing
    ``gemm.use_backend`` scope (e.g. the serving Engine's).  ``bias`` /
    ``softcap`` / ``residual`` become the plan's ``EpilogueSpec`` —
    applied on the fp32 accumulator inside the kernel's store step, so
    the projection's output leaves the GEMM already finished instead of
    round-tripping through HBM for a follow-up XLA op.  The raw-weight
    path applies the identical fp32 ops (bit-identical for fp32
    operands).
    """
    if isinstance(w, PackedWeight):
        spec = None
        if bias is not None or softcap is not None or residual is not None:
            spec = _gemm.EpilogueSpec(bias=bias is not None,
                                      softcap=softcap,
                                      residual=residual is not None)
        p = _gemm.plan_for_packed(_gemm.lead_m(x), w, epilogue=spec)
        return _gemm.execute(p, x, w, bias=bias, residual=residual,
                             out_dtype=out_dtype)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if softcap is not None:
        y = softcap * jnp.tanh(y * (1.0 / softcap))
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y.astype(out_dtype or x.dtype)


def fused_linear(x: jax.Array, w: PackedWeight) -> tuple:
    """One GEMM pass over a horizontally fused pack (``pack_fused``):
    streams x once, returns the per-part outputs of the static split map
    (Q/K/V; MLA's down-projections).  Two HBM reads of x deleted per
    call vs three separate projections.
    """
    p = _gemm.plan_for_packed(_gemm.lead_m(x), w)
    return _gemm.split_fused(p, _gemm.execute(p, x, w))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(
        jnp.float32)
    return (x * s).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """Rotary embedding.  x: [..., S, H, D] (D even), positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down, act: str = "silu"):
    """Unfused gated FFN (training / raw-weight path).  The activation
    comes from the shared ``kernels.panel_gemm.act_fn`` table so the
    fused epilogue path computes the identical function."""
    a = _act_fn(act)(linear(x, w_gate))
    return linear(a * linear(x, w_up), w_down)


def swiglu_fused(x, w_gate_up: PackedWeight, w_down, act: str = "silu",
                 residual=None):
    """Gated FFN over a horizontally fused gate+up pack: ONE kernel pass
    streams x once, carries two accumulators, and combines
    ``act(gate) * up`` on fp32 in the store step — the [.., 2F]
    intermediate never reaches HBM (glu ``EpilogueSpec``).  ``residual``
    rides the down-projection's epilogue (pre-norm blocks), deleting the
    separate residual-add round-trip too."""
    spec = _gemm.EpilogueSpec(glu=act)
    p = _gemm.plan_for_packed(_gemm.lead_m(x), w_gate_up, epilogue=spec)
    h = _gemm.execute(p, x, w_gate_up)
    return linear(h, w_down, residual=residual)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x * (1.0 / cap))


def embed_tokens(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits [..., V] fp32-softmaxed."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def init_dense(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else shape[0] ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
