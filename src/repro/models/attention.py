"""Attention variants: GQA / SWA / local-global / softcap / MLA (absorbed).

The workhorse is ``blocked_attention`` — an online-softmax (flash) attention
in pure JAX with lax.scan over KV chunks.  It is the memory-safe path used
for prefill_32k / train_4k lowering (HLO stays small, no (S, T) scores
materialization) and it accepts *traced* per-layer window / kv_len scalars
so a single scan-over-layers body serves alternating local/global patterns
(gemma2), growing decode caches, and SWA ring caches (explicit per-slot
``k_pos``; softmax is permutation-invariant over key order, so an unordered
ring buffer only needs true positions, not re-sorting).

On TPU the same math runs as the Pallas kernel in kernels/flash_attention.py
(validated against the same oracle); runtime selection mirrors the
gemm backend registry's explicit choice.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.models import flash_vjp as _fv

_NEG = -1e30

# §Perf iteration B: route the backward through the flash custom-VJP
# (O(S·D) residuals) instead of reverse-mode through the chunk scan
# (O(S·T) stacked score residuals).  Forward math is identical.
USE_FLASH_VJP = os.environ.get("REPRO_FLASH_VJP", "1") != "0"


def blocked_attention(
    q: jax.Array,                  # [B, S, H, D]
    k: jax.Array,                  # [B, T, Hkv, D]
    v: jax.Array,                  # [B, T, Hkv, Dv]
    *,
    scale: float,
    causal: bool = True,
    window=None,                   # None | int | traced int32 (<=0 => full)
    softcap: float | None = None,
    kv_len=None,                   # traced valid-cache length (default T)
    q_offset=0,                    # traced start position of q row 0:
                                   # scalar, or [B] per-slot offsets
                                   # (continuous-batching decode, where
                                   # every slot sits at its own length)
    k_pos=None,                    # [B, T] explicit key positions (ring
                                   # caches); -1 marks an empty slot
    chunk: int = 512,
) -> jax.Array:
    b, s, h, d = q.shape
    _, t, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_pos is not None:
            k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    tp = t + pad
    nc = tp // chunk
    if k_pos is None:
        kv_len = t if kv_len is None else kv_len
        k_pos = jnp.broadcast_to(jnp.arange(tp)[None], (b, tp))
        k_pos = jnp.where(k_pos < kv_len, k_pos, -1)

    q_off = jnp.asarray(q_offset, jnp.int32)
    if q_off.ndim == 0:
        q_off = jnp.broadcast_to(q_off, (b,))
    q_pos2d = q_off[:, None] + jnp.arange(s, dtype=jnp.int32)[None]  # [B,S]

    if USE_FLASH_VJP:
        q_pos_f = q_pos2d.astype(jnp.float32)
        if window is None:
            window_f = jnp.zeros((), jnp.float32)       # disabled
        else:
            window_f = jnp.asarray(window).astype(jnp.float32)
        return _fv.flash_attention(
            q, k, v, k_pos.astype(jnp.float32), q_pos_f, window_f,
            scale, causal, softcap, chunk)

    qg = q.reshape(b, s, hkv, g, d).astype(jnp.float32)

    kc = k.reshape(b, nc, chunk, hkv, d).swapaxes(0, 1)     # [nc,B,c,Hkv,D]
    vc = v.reshape(b, nc, chunk, hkv, dv).swapaxes(0, 1)
    pc = k_pos.reshape(b, nc, chunk).swapaxes(0, 1)         # [nc,B,c]

    def step(carry, xs):
        m, l, acc = carry
        k_c, v_c, p_c = xs
        s_blk = jnp.einsum("bskgd,bckd->bkgsc", qg,
                           k_c.astype(jnp.float32),
                           preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s_blk = softcap * jnp.tanh(s_blk / softcap)
        kp = p_c[:, None, :]                                # [B,1,c]
        qp = q_pos2d[:, :, None]                            # [B,S,1]
        mask = kp >= 0
        if causal:
            mask &= qp >= kp
        if window is not None:
            in_win = (qp - kp) < window
            mask &= in_win if isinstance(window, int) else jnp.logical_or(
                window <= 0, in_win)
        mask_e = mask[:, None, None]                        # [B,1,1,S,c]
        s_blk = jnp.where(mask_e, s_blk, _NEG)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[..., None])
        p = jnp.where(mask_e, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgsc,bckv->bkgsv", p, v_c.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, s), _NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)     # [B,S,Hkv,G,Dv]
    return out.reshape(b, s, h, dv).astype(q.dtype)


# --------------------------------------------------------------------- GQA
def gqa_params(key, cfg, dtype):
    """Weights for one GQA attention block (flattened 2D for packing)."""
    from repro.models import layers as L
    ks = jax.random.split(key, 4)
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": L.init_dense(ks[0], (d, h * hd), dtype=dtype),
        "wk": L.init_dense(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": L.init_dense(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": L.init_dense(ks[3], (h * hd, d), dtype=dtype),
    }


def _update_full_cache(cache, k, v, cache_index, s):
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
    return {"k": ck, "v": cv}, ck, cv, None, cache_index + s, cache_index


def _update_ring_cache(cache, k, v, cache_index, s):
    """SWA ring cache of width W.  Slots hold absolute positions in
    cache['pos'] (-1 = empty); attention masks by position, so slot order
    is irrelevant."""
    w = cache["k"].shape[1]
    b = k.shape[0]
    pos_new = cache_index + jnp.arange(s)
    if s >= w:                      # prefill longer than the window
        k_in, v_in = k[:, -w:], v[:, -w:]
        pos_in = jnp.broadcast_to(pos_new[-w:][None], (b, w))
        ck = k_in.astype(cache["k"].dtype)
        cv = v_in.astype(cache["v"].dtype)
        cp = pos_in
    else:                           # decode (s==1) or short prefill
        slot = cache_index % w
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        cp = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.broadcast_to(pos_new[None], (b, s)), slot,
            axis=1)
    new = {"k": ck, "v": cv, "pos": cp}
    return new, ck, cv, cp, None, cache_index


def _update_paged_cache(cache, k, v, page_size):
    """Continuous-batching paged cache (runtime/kv_cache): scatter the new
    tokens of every slot at its own length, then gather the logical-order
    dense view.  The gathered view has the same length and chunk layout as
    the dense ``[B, max_len]`` cache, and masked positions contribute
    exact zeros, so attention here is bit-identical to the dense path —
    the serving parity gate (tests/test_serving.py) rests on this."""
    from repro.runtime import kv_cache as KV
    pt, lens = cache["page_table"], cache["lens"]
    wm = cache.get("write_mask")
    s = k.shape[1]
    pk = KV.paged_update(cache["pages_k"], k, pt, lens, page_size,
                         write_mask=wm)
    pv = KV.paged_update(cache["pages_v"], v, pt, lens, page_size,
                         write_mask=wm)
    k_d = KV.paged_gather(pk, pt, page_size)
    v_d = KV.paged_gather(pv, pt, page_size)
    t_view = k_d.shape[1]
    k_pos = jnp.arange(t_view, dtype=jnp.int32)[None, :]
    k_pos = jnp.where(k_pos < lens[:, None] + s, k_pos, -1)
    return {"pages_k": pk, "pages_v": pv}, k_d, v_d, k_pos, None, lens


def gqa_attention(p, cfg, x, *, positions, window=None, cache=None,
                  cache_index=None, page_size=None):
    """GQA attention.  cache: dict(k=[B,T,Hkv,D], v=..., pos=... for ring)
    updated at cache_index, or a paged-cache view (pages_k/pages_v +
    page_table/lens, per-slot offsets).  Returns (out, new_cache)."""
    from repro.models import layers as L
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if "wqkv" in p:
        # horizontally fused pack (model_zoo.pack_for_inference): one
        # GEMM pass streams x once and produces all three projections
        q, k, v = L.fused_linear(x, p["wqkv"])
    else:
        q, k, v = (L.linear(x, p["wq"]), L.linear(x, p["wk"]),
                   L.linear(x, p["wv"]))
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    scale = cfg.attn_scale if cfg.attn_scale else hd ** -0.5

    new_cache, k_pos, kv_len, q_offset = None, None, s, 0
    if cache is not None:
        if "pages_k" in cache:
            new_cache, k, v, k_pos, kv_len, q_offset = _update_paged_cache(
                cache, k, v, page_size)
        elif "pos" in cache:
            new_cache, k, v, k_pos, kv_len, q_offset = _update_ring_cache(
                cache, k, v, cache_index, s)
        else:
            new_cache, k, v, k_pos, kv_len, q_offset = _update_full_cache(
                cache, k, v, cache_index, s)

    out = blocked_attention(
        q, k, v, scale=scale, causal=True, window=window,
        softcap=cfg.attn_softcap, kv_len=kv_len, q_offset=q_offset,
        k_pos=k_pos)
    return L.linear(out.reshape(b, s, h * hd), p["wo"]), new_cache


# --------------------------------------------------------------------- MLA
def mla_params(key, cfg, dtype):
    """DeepSeek-V3 Multi-head Latent Attention weights (absorbed layout)."""
    from repro.models import layers as L
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": L.init_dense(ks[0], (d, cfg.q_lora_rank), dtype=dtype),
        "w_uq": L.init_dense(ks[1], (cfg.q_lora_rank, h * (nope + rope_d)),
                             dtype=dtype),
        "w_dkv": L.init_dense(ks[2], (d, cfg.kv_lora_rank), dtype=dtype),
        "w_kr": L.init_dense(ks[3], (d, rope_d), dtype=dtype),
        "w_uk": L.init_dense(ks[4], (cfg.kv_lora_rank, h * nope),
                             dtype=dtype),
        "w_uv": L.init_dense(ks[5], (cfg.kv_lora_rank, h * vd), dtype=dtype),
        "wo": L.init_dense(ks[6], (h * vd, d), dtype=dtype),
    }


def mla_attention(p, cfg, x, *, positions, cache=None, cache_index=None,
                  window=None):
    """Absorbed-form MLA: attention runs as MQA over the compressed latent
    (kv_lora_rank + rope_dim per token) — the cache stores ONLY the latent,
    never expanded K/V.  q_nope is absorbed through W_UK; values are read
    as latent context then expanded through W_UV.  (window unused; MLA
    archs here are full-attention.)"""
    from repro.models import layers as L
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    if "w_dqkr" in p:
        # fused down-projections: q-latent, kv-latent, and k-rope all
        # consume x — one pass instead of three x reads
        cq, ckv, kr = L.fused_linear(x, p["w_dqkr"])
    else:
        cq = L.linear(x, p["w_dq"])
        ckv = L.linear(x, p["w_dkv"])                      # [B,S,r]
        kr = L.linear(x, p["w_kr"])
    q = L.linear(cq, p["w_uq"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)

    krope = L.rope(kr[:, :, None, :], positions,
                   cfg.rope_theta)[:, :, 0]                # [B,S,rope_d]

    # absorb: q_abs[b,s,h,r] = q_nope . W_UK(per head)
    w_uk = p["w_uk"].reshape(r, h, nope)
    dt = L.dot_dtype(x.dtype)
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(dt),
                       w_uk.astype(dt),
                       preferred_element_type=jnp.float32).astype(x.dtype)

    new_cache = None
    if cache is not None:
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_index, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope.astype(cache["krope"].dtype), cache_index,
            axis=1)
        new_cache = {"ckv": cc, "krope": cr}
        ckv_all, krope_all = cc, cr
        kv_len = cache_index + s
        q_offset = cache_index
    else:
        ckv_all, krope_all = ckv, krope
        kv_len, q_offset = s, 0

    # MQA over latent: kv head = 1, key dim = r + rope_d, value = latent (r)
    q_full = jnp.concatenate([q_abs, q_rope], axis=-1)      # [B,S,H,r+rd]
    k_full = jnp.concatenate([ckv_all, krope_all],
                             axis=-1)[:, :, None, :]        # [B,T,1,r+rd]
    v_lat = ckv_all[:, :, None, :]                          # [B,T,1,r]
    ctx = blocked_attention(
        q_full, k_full, v_lat, scale=(nope + rope_d) ** -0.5, causal=True,
        kv_len=kv_len, q_offset=q_offset)                   # [B,S,H,r]

    w_uv = p["w_uv"].reshape(r, h, vd)
    out = jnp.einsum("bshr,rhv->bshv", ctx.astype(dt), w_uv.astype(dt),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return L.linear(out.reshape(b, s, h * vd), p["wo"]), new_cache
