"""Mixture-of-Experts with capacity-based dispatch (static shapes).

Routing: softmax top-k with renormalization (qwen3 / deepseek style; the
deepseek-v3 bias-corrected sigmoid router is simplified to softmax top-k —
recorded in DESIGN.md).  Dispatch avoids the O(T*E*C*d) one-hot einsum:
slot positions come from a cumsum over a (T*k, E) one-hot (int32, no d
factor) and tokens are scatter-added into the (E, C, d) expert buffer —
so compiled FLOPs stay proportional to ACTUAL expert work (capacity * d),
which keeps the roofline MODEL_FLOPS/HLO_FLOPs ratio honest.

Sharding: the expert dim is the 'experts' logical axis (→ model axis, EP);
GSPMD inserts the token all-to-all at the data→expert boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_params(key, cfg, dtype):
    ks = jax.random.split(key, 5)
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": L.init_dense(ks[0], (d, e), dtype=jnp.float32),
        "wi_gate": L.init_dense(ks[1], (e, d, f), dtype=dtype),
        "wi_up": L.init_dense(ks[2], (e, d, f), dtype=dtype),
        "wo": L.init_dense(ks[3], (e, f, d), dtype=dtype),
    }
    if cfg.num_shared_experts:
        sf = cfg.moe_d_ff * cfg.num_shared_experts
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": L.init_dense(sk[0], (d, sf), dtype=dtype),
            "w_up": L.init_dense(sk[1], (d, sf), dtype=dtype),
            "w_down": L.init_dense(sk[2], (sf, d), dtype=dtype),
        }
    return p


def moe_ffn(p, cfg, x, *, capacity_factor: float = 1.25,
            groups: int | None = None, shard_fn=None):
    """x: [B, S, d] -> ([B, S, d], aux_loss).
    Token-drop at capacity (static shapes).

    Dispatch is GROUP-LOCAL (§Perf cell-3): slot assignment (the one-hot
    cumsum) and the scatter into expert buffers run independently per
    token group, with per-group capacity.  With groups = the batch-shard
    count, no dispatch op crosses a batch shard, so GSPMD lowers the
    token→expert movement as an expert-dim all-to-all instead of
    all-gathering the full fp32 token tensor to every device (measured
    3.2 TB/device/step on qwen3 train_4k × multi-pod).  groups=1
    reproduces the global-cumsum baseline.  Per-group capacity is how
    production MoE systems bound dispatch anyway (local capacity ≈
    global/G; imbalance beyond it drops — recorded, not hidden).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    g = groups or getattr(cfg, "moe_dispatch_groups", 0) or 1
    if t % g:
        g = 1
    # capacity-floor guard: per-group capacity can't drop below ~8 slots,
    # so grouping tiny token counts (decode) would inflate total buffer
    # slots ×G (measured 3× on deepseek-v3 decode) — fall back to global.
    if g > 1 and (t // g) * k < 4 * e:
        g = 1
    tg = t // g
    # dispatch/combine constraints only under grouped dispatch; the
    # global path keeps pure propagation (its measured optimum on dsv3)
    shard = (shard_fn if (shard_fn and g > 1) else (lambda a, *n: a))
    xt = shard(x.reshape(g, tg, d), "expert_group", None, None)
    capacity = max(int(tg * k * capacity_factor / e), 4)
    # round capacity to an 8-multiple (TPU sublane) without exceeding tg
    capacity = min(((capacity + 7) // 8) * 8, tg)

    def dispatch_one(xt_g):
        """One group: route, scatter, expert-FFN, combine."""
        logits = L.linear(xt_g.astype(jnp.float32), p["router"])  # [Tg,E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)                 # [Tg, k]
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        flat_e = top_i.reshape(-1)                             # [Tg*k]
        oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # [Tg*k, E]
        pos = jnp.cumsum(oh, axis=0) - 1                       # slot ids
        slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = slot < capacity
        e_idx = jnp.where(keep, flat_e, e)        # OOB -> dropped
        s_idx = jnp.where(keep, slot, capacity)

        xk = jnp.repeat(xt_g, k, axis=0)                       # [Tg*k, d]
        buf = jnp.zeros((e + 1, capacity + 1, d), x.dtype)
        buf = buf.at[e_idx, s_idx].add(xk)
        return (buf[:e, :capacity], e_idx, s_idx, top_p, probs, oh)

    buf, e_idx, s_idx, top_p, probs, oh = jax.vmap(dispatch_one)(xt)
    # buf: [G, E, C, d] — the dispatch writes it group-local (G on the
    # batch axes); the constraint below re-shards E onto `model`, which
    # GSPMD lowers as the expert all-to-all (the GShard pattern), instead
    # of all-gathering tokens to every device.
    buf = shard(buf, "expert_group", "experts", None, None)

    dt = L.dot_dtype(x.dtype)
    hg = jnp.einsum("gecd,edf->gecf", buf.astype(dt),
                    p["wi_gate"].astype(dt),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    hu = jnp.einsum("gecd,edf->gecf", buf.astype(dt),
                    p["wi_up"].astype(dt),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    ho = jnp.einsum("gecf,efd->gecd",
                    (jax.nn.silu(hg) * hu).astype(dt),
                    p["wo"].astype(dt),
                    preferred_element_type=jnp.float32).astype(x.dtype)

    # combine: bring expert outputs back to their token's group shard
    # (the return all-to-all), then gather per-token rows locally
    ho = shard(ho, "expert_group", None, None, None)
    ho = jnp.pad(ho, ((0, 0), (0, 1), (0, 1), (0, 0)))     # OOB row = 0
    out_tok = jax.vmap(lambda h, ei, si: h[ei, si])(ho, e_idx, s_idx)
    out = jnp.sum(out_tok.reshape(g, tg, k, d)
                  * top_p.reshape(g, tg, k, 1).astype(x.dtype), axis=2)
    out = out.reshape(t, d)

    if "shared" in p:
        xt_flat = xt.reshape(t, d)
        sh_p = p["shared"]
        if "w_gate_up" in sh_p:       # horizontally fused gate+up pack
            out = out + L.swiglu_fused(xt_flat, sh_p["w_gate_up"],
                                       sh_p["w_down"], cfg.act)
        else:
            out = out + L.swiglu(xt_flat, sh_p["w_gate"], sh_p["w_up"],
                                 sh_p["w_down"], cfg.act)

    # Switch-style load-balance aux loss, from the probs already computed.
    frac_tokens = jnp.mean(oh.astype(jnp.float32).reshape(t, k, e),
                           axis=(0, 1)) * k
    frac_probs = jnp.mean(probs.reshape(t, e), axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(b, s, d), aux
