"""Model substrate: layers, attention variants, MoE, SSM, decoder stacks."""
