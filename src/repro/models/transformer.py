"""Decoder stack: one scan-over-layers body serving all four families
(dense / moe / ssm / hybrid) and all three phases (train / prefill /
decode).

Layer heterogeneity (gemma2's local/global alternation) is expressed as
*data*, not structure: a per-layer int32 window array rides the scan as xs
(-1 = full attention), so the stacked-parameter scan body stays uniform and
the HLO stays O(1) in depth — required to keep 61-layer MoE dry-run
compiles tractable on the CPU host.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ----------------------------------------------------------------- params
def _init_layer(key, cfg, dtype):
    ks = jax.random.split(key, 8)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if cfg.attention_kind == "gqa":
        p["attn"] = A.gqa_params(ks[0], cfg, dtype)
    elif cfg.attention_kind == "mla":
        p["attn"] = A.mla_params(ks[0], cfg, dtype)
    elif cfg.attention_kind == "parallel_ssm":
        p["attn"] = A.gqa_params(ks[0], cfg, dtype)
        p["mamba"] = S.mamba_params(ks[1], cfg, dtype)
        p["ln_attn_out"] = jnp.ones((cfg.d_model,), dtype)
        p["ln_ssm_out"] = jnp.ones((cfg.d_model,), dtype)
    elif cfg.attention_kind == "none":
        p["mamba"] = S.mamba_params(ks[1], cfg, dtype)
    if cfg.post_norms:
        p["ln1_post"] = jnp.ones((cfg.d_model,), dtype)
        p["ln2_post"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.family == "moe":
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["moe"] = M.moe_params(ks[2], cfg, dtype)
    elif cfg.d_ff:
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = {
            "w_gate": L.init_dense(ks[3], (cfg.d_model, cfg.d_ff),
                                   dtype=dtype),
            "w_up": L.init_dense(ks[4], (cfg.d_model, cfg.d_ff),
                                 dtype=dtype),
            "w_down": L.init_dense(ks[5], (cfg.d_ff, cfg.d_model),
                                   dtype=dtype),
        }
    return p


def init_params(cfg, key):
    ks = jax.random.split(key, 4)
    dtype = cfg.pdtype
    params = {}
    if cfg.modality == "text":
        params["embed"] = L.init_dense(ks[0], (cfg.vocab_size, cfg.d_model),
                                       scale=1.0, dtype=dtype)
    layer_keys = jax.random.split(ks[1], cfg.num_layers)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not (cfg.tie_embeddings and cfg.modality == "text"):
        params["lm_head"] = L.init_dense(ks[2], (cfg.d_model, cfg.vocab_size),
                                         dtype=dtype)
    return params


def window_pattern(cfg) -> np.ndarray:
    """Per-layer attention window (int32; -1 = full attention)."""
    lyr = cfg.num_layers
    if cfg.local_global_period > 0:
        w = np.full((lyr,), -1, np.int32)
        for i in range(lyr):
            if i % cfg.local_global_period != cfg.local_global_period - 1:
                w[i] = cfg.window
        return w
    if cfg.window is not None:
        return np.full((lyr,), cfg.window, np.int32)
    return np.full((lyr,), -1, np.int32)


# ------------------------------------------------------------------ cache
def init_cache(cfg, batch: int, max_len: int):
    """Pre-allocated decode cache (stacked over layers for the scan)."""
    lyr, dtype = cfg.num_layers, jnp.dtype(cfg.cache_dtype)
    c = {}
    if cfg.attention_kind in ("gqa", "parallel_ssm"):
        t = (min(cfg.window, max_len) if cfg.resolved_cache_kind == "window"
             else max_len)
        c["k"] = jnp.zeros((lyr, batch, t, cfg.num_kv_heads, cfg.head_dim),
                           dtype)
        c["v"] = jnp.zeros_like(c["k"])
        if cfg.resolved_cache_kind == "window":
            c["pos"] = jnp.full((lyr, batch, t), -1, jnp.int32)
    if cfg.attention_kind == "mla":
        c["ckv"] = jnp.zeros((lyr, batch, max_len, cfg.kv_lora_rank), dtype)
        c["krope"] = jnp.zeros((lyr, batch, max_len, cfg.qk_rope_dim), dtype)
    if cfg.attention_kind in ("none", "parallel_ssm"):
        conv_dim = (cfg.ssm_heads * cfg.ssm_head_dim
                    + 2 * cfg.ssm_groups * cfg.ssm_state)
        c["state"] = jnp.zeros(
            (lyr, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
        c["conv"] = jnp.zeros((lyr, batch, cfg.conv_width - 1, conv_dim),
                              dtype)
    return {"layers": c, "index": jnp.zeros((), jnp.int32)}


# ------------------------------------------------------------------ layers
def _layer_forward(cfg, lp, x, *, window_l, positions, cache_l, cache_index,
                   mode, shard_fn=None, page_size=None):
    """One decoder layer.  Returns (x, new_cache_l, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.norm_plus_one)

    attn_cache = None
    if cache_l is not None and "pages_k" in cache_l:
        attn_cache = cache_l          # paged view incl. page_table/lens
    elif cache_l is not None and "k" in cache_l:
        attn_cache = {k: cache_l[k] for k in ("k", "v", "pos")
                      if k in cache_l}
    if cache_l is not None and "ckv" in cache_l:
        attn_cache = {"ckv": cache_l["ckv"], "krope": cache_l["krope"]}
    ssm_cache = None
    if cache_l is not None and "state" in cache_l:
        ssm_cache = {"state": cache_l["state"], "conv": cache_l["conv"]}

    if cfg.attention_kind == "gqa":
        out, nc = A.gqa_attention(lp["attn"], cfg, h, positions=positions,
                                  window=window_l, cache=attn_cache,
                                  cache_index=cache_index,
                                  page_size=page_size)
        if nc:
            new_cache.update(nc)
    elif cfg.attention_kind == "mla":
        out, nc = A.mla_attention(lp["attn"], cfg, h, positions=positions,
                                  cache=attn_cache, cache_index=cache_index)
        if nc:
            new_cache.update(nc)
    elif cfg.attention_kind == "parallel_ssm":
        a_out, nca = A.gqa_attention(lp["attn"], cfg, h, positions=positions,
                                     window=window_l, cache=attn_cache,
                                     cache_index=cache_index)
        s_out, ncs = S.mamba_forward(lp["mamba"], cfg, h, cache=ssm_cache,
                                     mode=mode)
        out = 0.5 * (L.rms_norm(a_out, lp["ln_attn_out"], cfg.norm_eps)
                     + L.rms_norm(s_out, lp["ln_ssm_out"], cfg.norm_eps))
        if nca:
            new_cache.update(nca)
        if ncs:
            new_cache.update(ncs)
    else:                                      # "none": pure SSM mixer
        out, ncs = S.mamba_forward(lp["mamba"], cfg, h, cache=ssm_cache,
                                   mode=mode)
        if ncs:
            new_cache.update(ncs)

    if cfg.post_norms:
        out = L.rms_norm(out, lp["ln1_post"], cfg.norm_eps,
                         cfg.norm_plus_one)
    x = x + out

    if cfg.family == "moe":
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.norm_plus_one)
        out2, aux = M.moe_ffn(lp["moe"], cfg, h2,
                              capacity_factor=cfg.capacity_factor,
                              shard_fn=shard_fn)
        if cfg.post_norms:
            out2 = L.rms_norm(out2, lp["ln2_post"], cfg.norm_eps,
                              cfg.norm_plus_one)
        x = x + out2
    elif cfg.d_ff:
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.norm_plus_one)
        ffn = lp["ffn"]
        if "w_gate_up" in ffn:
            # fused gate+up pack: one pass, glu combine in the store
            # step; in pre-norm blocks the residual add rides the
            # down-projection's epilogue as well
            if cfg.post_norms:
                out2 = L.swiglu_fused(h2, ffn["w_gate_up"], ffn["w_down"],
                                      cfg.act)
                out2 = L.rms_norm(out2, lp["ln2_post"], cfg.norm_eps,
                                  cfg.norm_plus_one)
                x = x + out2
            else:
                x = L.swiglu_fused(h2, ffn["w_gate_up"], ffn["w_down"],
                                   cfg.act, residual=x)
        else:
            out2 = L.swiglu(h2, ffn["w_gate"], ffn["w_up"],
                            ffn["w_down"], cfg.act)
            if cfg.post_norms:
                out2 = L.rms_norm(out2, lp["ln2_post"], cfg.norm_eps,
                                  cfg.norm_plus_one)
            x = x + out2

    return x, (new_cache or None), aux


# ----------------------------------------------------------------- forward
def forward(cfg, params, inputs, *, cache=None, mode: str = "train",
            logits_mode: str = "all", shard_fn=None, page_size=None,
            logit_index=None):
    """Run the stack.

    inputs: int tokens [B, S] (text) or embeddings [B, S, d] (stub
    frontends).  mode: train | prefill | decode.  Returns
    (logits, new_cache, aux_loss).  shard_fn: optional activation
    sharding-constraint hook (parallel/sharding.activation_sharder).

    A *paged* cache (dict with ``page_table``/``lens``, see
    runtime/kv_cache) serves the continuous-batching pool: positions are
    per-slot (``lens[b] + i``) and KV reads/writes go through the slot's
    page table, so one static-shape trace serves every mix of slot
    progress.  ``logits_mode="index"`` computes logits for the single row
    ``logit_index`` (traced) — the last *real* row of a padded prefill
    chunk.
    """
    assert mode in ("train", "prefill", "decode")
    assert logits_mode in ("all", "last", "none", "index")
    shard = shard_fn or (lambda x, *names: x)
    if cfg.modality == "text":
        x = L.embed_tokens(params["embed"], inputs).astype(cfg.cdtype)
    else:
        x = inputs.astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    x = shard(x, "batch", "seq", "d_model")

    paged = cache is not None and "page_table" in cache
    s = x.shape[1]
    if paged:
        if cfg.attention_kind != "gqa":
            raise NotImplementedError(
                f"paged decode supports GQA archs; got "
                f"{cfg.attention_kind!r}")
        if page_size is None:
            raise ValueError("paged cache needs page_size")
        cache_index = None
        positions = (cache["lens"][:, None]
                     + jnp.arange(s, dtype=jnp.int32)[None])    # [B, S]
    else:
        cache_index = cache["index"] if cache is not None else 0
        positions = (jnp.arange(s) if mode != "decode"
                     else cache_index + jnp.arange(s))
    w_arr = jnp.asarray(window_pattern(cfg))

    cache_layers = cache["layers"] if cache is not None else None
    has_cache = cache_layers is not None
    # Slot bookkeeping rides OUTSIDE the per-layer subtree: every layer
    # sees the same page_table/lens/write_mask, only the pages differ.
    paged_extra = None
    if paged:
        paged_extra = {k: cache[k] for k in
                       ("page_table", "lens", "write_mask") if k in cache}

    # Cache rides the scan CARRY and is updated in place per layer
    # (dynamic_update_index on the stacked buffers).  The xs/ys
    # alternative stacks a fresh copy of the whole cache every layer —
    # XLA materializes the ys buffer per iteration (+2 × cache bytes of
    # HBM traffic per layer, the dominant decode term; §Perf C3).
    def body(carry, xs):
        x, aux, cl = carry
        lp, w_l, li = xs
        c_l = (None if cl is None else
               jax.tree.map(lambda buf: jax.lax.dynamic_index_in_dim(
                   buf, li, 0, keepdims=False), cl))
        if c_l is not None and paged_extra is not None:
            c_l = {**c_l, **paged_extra}
        x, new_c, a = _layer_forward(
            cfg, lp, x, window_l=w_l, positions=positions, cache_l=c_l,
            cache_index=cache_index, mode=mode, shard_fn=shard,
            page_size=page_size)
        if new_c is not None:
            cl = jax.tree.map(
                lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                    buf, new.astype(buf.dtype), li, 0), cl, new_c)
        x = shard(x, "batch", "seq", "d_model")
        return (x, aux + a, cl), None

    if mode == "train" and cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)

    xs = (params["layers"], w_arr, jnp.arange(cfg.num_layers))
    (x, aux, new_cache_layers), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), cache_layers), xs)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.norm_plus_one)
    if logits_mode == "last":
        x = x[:, -1:]
    elif logits_mode == "index":
        # single-row head, same [B, 1, d] GEMM shape as "last" — keeps
        # the padded-final-chunk logits bit-identical to one-shot prefill
        x = jax.lax.dynamic_slice_in_dim(x, logit_index, 1, axis=1)
    head = (params["embed"].T if (cfg.tie_embeddings
                                  and cfg.modality == "text")
            else params["lm_head"])
    logits = None
    if logits_mode != "none":
        from repro.core.packing import PackedWeight
        if isinstance(head, PackedWeight) and cfg.logit_softcap:
            # packed LM head: the tanh softcap runs on the fp32
            # accumulator inside the GEMM's store step — the full-vocab
            # logits tensor is written to HBM exactly once, capped
            logits = L.linear(x, head, softcap=cfg.logit_softcap,
                              out_dtype=jnp.float32)
        else:
            logits = L.linear(x, head)
            logits = L.softcap(logits.astype(jnp.float32),
                               cfg.logit_softcap)
        logits = shard(logits, "batch", "seq", "vocab")

    new_cache = None
    if has_cache:
        if paged:
            # lens/page_table are host-owned (the scheduler advances
            # them between steps); pass through unchanged
            new_cache = dict(cache, layers=new_cache_layers)
        else:
            new_cache = {"layers": new_cache_layers,
                         "index": cache_index + s}
    return logits, new_cache, aux / cfg.num_layers


def loss_fn(cfg, params, batch, *, shard_fn=None, aux_weight: float = 0.01):
    """Mean next-token CE (+ MoE load-balance aux)."""
    logits, _, aux = forward(cfg, params, batch["inputs"], mode="train",
                             logits_mode="all", shard_fn=shard_fn)
    ce = L.cross_entropy(logits, batch["labels"])
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def prefill(cfg, params, inputs, *, max_len: int, shard_fn=None):
    """Batched prefill: build the cache, return last-token logits."""
    b = inputs.shape[0]
    cache = init_cache(cfg, b, max_len)
    logits, cache, _ = forward(cfg, params, inputs, cache=cache,
                               mode="prefill", logits_mode="last",
                               shard_fn=shard_fn)
    return logits[:, 0], cache


def decode_step(cfg, params, cache, tokens, *, shard_fn=None):
    """One decode step.  tokens: [B, 1] ids or [B, 1, d] embeds."""
    logits, cache, _ = forward(cfg, params, tokens, cache=cache,
                               mode="decode", logits_mode="last",
                               shard_fn=shard_fn)
    return logits[:, 0], cache


# -------------------------------------------- continuous-batching steps
def prefill_chunk(cfg, params, cache, tokens, *, page_size, logit_index,
                  shard_fn=None):
    """One chunked-prefill admission step against a paged cache.

    tokens: [B, C] — a fixed-width chunk of one (or more) prompts, padded
    past the prompt end; the pad rows' KV lands beyond the slot's length
    counter and is either masked or overwritten before it is ever read.
    Returns (logits [B, V] for row ``logit_index``, cache) — callers use
    the logits only on a prompt's final chunk.
    """
    logits, cache, _ = forward(cfg, params, tokens, cache=cache,
                               mode="prefill", logits_mode="index",
                               logit_index=logit_index,
                               page_size=page_size, shard_fn=shard_fn)
    return logits[:, 0], cache


def paged_decode_step(cfg, params, cache, tokens, *, page_size,
                      shard_fn=None):
    """One decode step for the whole slot pool against a paged cache:
    per-slot positions come from ``cache['lens']``; slots outside
    ``cache['write_mask']`` (idle / still prefilling) write nothing and
    their logits are discarded by the scheduler."""
    logits, cache, _ = forward(cfg, params, tokens, cache=cache,
                               mode="decode", logits_mode="last",
                               page_size=page_size, shard_fn=shard_fn)
    return logits[:, 0], cache
