"""Flash-style custom VJP for blocked attention — §Perf iteration B.

Problem (measured in the dry-run walker): reverse-mode through the
KV-chunk `lax.scan` of models/attention.blocked_attention saves every
per-chunk residual — the (S × chunk) score/probability blocks, stacked
over chunks — i.e. the full quadratic (S × T) score matrix in fp32, per
layer, per microbatch.  That made every train_4k cell memory-bound
(e.g. hymba train: 67 s memory term vs 1.4 s compute).

Fix: the FlashAttention backward.  Forward saves only (q, k, v, out,
lse) — O(S·D) — and the backward recomputes each chunk's scores from
q·kᵀ and the saved log-sum-exp:

    p_ij   = exp(s_ij − lse_i)
    dv_j   = Σ_i p_ij · do_i
    Δ_i    = Σ_d do_i · out_i
    ds_ij  = p_ij · (do_i · v_j − Δ_i)       (× tanh-softcap jacobian)
    dq_i  += scale · Σ_j ds_ij · k_j          (accumulated over chunks)
    dk_j   = scale · Σ_i ds_ij · q_i
    dv, dk are per-chunk outputs; dq is the scan carry.

Same masking semantics as the forward (causal / sliding window /
explicit k_pos ring slots / tanh softcap).  Traced integer auxiliaries
(positions, window, kv_len) enter as float arrays so custom_vjp
cotangents stay well-typed; they get zero gradients.

Validated against jax.grad of the reference scan implementation in
tests/test_flash_vjp.py (allclose, fp32).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

_NEG = -1e30


def _dot_dtype(native):
    from repro.models.layers import dot_dtype
    return dot_dtype(native)


def _slice_chunk(x, i, c):
    """Chunk i of x along the T axis (axis 1), via dynamic_slice.

    §Perf iteration C2: the earlier reshape+swapaxes restack copied (and
    fp32-hoisted) the ENTIRE cache once per layer — 2×cache bytes of HBM
    traffic per decode step (dominant on every decode cell).  Scanning
    over chunk INDICES and slicing in place reads each cache byte once,
    which is also exactly what the Pallas kernel's BlockSpec index_map
    does on TPU.
    """
    return jax.lax.dynamic_slice_in_dim(x, i * c, c, axis=1)


def _scores(qg, k_c, p_c, q_pos, *, scale, causal, window, softcap):
    """Score block + mask for one KV chunk.

    qg: [B,S,Hkv,G,D] in cache dtype; k_c: [B,c,Hkv,D]; p_c: [B,c] float
    positions.  The QK dot consumes the operands' native dtype with fp32
    accumulation (MXU semantics) — casting k_c up per chunk invites XLA
    to hoist an fp32 round-trip of the ENTIRE cache across the update
    (measured +32 GB/layer on decode_32k).  Returns (s_blk [B,Hkv,G,S,c]
    post-softcap fp32, mask [B,1,1,S,c], tanh(s/cap) or None).
    """
    s_blk = jnp.einsum("bskgd,bckd->bkgsc", qg, k_c,
                       preferred_element_type=jnp.float32) * scale
    t = None
    if softcap is not None:
        t = jnp.tanh(s_blk / softcap)
        s_blk = softcap * t
    kp = p_c[:, None, :]
    qp = q_pos[:, :, None]
    mask = kp >= 0.0
    if causal:
        mask &= qp >= kp
    # window: scalar float; <= 0 disables
    in_win = (qp - kp) < window
    mask &= jnp.logical_or(window <= 0.0, in_win)
    return s_blk, mask[:, None, None], t


def _fwd_core(q, k, v, k_pos, q_pos, window, *, scale, causal, softcap,
              chunk):
    # named_scope labels every HLO op from this region so the roofline
    # walker can bucket "attention-intermediate" HBM traffic — on TPU the
    # Pallas kernel (kernels/flash_attention.py) keeps these blocks in
    # VMEM, so §Roofline reports the XLA-path term AND the
    # kernel-adjusted term (see launch/dryrun.py).
    with jax.named_scope("flash_attn_fwd"):
        return _fwd_core_inner(q, k, v, k_pos, q_pos, window, scale=scale,
                               causal=causal, softcap=softcap, chunk=chunk)


def _fwd_core_inner(q, k, v, k_pos, q_pos, window, *, scale, causal,
                    softcap, chunk):
    b, s, h, d = q.shape
    _, t, hkv, _ = k.shape
    dv_ = v.shape[-1]
    g = h // hkv
    nc = t // chunk
    # QK/PV dots consume the cache dtype directly (fp32 accumulate);
    # casting the cache up per chunk costs an fp32 cache round-trip.
    dt = _dot_dtype(k.dtype)
    qg = q.reshape(b, s, hkv, g, d).astype(dt)

    def step(carry, i):
        m, l, acc = carry
        k_c = _slice_chunk(k, i, chunk).astype(dt)
        v_c = _slice_chunk(v, i, chunk).astype(dt)
        p_c = _slice_chunk(k_pos, i, chunk)
        s_blk, mask, _ = _scores(qg, k_c, p_c, q_pos, scale=scale,
                                 causal=causal, window=window,
                                 softcap=softcap)
        s_blk = jnp.where(mask, s_blk, _NEG)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p = jnp.where(mask, jnp.exp(s_blk - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgsc,bckv->bkgsv", p.astype(dt), v_c,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, s), _NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, dv_), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nc))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4)
    lse = m + jnp.log(l_safe)                       # [B,Hkv,G,S]
    return out.reshape(b, s, h, dv_).astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def flash_attention(q, k, v, k_pos, q_pos, window, scale, causal, softcap,
                    chunk):
    """out = softmax(mask(q·kᵀ))·v with O(S·D) VJP residuals.

    q: [B,S,H,D]; k,v: [B,T,Hkv,Dv]; k_pos: [B,T] float (−1 = empty
    slot); q_pos: [B,S] float; window: float scalar (<=0 = full).
    T must be a multiple of ``chunk`` (caller pads; pad slots get
    k_pos = −1).
    """
    out, _ = _fwd_core(q, k, v, k_pos, q_pos, window, scale=scale,
                       causal=causal, softcap=softcap, chunk=chunk)
    return out


def _fwd(q, k, v, k_pos, q_pos, window, scale, causal, softcap, chunk):
    out, lse = _fwd_core(q, k, v, k_pos, q_pos, window, scale=scale,
                         causal=causal, softcap=softcap, chunk=chunk)
    return out, (q, k, v, k_pos, q_pos, window, out, lse)


def _bwd(scale, causal, softcap, chunk, res, d_out):
    with jax.named_scope("flash_attn_bwd"):
        return _bwd_inner(scale, causal, softcap, chunk, res, d_out)


def _bwd_inner(scale, causal, softcap, chunk, res, d_out):
    q, k, v, k_pos, q_pos, window, out, lse = res
    b, s, h, d = q.shape
    _, t, hkv, dv_ = v.shape
    g = h // hkv
    nc = t // chunk
    dt = _dot_dtype(k.dtype)
    qg = q.reshape(b, s, hkv, g, d).astype(dt)
    do = d_out.reshape(b, s, hkv, g, dv_).astype(dt)
    og = out.reshape(b, s, hkv, g, dv_)
    delta = jnp.einsum("bskgv,bskgv->bskg", do, og,
                       preferred_element_type=jnp.float32)
    delta = delta.transpose(0, 2, 3, 1)             # [B,Hkv,G,S]

    def step(dq_acc, i):
        k_c = _slice_chunk(k, i, chunk).astype(dt)
        v_c = _slice_chunk(v, i, chunk).astype(dt)
        p_c = _slice_chunk(k_pos, i, chunk)
        s_blk, mask, tanh_t = _scores(qg, k_c, p_c, q_pos, scale=scale,
                                      causal=causal, window=window,
                                      softcap=softcap)
        p = jnp.where(mask, jnp.exp(s_blk - lse[..., None]), 0.0)
        p_lo = p.astype(dt)                         # dot-operand dtype
        # dv_j = sum_i p_ij do_i
        dv_c = jnp.einsum("bkgsc,bskgv->bckv", p_lo, do,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bskgv,bckv->bkgsc", do, v_c,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        if softcap is not None:
            ds = ds * (1.0 - tanh_t * tanh_t)       # softcap jacobian
        ds_lo = ds.astype(dt)
        dq_acc = dq_acc + jnp.einsum(
            "bkgsc,bckd->bskgd", ds_lo, k_c,
            preferred_element_type=jnp.float32) * scale
        dk_c = jnp.einsum("bkgsc,bskgd->bckd", ds_lo, qg,
                          preferred_element_type=jnp.float32) * scale
        return dq_acc, (dk_c.astype(k.dtype), dv_c.astype(v_c.dtype))

    dq0 = jnp.zeros((b, s, hkv, g, d), jnp.float32)
    dq, (dkc, dvc) = jax.lax.scan(step, dq0, jnp.arange(nc))
    dk = dkc.swapaxes(0, 1).reshape(b, t, hkv, d).astype(k.dtype)
    dv = dvc.swapaxes(0, 1).reshape(b, t, hkv, dv_).astype(v.dtype)
    dq = dq.reshape(b, s, h, d).astype(q.dtype)
    zero = lambda x: jnp.zeros_like(x)
    return dq, dk, dv, zero(k_pos), zero(q_pos), zero(window)


flash_attention.defvjp(_fwd, _bwd)
