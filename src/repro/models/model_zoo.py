"""Model zoo: arch registry, reduced smoke configs, input specs, and the
load-time weight pack (paper lever 2 applied to a whole model).

``input_specs`` returns ShapeDtypeStruct stand-ins only — the full-scale
configs are never allocated on this host; they exist solely to be lowered
+ compiled in launch/dryrun.py.  ``[audio]``/``[vlm]`` archs get stub
frontends per the assignment: precomputed frame/patch embeddings
[B, S, d_model] instead of token ids.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro import gemm as gemm_api
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.core import packing
from repro.models import transformer

ARCHS = {
    "musicgen-medium": "musicgen_medium",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-7b": "deepseek_7b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "gemma2-9b": "gemma2_9b",
    "stablelm-3b": "stablelm_3b",
    "internvl2-76b": "internvl2_76b",
    "mamba2-370m": "mamba2_370m",
    "hymba-1.5b": "hymba_1_5b",
}

# The paper's Table 3: twelve LLM prefill GEMMs at M = S = 128, as
# (model, op, N, K).  These drive benchmarks/table3 and the GEMM tests.
PAPER_GEMM_SHAPES = [
    ("gpt2-style", "qkv", 2048, 2048),
    ("gpt2-style", "ffn1", 8192, 2048),
    ("gpt2-style", "ffn2", 2048, 8192),
    ("gpt2-style", "lm_head", 60000, 2048),
    ("tinyllama-1.1b", "qkv", 2048, 2048),
    ("tinyllama-1.1b", "ffn1", 5632, 2048),
    ("tinyllama-1.1b", "ffn2", 2048, 5632),
    ("tinyllama-1.1b", "lm_head", 32000, 2048),
    ("llama-7b", "qkv", 4096, 4096),
    ("llama-7b", "ffn1", 11008, 4096),
    ("llama-7b", "ffn2", 4096, 11008),
    ("llama-7b", "lm_head", 32000, 4096),
]
PAPER_M = 128

# long_500k applicability (DESIGN.md §6): sub-quadratic decode state only.
LONG_CONTEXT_ARCHS = {"mamba2-370m", "hymba-1.5b", "h2o-danube-3-4b"}


def list_archs() -> list[str]:
    return list(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {list(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.CONFIG


def cells(include_skipped: bool = False):
    """All (arch, shape) assignment cells; skips carry a reason."""
    out = []
    for arch in ARCHS:
        for sname, shape in SHAPES.items():
            skip = None
            if sname == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                skip = ("full-attention arch: 524k-context decode cache / "
                        "quadratic prefill out of serving budget "
                        "(DESIGN.md §6)")
            if skip is None or include_skipped:
                out.append((arch, sname, skip))
    return out


# ----------------------------------------------------------- reduced configs
def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving smoke config: tiny widths, same structure."""
    kw: dict = dict(
        name=cfg.name + "-smoke", num_layers=2, d_model=64,
        vocab_size=128, remat=False,
    )
    if cfg.attention_kind in ("gqa", "parallel_ssm"):
        kw.update(num_heads=4, num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
                  head_dim=16)
    if cfg.attention_kind == "mla":
        kw.update(num_heads=4, num_kv_heads=4, head_dim=24, q_lora_rank=32,
                  kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
                  v_head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.family == "moe":
        kw.update(num_experts=8, experts_per_token=2, moe_d_ff=64)
    if cfg.ssm_heads:
        kw.update(ssm_heads=4, ssm_head_dim=16, ssm_state=8, ssm_chunk=16)
    if cfg.window is not None:
        kw.update(window=32)
    return dataclasses.replace(cfg, **kw)


# ------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the step the
    shape exercises (train_step / prefill / decode serve_step)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    stub = cfg.modality != "text"
    if shape.kind == "train":
        inputs = (_sds((b, s, cfg.d_model), cfg.cdtype) if stub
                  else _sds((b, s), jnp.int32))
        return {"inputs": inputs, "labels": _sds((b, s), jnp.int32)}
    if shape.kind == "prefill":
        inputs = (_sds((b, s, cfg.d_model), cfg.cdtype) if stub
                  else _sds((b, s), jnp.int32))
        return {"inputs": inputs}
    # decode: one new token against a seq_len-deep cache
    tokens = (_sds((b, 1, cfg.d_model), cfg.cdtype) if stub
              else _sds((b, 1), jnp.int32))
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, shape.seq_len))
    return {"tokens": tokens, "cache": cache}


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocation (dry-run input)."""
    return jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.key(0)))


def build(cfg: ModelConfig, seed: int = 0):
    """Real parameter init (smoke tests / examples)."""
    return transformer.init_params(cfg, jax.random.key(seed))


# ------------------------------------------------- load-time pack (lever 2)
# 2-D projection weights that route through core.panel_gemm when packed.
_PACKABLE = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "w_dq", "w_uq",
    "w_dkv", "w_kr", "in_proj", "out_proj", "lm_head",
}
# Deliberately unpacked: embed (gather), router (small, fp32), MoE expert
# banks (3-D batched einsum — packed per-expert form is a §Perf item),
# MLA absorbed factors w_uk/w_uv (consumed reshaped to (r, H, d) inside the
# einsum, not through `linear`), conv/norm vectors.

# Horizontal fusion groups: same-input projections concatenated along N
# into ONE pack at load (``packing.pack_fused``), so one kernel pass
# streams the shared activations once.  The fused key is what the model
# layers branch on (``attention.gqa_attention``: "wqkv";
# ``transformer._layer_forward``: "w_gate_up"; ``mla_attention``:
# "w_dqkr").  glu groups combine in the kernel store step, so their pack
# blocks reserve VMEM for the two-accumulator epilogue.
_FUSE_GROUPS = (
    (("wq", "wk", "wv"), "wqkv", None),
    (("w_gate", "w_up"), "w_gate_up", "glu"),
    (("w_dq", "w_dkv", "w_kr"), "w_dqkr", None),
)


# keep_fp32 aliases: user-facing role names -> the param keys they pin.
_KEEP_FP32_ALIASES = {"head": ("lm_head",), "embed": ("embed",)}


def _resolve_keep_fp32(keep_fp32) -> frozenset:
    names: set[str] = set()
    for entry in keep_fp32 or ():
        names.update(_KEEP_FP32_ALIASES.get(entry, (entry,)))
    return frozenset(names)


def pack_for_inference(cfg: ModelConfig, params, *, block_n=None,
                       block_k=None, shardings=None,
                       m_hint: int = PAPER_M, fuse: bool = True,
                       quant: str | None = None,
                       keep_fp32=("head", "embed")) -> dict:
    """Pack every projection weight once at model load (paper §3.2).

    The per-weight (block_n, block_k) decision is the dispatch POLICY's
    (``gemm.pack_blocks``): each weight's (N, K) resolves a plan at
    ``m_hint`` rows (the paper's S = 128 prefill panel), so K >= N
    projections get occupancy-sized fine column panels and N > K
    projections get the deep-K pre-pack blocks.  Explicit ``block_n`` /
    ``block_k`` still override (benchmark sweeps).

    ``fuse=True`` (the default) additionally fuses same-input projection
    groups horizontally (``_FUSE_GROUPS``): Q/K/V (and MLA's three
    down-projections) become one pack with a static split map, and
    gate+up become one pack whose glu combine runs inside the kernel
    store step — the prefill/decode hot paths then emit one GEMM where
    they emitted three.  ``fuse=False`` is the A/B escape hatch
    (``launch/serve.py --no-fusion``).

    ``quant`` ("int8" | "ternary") is the MIXED-PRECISION tree rewrite
    (repro.quant): every packable projection quantizes at pack time —
    fused groups included — EXCEPT the roles named by ``keep_fp32``
    ("head" -> lm_head, "embed" -> the embedding table, or literal
    param names), which keep the fp32 pack.  The default pins the LM
    head and embeddings, the two spots where quantization error lands
    directly on the logits.  Each concrete quantized pack is measured
    and tolerance-gated by the error ledger (docs/quantization.md).

    Stacked per-layer weights (L, K, N) pack along their last two dims;
    lax.scan slices the leading dim, so inside the scan body each
    PackedWeight carries the 2-D panels the kernel consumes.  ``shardings``
    (a matching pytree) re-places each packed array so no resharding
    appears per call.
    """
    keep = _resolve_keep_fp32(keep_fp32)
    if quant is not None:
        from repro.quant.formats import _check_fmt
        _check_fmt(quant)

    def blocks_for(n, k, epilogue=None, fmt=None):
        # explicit overrides keep the legacy fit-to-dim behavior
        bn = packing.fit_block(n, block_n) if block_n else None
        bk = packing.fit_block(k, block_k) if block_k else None
        return gemm_api.pack_blocks(n, k, m_hint=m_hint,
                                    block_n=bn, block_k=bk,
                                    epilogue=epilogue,
                                    weight_format=fmt or "fp32")

    def place_pw(pw, shard_node):
        if shard_node is None:
            return pw
        from repro.quant.formats import SparseTernaryPackedWeight
        if isinstance(pw, SparseTernaryPackedWeight):
            # shardings were derived from the abstract (eval_shape) tree,
            # and abstract packs never compress — the dense-layout specs
            # don't apply to the data-dependent occupied-group slab.
            # Leave the compressed pack unplaced: jit replicates it, and
            # the slab is small by construction (that's the point).
            return pw
        kw = {}
        if isinstance(shard_node, packing.PackedWeight):
            if shard_node.data is not None:
                kw["data"] = jax.device_put(pw.data, shard_node.data)
            scales_s = getattr(shard_node, "scales", None)
            if scales_s is not None and getattr(pw, "scales",
                                                None) is not None:
                kw["scales"] = jax.device_put(pw.scales, scales_s)
        else:
            kw["data"] = jax.device_put(pw.data, shard_node)
        return dataclasses.replace(pw, **kw) if kw else pw

    def pack_one(name, node, shard_node):
        fmt = quant if (quant and name not in keep) else None
        if node.ndim == 3:                          # stacked (L, K, N)
            _, k, n = node.shape
            bn, bk = blocks_for(n, k, fmt=fmt)
            if fmt:
                from repro.quant.formats import quantize_pack
                pw = quantize_pack(node, fmt, block_n=bn, block_k=bk)
            else:
                data = jnp.pad(node,
                               ((0, 0), (0, (-k) % bk), (0, (-n) % bn)))
                pw = packing.PackedWeight(data=data, n=n, k=k,
                                          block_n=bn, block_k=bk)
            return place_pw(pw, shard_node)
        k, n = node.shape
        bn, bk = blocks_for(n, k, fmt=fmt)
        pw = packing.pack(node, block_n=bn, block_k=bk, quant=fmt)
        return place_pw(pw, shard_node)

    def pack_group(group, nodes, shard_node, glu: bool):
        k = nodes[0].shape[-2]
        n_cat = sum(int(w.shape[-1]) for w in nodes)
        # a group quantizes only when every member is quantizable (a
        # keep_fp32 member pins the whole fused pack to fp32)
        fmt = quant if (quant and not any(g in keep for g in group)) \
            else None
        # glu packs budget VMEM for the two-tile/two-accumulator store
        # phase, under the activation the layer will actually execute
        # (vmem_bytes already reserves bias/residual operand headroom
        # unconditionally, so pack-time and execute-time footprints
        # agree whatever else the layer attaches)
        spec = gemm_api.EpilogueSpec(glu=cfg.act) if glu else None
        bn, bk = blocks_for(n_cat, k, epilogue=spec, fmt=fmt)
        pw = packing.pack_fused(list(nodes), block_n=bn, block_k=bk,
                                quant=fmt)
        return place_pw(pw, shard_node)

    def walk(path, node, shard_node):
        if isinstance(node, dict):
            shard = shard_node if isinstance(shard_node, dict) else {}
            out = {}
            done = set()
            if fuse:
                for group, fused_name, glu in _FUSE_GROUPS:
                    if not all(g in node and hasattr(node[g], "ndim")
                               and node[g].ndim >= 2 for g in group):
                        continue
                    out[fused_name] = pack_group(
                        group, [node[g] for g in group],
                        shard.get(fused_name), glu == "glu")
                    done.update(group)
            for key, v in node.items():
                if key in done:
                    continue
                out[key] = walk(path + (key,), v, shard.get(key))
            return out
        name = path[-1]
        if name not in _PACKABLE or node.ndim < 2:
            return node
        if name == "wo" and "moe" in path:
            return node                         # MoE expert bank, not attn
        return pack_one(name, node, shard_node)

    return walk((), params, shardings)
