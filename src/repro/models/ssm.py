"""Mamba-2 (SSD — state-space duality) in pure JAX.

Chunked SSD algorithm (Dao & Gu 2024, §6): intra-chunk quadratic blocks +
inter-chunk linear state recurrence via lax.scan, so prefill HLO stays
O(chunk) and decode is an O(1)-state step — this is what makes the
long_500k cells runnable for the SSM/hybrid archs where full attention is
skipped (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _segsum(a):
    """a: [..., l] -> [..., l, l]; out[i,j] = sum_{k=j+1..i} a[k] (i>=j)."""
    cs = jnp.cumsum(a, -1)
    s = cs[..., :, None] - cs[..., None, :]
    l = a.shape[-1]
    return jnp.where(jnp.tril(jnp.ones((l, l), bool)), s, -jnp.inf)


def ssd_chunked(x, a, b, c, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: [B, T, H, P] (pre-multiplied by dt)      a: [B, T, H] (= A*dt, <0)
    b, c: [B, T, G, N] (groups broadcast to H)
    Returns y: [B, T, H, P], final_state: [B, H, P, N].
    """
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        # decay-neutral padding: a=0 (no state decay), x=0 (no input), so
        # the final state equals the unpadded stream's; padded y rows are
        # sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    t_real, t = t, t + pad
    nc = t // chunk
    rep = h // g

    def to_chunks(m):
        return m.reshape(bsz, nc, chunk, *m.shape[2:]).swapaxes(0, 1)

    xc = to_chunks(x.astype(jnp.float32))                  # [nc,B,l,H,P]
    ac = to_chunks(a.astype(jnp.float32)).transpose(0, 1, 3, 2)  # [nc,B,H,l]
    bc = to_chunks(jnp.repeat(b, rep, axis=2).astype(jnp.float32))
    cc = to_chunks(jnp.repeat(c, rep, axis=2).astype(jnp.float32))

    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    # §Perf iteration B2: without the checkpoint, reverse-mode through
    # this scan stacks every chunk's [B,H,l,l] decay/score intermediates
    # (hymba train_4k: 44 s memory term).  Rematerializing the chunk body
    # keeps only (state, chunk inputs) as residuals and recomputes the
    # quadratic blocks in the backward — the SSD analogue of the flash
    # attention VJP (models/flash_vjp.py).
    @jax.checkpoint
    def step(state, xs):
        x_c, a_c, b_c, c_c = xs                 # [B,l,H,P],[B,H,l],...
        a_cum = jnp.cumsum(a_c, axis=-1)        # [B,H,l]
        lmat = jnp.exp(_segsum(a_c))            # [B,H,l,l]
        cb = jnp.einsum("blhn,bshn->bhls", c_c, b_c,
                        preferred_element_type=jnp.float32)
        y_diag = jnp.einsum("bhls,bhls,bshp->blhp", cb, lmat, x_c,
                            preferred_element_type=jnp.float32)
        # contribution of the state entering this chunk
        y_off = jnp.einsum("blhn,bhpn,bhl->blhp", c_c, state,
                           jnp.exp(a_cum),
                           preferred_element_type=jnp.float32)
        # state update: decayed carry + this chunk's contribution
        decay_states = jnp.exp(a_cum[..., -1:] - a_cum)    # [B,H,l]
        chunk_state = jnp.einsum("bshn,bhs,bshp->bhpn", b_c, decay_states,
                                 x_c, preferred_element_type=jnp.float32)
        new_state = state * jnp.exp(a_cum[..., -1])[..., None, None] \
            + chunk_state
        return new_state, y_diag + y_off

    # named_scope: lets the roofline walker bucket the intra-chunk
    # quadratic blocks this jnp path materializes — the deployed TPU path
    # is the Pallas kernel (kernels/ssd.py, VMEM-resident), so
    # launch/dryrun.py reports a kernel-adjusted memory term too.
    with jax.named_scope("ssd_chunk"):
        final, ys = jax.lax.scan(step, initial_state, (xc, ac, bc, cc))
    y = ys.swapaxes(0, 1).reshape(bsz, t, h, p)[:, :t_real]
    return y.astype(x.dtype), final


def ssd_step(state, x_t, a_t, b_t, c_t):
    """Single decode step.  state: [B,H,P,N]; x_t: [B,H,P] (dt-premult);
    a_t: [B,H]; b_t, c_t: [B,G,N] -> broadcast to H."""
    h = x_t.shape[1]
    g = b_t.shape[1]
    b_t = jnp.repeat(b_t, h // g, axis=1)
    c_t = jnp.repeat(c_t, h // g, axis=1)
    state = state * jnp.exp(a_t)[..., None, None] \
        + x_t[..., None] * b_t[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, c_t,
                   preferred_element_type=jnp.float32)
    return state, y


# ------------------------------------------------------------- mamba2 layer
def mamba_params(key, cfg, dtype):
    d = cfg.d_model
    h, p, n, g = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                  cfg.ssm_groups)
    d_in = h * p
    conv_dim = d_in + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.init_dense(
            ks[0], (d, 2 * d_in + 2 * g * n + h), dtype=dtype),
        "conv_w": L.init_dense(ks[1], (cfg.conv_width, conv_dim),
                               scale=cfg.conv_width ** -0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": L.init_dense(ks[2], (d_in, d), dtype=dtype),
    }


def _causal_conv(x, w, b, history=None):
    """Depthwise causal conv along time.  x: [B,T,C]; w: [W,C].
    history: [B, W-1, C] prior context (decode) or None (zero left-pad)."""
    width = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None]
              for i in range(width))
    return out + b[None, None], xp[:, -(width - 1):, :]


def mamba_forward(p, cfg, x, *, cache=None, mode: str = "train"):
    """Mamba-2 mixer.  Returns (out, new_cache).  cache:
    {"state": [B,H,P,N] fp32, "conv": [B,W-1,conv_dim]}."""
    bsz, t, _ = x.shape
    h, pd, n, g = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                   cfg.ssm_groups)
    d_in = h * pd
    proj = L.linear(x, p["in_proj"])
    z, xbc_dt = proj[..., :d_in], proj[..., d_in:]
    xbc, dt_raw = xbc_dt[..., :d_in + 2 * g * n], xbc_dt[..., d_in + 2 * g * n:]

    conv_hist = cache["conv"] if cache is not None else None
    if mode == "decode":
        xbc_conv, new_hist = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                          history=conv_hist)
    else:
        xbc_conv, new_hist = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc_conv = jax.nn.silu(xbc_conv)

    xs = xbc_conv[..., :d_in].reshape(bsz, t, h, pd)
    b_ssm = xbc_conv[..., d_in:d_in + g * n].reshape(bsz, t, g, n)
    c_ssm = xbc_conv[..., d_in + g * n:].reshape(bsz, t, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])          # [B,T,H]
    a = -jnp.exp(p["a_log"])[None, None] * dt                 # [B,T,H] (<0)
    x_dt = xs.astype(jnp.float32) * dt[..., None]

    if mode == "decode":
        assert t == 1
        state, y = ssd_step(cache["state"], x_dt[:, 0], a[:, 0],
                            b_ssm[:, 0].astype(jnp.float32),
                            c_ssm[:, 0].astype(jnp.float32))
        y = y[:, None]                                        # [B,1,H,P]
        new_cache = {"state": state, "conv": new_hist}
    else:
        init = cache["state"] if cache is not None else None
        y, state = ssd_chunked(x_dt, a, b_ssm, c_ssm, chunk=cfg.ssm_chunk,
                               initial_state=init)
        new_cache = ({"state": state, "conv": new_hist}
                     if mode == "prefill" else None)

    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, t, d_in).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return L.linear(y, p["out_proj"]), new_cache


def empty_cache(cfg, batch, dtype=jnp.bfloat16):
    h, pd, n, g = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                   cfg.ssm_groups)
    conv_dim = h * pd + 2 * g * n
    return {
        "state": jnp.zeros((batch, h, pd, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    }
