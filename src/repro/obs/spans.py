"""Span tracing: nestable timed scopes exported as a Chrome/Perfetto trace.

A :class:`Tracer` collects *span events* — named, attributed, nestable
timed scopes — from every layer of the stack (plan resolution, weight
packing, autotune sweeps, prefill/decode ticks, megasteps, prefix-cache
operations, fault and degradation events) into one timeline that
``export_chrome_trace`` writes as Chrome-trace JSON, loadable directly
in ``ui.perfetto.dev``.

Activation mirrors ``gemm.use_backend``: a thread-local scope stack over
a process default (:func:`use_tracer` / :func:`set_tracer` /
:func:`no_tracer`), with a module-level activity counter so the
inactive path is a single integer check — instrumented call sites cost
nothing measurable when tracing is off (the table12_obs overhead gate).

Async-dispatch caveat (docs/observability.md): a span around a jitted
call measures *dispatch* unless the caller fences.  Spans themselves
never fence — the scheduler's tick timer (obs/timing) owns the fence
decision, because fencing changes what you measure.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import threading
import time
from typing import Any, Iterator

# Module-level activity counter: incremented per active scope entry and
# per process-default install.  The instrumented fast path is
# ``if _ANY: ...`` — one global int truth test when tracing is off.
_ANY = 0
_DEFAULT: "Tracer | None" = None
_STATE = threading.local()          # per-thread tracer override stack
_LOCK = threading.Lock()


def active_tracer() -> "Tracer | None":
    """The innermost scoped tracer, else the process default, else None.
    Call sites should guard with ``if spans._ANY`` first (or use
    :func:`span`, which does)."""
    stack = getattr(_STATE, "stack", None)
    if stack:
        return stack[-1]
    return _DEFAULT


def set_tracer(tracer: "Tracer | None") -> "Tracer | None":
    """Install ``tracer`` as the process default (None uninstalls).
    Returns the previous default."""
    global _DEFAULT, _ANY
    with _LOCK:
        prev = _DEFAULT
        _DEFAULT = tracer
        _ANY += (1 if tracer is not None else 0) - \
                (1 if prev is not None else 0)
    return prev


@contextlib.contextmanager
def use_tracer(tracer: "Tracer | None") -> Iterator["Tracer | None"]:
    """Scope ``tracer`` as this thread's active tracer (None = trace
    nothing inside, even if a process default is installed)."""
    global _ANY
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(tracer)
    with _LOCK:
        _ANY += 1
    try:
        yield tracer
    finally:
        stack.pop()
        with _LOCK:
            _ANY -= 1


def no_tracer():
    """Scope with tracing disabled (shadows any process default)."""
    return use_tracer(None)


class _SpanHandle:
    """Live handle yielded by :func:`span`: ``set(k=v)`` attaches
    attributes that are only known once the work ran."""
    __slots__ = ("tracer", "name", "t0", "args", "sid", "tid")

    def __init__(self, tracer, name, t0, args, sid, tid):
        self.tracer = tracer
        self.name = name
        self.t0 = t0
        self.args = args
        self.sid = sid
        self.tid = tid

    def set(self, **kw):
        self.args.update(kw)


class _NoopHandle:
    __slots__ = ()

    def set(self, **kw):
        pass


_NOOP = _NoopHandle()


class Tracer:
    """Collects span/instant events; thread-safe appends; exported via
    :func:`export_chrome_trace` (or :meth:`chrome_trace` for the dict).

    ``max_events`` bounds memory on long serves: beyond it the OLDEST
    events are dropped (``dropped`` counts them) — the exported window
    is the most recent activity, matching the flight-recorder
    discipline."""

    def __init__(self, *, max_events: int = 200_000):
        self.t0 = time.perf_counter()
        self.events: list[dict] = []
        self.max_events = max_events
        self.dropped = 0
        self._lock = threading.Lock()
        self._ids = itertools.count(1)

    # ------------------------------------------------------------ events
    def _now_us(self) -> float:
        return (time.perf_counter() - self.t0) * 1e6

    def _push(self, ev: dict):
        with self._lock:
            self.events.append(ev)
            if len(self.events) > self.max_events:
                # drop the oldest half in one slice (amortized O(1))
                cut = self.max_events // 2
                self.dropped += cut
                del self.events[:cut]

    def instant(self, name: str, **args):
        """A zero-duration marker event (faults, degradations, evictions)."""
        self._push({"name": name, "ph": "i", "s": "t",
                    "ts": self._now_us(), "pid": 1,
                    "tid": threading.get_ident() % 100_000,
                    "args": args})

    def begin(self, name: str, **args) -> _SpanHandle:
        tid = threading.get_ident() % 100_000
        h = _SpanHandle(self, name, self._now_us(), dict(args),
                        next(self._ids), tid)
        stack = getattr(_STATE, "spans", None)
        if stack is None:
            stack = _STATE.spans = []
        stack.append(h)
        return h

    def end(self, h: _SpanHandle):
        stack = getattr(_STATE, "spans", None)
        if stack and stack[-1] is h:
            stack.pop()
        self._push({"name": h.name, "ph": "X", "ts": h.t0,
                    "dur": self._now_us() - h.t0, "pid": 1, "tid": h.tid,
                    "args": h.args, "id": h.sid})

    # --------------------------------------------------------- exporting
    def chrome_trace(self, *, recorder=None) -> dict:
        """The Chrome-trace JSON object (``traceEvents`` plus the
        flight-recorder dump when ``recorder`` is given).  GEMM dispatch
        spans are synthesized for recorder entries — see
        :func:`repro.obs.report.synthesize_gemm_events`."""
        with self._lock:
            events = list(self.events)
        events.insert(0, {"name": "process_name", "ph": "M", "pid": 1,
                          "args": {"name": "repro serve"}})
        out = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"dropped_events": self.dropped}}
        if recorder is not None:
            from repro.obs import report as _report
            records = recorder.dump()
            out["flightRecorder"] = records
            out["gemmManifests"] = {
                key: list(recs)
                for key, recs in recorder.manifests().items()}
            out["traceEvents"].extend(
                _report.synthesize_gemm_events(out))
        return out

    def export_chrome_trace(self, path: str, *, recorder=None) -> str:
        """Write the Perfetto-loadable trace JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(recorder=recorder), f)
        return path


def current_span() -> "_SpanHandle | None":
    """The innermost open span on this thread (recorder entries use it
    to attach themselves to the tick that dispatched them)."""
    stack = getattr(_STATE, "spans", None)
    return stack[-1] if stack else None


class _SpanCM:
    """Re-usable span context manager (plain class, not a generator, so
    the inactive path allocates only this tiny object)."""
    __slots__ = ("name", "kw", "handle")

    def __init__(self, name: str, kw: dict):
        self.name = name
        self.kw = kw
        self.handle = _NOOP

    def __enter__(self):
        if _ANY:
            tr = active_tracer()
            if tr is not None:
                self.handle = tr.begin(self.name, **self.kw)
        return self.handle

    def __exit__(self, *exc):
        h = self.handle
        if h is not _NOOP:
            h.tracer.end(h)
        return False


def span(name: str, **attrs: Any) -> _SpanCM:
    """``with obs.span("prefill_chunk", rid=3):`` — a nestable timed
    scope.  No-op (no event, no tracer lookup beyond one int check)
    when no tracer is active."""
    return _SpanCM(name, attrs)


def instant(name: str, **attrs: Any) -> None:
    """Fire-and-forget marker event (no-op when tracing is off)."""
    if _ANY:
        tr = active_tracer()
        if tr is not None:
            tr.instant(name, **attrs)


def validate_chrome_trace(obj: dict) -> list[str]:
    """Schema check for an exported trace: returns a list of problems
    (empty = valid).  Used by tests and the CI traced-serve step."""
    problems = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["missing traceEvents key"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"event {i} not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "B", "E"):
            problems.append(f"event {i}: bad ph {ph!r}")
            continue
        if "name" not in ev:
            problems.append(f"event {i}: missing name")
        if ph in ("X", "i") and not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i}: missing/bad ts")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            problems.append(f"event {i}: complete event missing dur")
        args = ev.get("args", {})
        if not isinstance(args, dict):
            problems.append(f"event {i}: args not an object")
        else:
            try:
                json.dumps(args)
            except TypeError:
                problems.append(f"event {i}: args not JSON-serializable")
    return problems
