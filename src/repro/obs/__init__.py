"""Unified observability: flight recorder, span tracing, metrics.

    from repro import obs

    tracer = obs.Tracer()
    rec = obs.FlightRecorder(capacity=4096, fence=False)
    reg = obs.MetricsRegistry()
    with obs.use_tracer(tracer), obs.use_recorder(rec), \
            obs.use_metrics(reg):
        engine.serve(...)
    tracer.export_chrome_trace("serve.trace.json", recorder=rec)
    reg.write_snapshot("serve.metrics.json")

Three planes, one discipline (scoped like ``gemm.use_backend``, strict
zero-cost no-ops when inactive):

* **Flight recorder** (``obs.recorder``) — fixed-size ring buffer of
  per-dispatch GEMM records hooked into ``gemm.execute``: plan key,
  (m, n, k), backend, lever, epilogue, plan-cache hit/miss, wall time
  and achieved GFLOPS with fraction-of-roofline.  Jitted dispatches
  register trace-time *manifests* instead of fabricated timings.
* **Span tracing** (``obs.spans``) — nestable ``span()`` scopes through
  plan resolve, pack, autotune, serving ticks, prefix-cache and fault
  events; exported as Chrome-trace JSON for ``ui.perfetto.dev``.
* **Metrics** (``obs.metrics``) — counters/gauges/fixed-bucket
  histograms unifying ``ServeStats`` / ``PrefixCacheStats`` /
  ``StoreInfo`` / ``plan_cache_info`` behind Prometheus-text and JSON
  snapshot exporters.

See docs/observability.md for the record schema, span taxonomy, metric
naming, and the async-dispatch fencing caveats.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               active_metrics, gemm_collector,
                               publish_prefix_stats, publish_serve_stats,
                               set_metrics, use_metrics)
from repro.obs.recorder import (FlightRecorder, active_recorder,
                                manifest_scope, manifests, no_recorder,
                                reset_manifests, set_recorder,
                                use_recorder)
from repro.obs.report import (format_table, gemm_events, per_shape_table,
                              synthesize_gemm_events)
from repro.obs.spans import (Tracer, active_tracer, current_span, instant,
                             no_tracer, set_tracer, span, use_tracer,
                             validate_chrome_trace)
from repro.obs.timing import FencedTimer, measure

__all__ = [
    "Counter", "FencedTimer", "FlightRecorder", "Gauge", "Histogram",
    "MetricsRegistry", "Tracer",
    "active_metrics", "active_recorder", "active_tracer", "current_span",
    "format_table", "gemm_collector", "gemm_events", "instant",
    "manifest_scope", "manifests", "measure", "no_recorder", "no_tracer",
    "per_shape_table", "publish_prefix_stats", "publish_serve_stats",
    "reset_manifests", "set_metrics", "set_recorder", "set_tracer",
    "span", "synthesize_gemm_events", "use_metrics", "use_recorder",
    "use_tracer", "validate_chrome_trace",
]
