"""Flight-recorder reductions: per-GEMM trace spans and the paper-style
per-shape table.

Two jobs, both operating on the exported trace object (the JSON
``launch/serve --trace-out`` writes):

* :func:`synthesize_gemm_events` — per-GEMM child spans for the jitted
  serving path.  Inside jit, ``gemm.execute`` runs at trace time; a
  per-dispatch wall clock does not exist, and pretending otherwise
  would be fabrication.  What IS known exactly: the manifest (which
  plans each compiled step dispatches, registered at trace time) and
  each scheduler tick's measured span.  So for every tick span carrying
  a ``step=<key>`` attribute we emit one child span per manifest plan,
  with the tick's duration *apportioned by the plans' ``t_pred``
  share* and each child explicitly flagged ``"apportioned": true`` —
  honest attribution, visually useful in Perfetto, never mistakable
  for a measurement.  Eager dispatches (warmup, direct execute) get
  real measured spans from the recorder and are flagged
  ``apportioned: false``.

* :func:`per_shape_table` — the paper's shape-resolved characterization
  from live traffic: per (m, n, k, format), the dispatch count, lever
  mix, median achieved GFLOPS and median fraction-of-roofline.
  Surfaced by the ``launch/trace_report`` CLI.
"""
from __future__ import annotations

import math


def _share_weights(records: list[dict]) -> list[float]:
    """Relative duration weights for a step's manifest plans: scheduler
    ``t_pred`` when finite, else the flop count — normalized to sum 1."""
    raw = []
    for r in records:
        t = r.get("t_pred")
        if t is None or not math.isfinite(t) or t <= 0:
            t = 2.0 * r["m"] * r["n"] * r["k"]
        raw.append(float(t))
    total = sum(raw)
    if total <= 0:
        return [1.0 / len(raw)] * len(raw)
    return [w / total for w in raw]


def synthesize_gemm_events(trace: dict) -> list[dict]:
    """Apportioned per-GEMM child spans for every tick span that names a
    manifested step (see module docstring).  Returns the new events;
    does not mutate ``trace``."""
    manifests = trace.get("gemmManifests") or {}
    if not manifests:
        return []
    out = []
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        step = (ev.get("args") or {}).get("step")
        recs = manifests.get(step)
        if not recs:
            continue
        shares = _share_weights(recs)
        ts = ev["ts"]
        # megastep drains carry ticks=D: the manifest runs once per
        # device-side tick, so the child sequence repeats D times
        ticks = int((ev.get("args") or {}).get("ticks", 1)) or 1
        dur_per_tick = ev["dur"] / ticks
        for t in range(ticks):
            for r, share in zip(recs, shares):
                d = dur_per_tick * share
                args = dict(r)
                args["apportioned"] = True
                args["step"] = step
                wall_s = d * 1e-6
                if wall_s > 0:
                    args["gflops"] = (2.0 * r["m"] * r["n"] * r["k"]
                                      / wall_s / 1e9)
                    args["roofline_frac"] = _frac(r, wall_s)
                out.append({"name": "gemm_dispatch", "ph": "X", "ts": ts,
                            "dur": d, "pid": 1,
                            "tid": ev.get("tid", 1), "args": args})
                ts += d
    return out


def _frac(rec: dict, wall_s: float) -> float | None:
    try:
        from repro.roofline import gemm_roofline
        db = rec.get("density_bucket", -1)
        wd = 1.0 if db < 0 else max(0.05, 1.0 - (db + 0.5) / 10.0)
        bound = gemm_roofline(rec["m"], rec["n"], rec["k"],
                              weight_format=rec.get("weight_format",
                                                    "fp32"),
                              weight_density=wd)
        if bound and bound > 0:
            return min(1.0, bound / wall_s)
    except Exception:
        pass
    return None


def gemm_events(trace: dict) -> list[dict]:
    """Every per-GEMM dispatch span's args dict — measured (eager) and
    apportioned (jitted) alike."""
    return [ev.get("args", {}) for ev in trace.get("traceEvents", [])
            if ev.get("name") == "gemm_dispatch" and ev.get("ph") == "X"]


def _median(vals: list[float]) -> float | None:
    vals = sorted(v for v in vals if v is not None)
    if not vals:
        return None
    n = len(vals)
    mid = n // 2
    return vals[mid] if n % 2 else 0.5 * (vals[mid - 1] + vals[mid])


def per_shape_table(trace: dict) -> list[dict]:
    """The paper-style shape-resolved characterization from a trace:
    one row per (m, n, k, weight_format) with dispatch count, lever
    mix, median GFLOPS and median roofline fraction.  ``apportioned``
    counts how many of the shape's samples are share-attributed rather
    than measured (0 = all real timings).  ``sparse`` lists the
    density buckets seen for the shape (``dense`` or ``d<bucket>`` —
    the sparse-ternary arm's zero-group-fraction decile)."""
    groups: dict[tuple, dict] = {}
    for a in gemm_events(trace):
        if "m" not in a:
            continue
        key = (a["m"], a["n"], a["k"], a.get("weight_format", "fp32"))
        g = groups.setdefault(key, {"count": 0, "apportioned": 0,
                                    "levers": {}, "gflops": [],
                                    "frac": [], "split_k": set(),
                                    "epilogues": set(), "buckets": set()})
        g["count"] += 1
        if a.get("apportioned"):
            g["apportioned"] += 1
        lv = a.get("lever", "?")
        g["levers"][lv] = g["levers"].get(lv, 0) + 1
        if a.get("gflops") is not None:
            g["gflops"].append(a["gflops"])
        if a.get("roofline_frac") is not None:
            g["frac"].append(a["roofline_frac"])
        g["split_k"].add(a.get("split_k", 1))
        g["epilogues"].add(a.get("epilogue", "none"))
        g["buckets"].add(a.get("density_bucket", -1))
    rows = []
    for (m, n, k, fmt), g in sorted(groups.items()):
        lever_mix = ",".join(f"{lv}:{c}" for lv, c in
                             sorted(g["levers"].items(),
                                    key=lambda kv: -kv[1]))
        rows.append({
            "m": m, "n": n, "k": k, "format": fmt,
            "dispatches": g["count"],
            "apportioned": g["apportioned"],
            "lever_mix": lever_mix,
            "split_k": ",".join(str(s) for s in sorted(g["split_k"])),
            "sparse": ",".join("dense" if b < 0 else f"d{b}"
                               for b in sorted(g["buckets"])),
            "median_gflops": _median(g["gflops"]),
            "median_roofline_frac": _median(g["frac"]),
        })
    return rows


def format_table(rows: list[dict]) -> str:
    """Fixed-width text rendering of :func:`per_shape_table` rows."""
    if not rows:
        return "(no GEMM dispatch spans in trace)"
    cols = [("m", 6), ("n", 6), ("k", 6), ("format", 8),
            ("dispatches", 10), ("apportioned", 11), ("lever_mix", 26),
            ("split_k", 7), ("sparse", 8), ("median_gflops", 13),
            ("median_roofline_frac", 20)]
    lines = ["  ".join(name.rjust(w) for name, w in cols),
             "  ".join("-" * w for _, w in cols)]
    for r in rows:
        cells = []
        for name, w in cols:
            v = r[name]
            if v is None:
                v = "-"
            elif isinstance(v, float):
                v = f"{v:.3f}"
            cells.append(str(v).rjust(w))
        lines.append("  ".join(cells))
    return "\n".join(lines)
