"""Dispatch flight recorder: a fixed-size ring buffer of GEMM dispatches.

Every ``gemm.execute`` call lands here when a recorder is active: plan
key, (m, n, k), backend, lever (prepack / fine-panel / split-K / quant
format), epilogue, plan-cache hit/miss, wall time, and achieved GFLOPS
with the fraction-of-roofline from ``roofline.analysis.gemm_roofline``.
Scoped exactly like ``gemm.use_backend`` (:func:`use_recorder` /
:func:`no_recorder` / :func:`set_recorder`); when inactive the hook in
``gemm/execute.py`` is a single module-level int check — zero
allocation, below measurement noise (gated by benchmarks/table12_obs).

Two dispatch regimes, recorded honestly rather than papered over:

* **Eager** dispatches (operands are concrete arrays — warmup, plan
  probing, direct ``gemm.execute`` use).  Wall time is measurable, but
  only if we fence: JAX dispatches asynchronously, so ``perf_counter``
  around the call measures *dispatch* cost.  A recorder created with
  ``fence=True`` calls ``block_until_ready`` on the result before
  closing the timer — opt-in, because the fence itself changes what you
  measure (it serializes the pipeline).  Unfenced eager records carry
  ``wall_ms`` of the dispatch only and are flagged ``fenced: False``.

* **Traced** dispatches (operands are tracers — every jitted Engine
  step).  Per-call wall time does not exist at trace time and cannot be
  recovered per-GEMM afterwards, so we record the *manifest*: each
  jitted step body opens :func:`manifest_scope`, and traced ``execute``
  calls register their plan (static shape/lever data) under that step
  key, once per compilation.  Scheduler tick spans carry
  ``step=<key>``; at export time ``obs.report`` synthesizes per-GEMM
  child spans under each tick with duration apportioned by the plans'
  ``t_pred`` share, explicitly flagged ``"apportioned": true``.
  Manifests register unconditionally (trace-time cost only), so a
  recorder attached after warmup still sees them.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Iterator

from repro.obs import spans

# Combined hot flag for the execute() hook: nonzero while any recorder
# scope/default is installed OR any manifest scope is open.  The
# inactive fast path in gemm/execute is ``if _HOT: ...`` — one global
# int truth test.
_HOT = 0
_DEFAULT: "FlightRecorder | None" = None
_STATE = threading.local()          # .stack: recorder scopes; .mkey: manifest
_LOCK = threading.Lock()

# step key -> list of manifest records (static plan info registered at
# jit-trace time).  Module-level and persistent: jit traces once per
# shape, so late-attached recorders still see every compiled step.
_MANIFESTS: dict[str, list[dict]] = {}


def active_recorder() -> "FlightRecorder | None":
    stack = getattr(_STATE, "stack", None)
    if stack:
        return stack[-1]
    return _DEFAULT


def set_recorder(rec: "FlightRecorder | None") -> "FlightRecorder | None":
    """Install ``rec`` as the process-default recorder (None uninstalls).
    Returns the previous default."""
    global _DEFAULT, _HOT
    with _LOCK:
        prev = _DEFAULT
        _DEFAULT = rec
        _HOT += (1 if rec is not None else 0) - (1 if prev is not None else 0)
    return prev


@contextlib.contextmanager
def use_recorder(rec: "FlightRecorder | None") -> Iterator["FlightRecorder | None"]:
    """Scope ``rec`` as this thread's active recorder (None = record
    nothing inside, shadowing any process default)."""
    global _HOT
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(rec)
    with _LOCK:
        _HOT += 1
    try:
        yield rec
    finally:
        stack.pop()
        with _LOCK:
            _HOT -= 1


def no_recorder():
    """Scope with recording disabled (shadows any process default)."""
    return use_recorder(None)


@contextlib.contextmanager
def manifest_scope(key: str) -> Iterator[None]:
    """Open around a jitted step *body* (executes at trace time only).

    Traced ``execute`` calls inside register their plan under ``key`` in
    the module-level manifest table.  Entering the scope resets the
    key's record list, so a retrace rewrites rather than duplicates.
    Reentrant traces (a jitted step tracing inside another) stack."""
    global _HOT
    prev = getattr(_STATE, "mkey", None)
    _STATE.mkey = key
    _MANIFESTS[key] = []
    with _LOCK:
        _HOT += 1
    try:
        yield
    finally:
        _STATE.mkey = prev
        with _LOCK:
            _HOT -= 1


def manifests() -> dict[str, list[dict]]:
    """The full step-key -> plan-records manifest table (live view)."""
    return _MANIFESTS


def _plan_record(p, m: int) -> dict:
    """Static (shape/lever) fields shared by ring records and manifests."""
    return {
        "plan": p.describe(),
        "m": int(m), "n": int(p.n), "k": int(p.k),
        "backend": p.backend,
        "lever": p.lever,
        "pack": p.pack,
        "split_k": int(p.split_k),
        "weight_format": p.weight_format,
        "density_bucket": int(p.density_bucket),
        "epilogue": str(p.epilogue) if p.epilogue is not None else "none",
        "decode": bool(p.decode),
        "t_pred": float(p.t_pred),
    }


class FlightRecorder:
    """Fixed-size ring buffer of per-dispatch records.

    ``capacity`` bounds memory; once full, the oldest records are
    overwritten (``wrapped`` counts overwrites).  ``fence=True`` makes
    eager timed entries call ``block_until_ready`` before closing the
    timer — execution time instead of dispatch time, at the cost of
    serializing the pipeline (see docs/observability.md)."""

    def __init__(self, *, capacity: int = 4096, fence: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.fence = fence
        self._ring: list[dict | None] = [None] * capacity
        self._idx = 0
        self.total = 0          # dispatches recorded over the lifetime
        self.wrapped = 0        # records overwritten by the ring
        self.traced = 0         # trace-time (manifest) registrations seen
        self._seen: set[str] = set()   # plan keys already dispatched
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()

    # ---------------------------------------------------------- recording
    def record(self, p, m: int, *, wall_s: float | None,
               fenced: bool) -> None:
        """Record one eager dispatch.  ``wall_s`` is the measured wall
        time (None if timing was impossible); ``fenced`` says whether
        the timer closed behind a ``block_until_ready``."""
        rec = _plan_record(p, m)
        key = rec["plan"]
        rec["ts_ms"] = (time.perf_counter() - self._t0) * 1e3
        if wall_s is not None and wall_s > 0:
            rec["wall_ms"] = wall_s * 1e3
            rec["fenced"] = fenced
            if fenced:
                flops = 2.0 * m * rec["n"] * rec["k"]
                rec["gflops"] = flops / wall_s / 1e9
                rec["roofline_frac"] = _roofline_frac(rec, wall_s)
        with self._lock:
            # plan-cache proxy: first time this recorder sees the key is
            # a miss from the recorder's point of view (the process-wide
            # plan cache may have been warm before we attached — the
            # plan_resolve spans in the trace carry the live resolves).
            rec["plan_cache_hit"] = key in self._seen
            self._seen.add(key)
            if self._ring[self._idx] is not None:
                self.wrapped += 1
            self._ring[self._idx] = rec
            self._idx = (self._idx + 1) % self.capacity
            self.total += 1
        if spans._ANY and wall_s is not None:
            tr = spans.active_tracer()
            if tr is not None:
                # eager dispatches get real (measured) spans; traced
                # ones get apportioned children at export time
                tr._push({"name": "gemm_dispatch", "ph": "X",
                          "ts": tr._now_us() - wall_s * 1e6,
                          "dur": wall_s * 1e6, "pid": 1,
                          "tid": threading.get_ident() % 100_000,
                          "args": rec})

    def note_traced(self) -> None:
        with self._lock:
            self.traced += 1

    # ---------------------------------------------------------- reading
    def dump(self) -> list[dict]:
        """Records in chronological order (oldest surviving first)."""
        with self._lock:
            tail = [r for r in self._ring[self._idx:] if r is not None]
            head = [r for r in self._ring[:self._idx] if r is not None]
            return [dict(r) for r in tail + head]

    def manifests(self) -> dict[str, list[dict]]:
        return _MANIFESTS

    def summary(self) -> dict:
        return {"total": self.total, "wrapped": self.wrapped,
                "traced": self.traced, "capacity": self.capacity,
                "fence": self.fence}


def on_traced(p, m: int) -> None:
    """Called by ``gemm.execute`` when a dispatch ran on tracers (i.e.
    at jit-trace time).  Registers the plan's static record into the
    open manifest scope, if any — once per compilation, zero
    per-dispatch cost at run time."""
    mkey = getattr(_STATE, "mkey", None)
    if mkey is not None:
        _MANIFESTS.setdefault(mkey, []).append(_plan_record(p, m))
    rec = active_recorder()
    if rec is not None:
        rec.note_traced()


def _roofline_frac(rec: dict, wall_s: float) -> float | None:
    """Fraction of the analytic roofline bound achieved by this
    dispatch (lazy import keeps obs free of repro deps at module
    level)."""
    try:
        from repro.roofline import gemm_roofline
        db = rec.get("density_bucket", -1)
        # sparse packs: score against the occupied fraction the layout
        # implies (the bucket's midpoint), not the dense shape's work
        wd = 1.0 if db < 0 else max(0.05, 1.0 - (db + 0.5) / 10.0)
        t_bound = gemm_roofline(rec["m"], rec["n"], rec["k"],
                                weight_format=rec["weight_format"],
                                weight_density=wd)
        if t_bound and t_bound > 0:
            return min(1.0, t_bound / wall_s)
    except Exception:
        pass
    return None


def reset_manifests() -> None:
    """Test hook: forget every registered manifest (jit caches persist,
    so a cleared manifest only repopulates on a fresh trace)."""
    _MANIFESTS.clear()
