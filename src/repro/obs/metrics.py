"""Metrics registry: counters, gauges, and deterministic histograms.

One registry unifies the serving stack's four pre-existing stats
surfaces — ``runtime.batching.ServeStats``,
``runtime.prefix_cache.PrefixCacheStats``, ``gemm.plan_store.StoreInfo``
and ``gemm.plan_cache_info`` — as *views*: the dataclass / namedtuple
APIs stay exactly as they were (no caller or test churn); the obs layer
publishes them into the registry (:func:`publish_serve_stats`,
:func:`publish_prefix_stats`) or pulls them at snapshot time via
registered collectors (:func:`gemm_collector` for the plan cache and
plan store).  Exporters: Prometheus text (:meth:`prometheus_text`) and
a JSON-able snapshot (:meth:`snapshot`).

Histograms use *fixed* bucket boundaries chosen at construction — a
seeded serve run produces a bit-identical snapshot (minus explicitly
timing-valued metrics, which are wall-clock and therefore excluded by
the determinism test via the ``_ms``/``_seconds`` naming convention).

Scoping mirrors ``gemm.use_backend``: :func:`use_metrics` /
:func:`set_metrics` with a module-level activity flag so inactive call
sites cost one int check.
"""
from __future__ import annotations

import contextlib
import json
import threading
from typing import Callable, Iterator

_ANY = 0
_DEFAULT: "MetricsRegistry | None" = None
_STATE = threading.local()
_LOCK = threading.Lock()


def active_metrics() -> "MetricsRegistry | None":
    stack = getattr(_STATE, "stack", None)
    if stack:
        return stack[-1]
    return _DEFAULT


def set_metrics(reg: "MetricsRegistry | None") -> "MetricsRegistry | None":
    """Install ``reg`` as the process default (None uninstalls)."""
    global _DEFAULT, _ANY
    with _LOCK:
        prev = _DEFAULT
        _DEFAULT = reg
        _ANY += (1 if reg is not None else 0) - (1 if prev is not None else 0)
    return prev


@contextlib.contextmanager
def use_metrics(reg: "MetricsRegistry | None") -> Iterator["MetricsRegistry | None"]:
    global _ANY
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(reg)
    with _LOCK:
        _ANY += 1
    try:
        yield reg
    finally:
        stack.pop()
        with _LOCK:
            _ANY -= 1


def _labelkey(labels: dict | None) -> tuple:
    return tuple(sorted(labels.items())) if labels else ()


class Counter:
    """Monotonic counter, optionally labelled."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0, **labels):
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._series.get(_labelkey(labels), 0.0)

    def series(self) -> dict[tuple, float]:
        with self._lock:
            return dict(self._series)


class Gauge:
    """Last-write-wins value, optionally labelled."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def set(self, value: float, **labels):
        with self._lock:
            self._series[_labelkey(labels)] = float(value)

    def value(self, **labels) -> float:
        return self._series.get(_labelkey(labels), 0.0)

    def series(self) -> dict[tuple, float]:
        with self._lock:
            return dict(self._series)


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are the inclusive upper
    bounds, in increasing order; an implicit +Inf bucket catches the
    rest.  Fixed boundaries (no adaptive resizing) keep snapshots
    deterministic for deterministic inputs."""

    kind = "histogram"

    def __init__(self, name: str, buckets: tuple, help: str = ""):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError("buckets must be a non-empty increasing sequence")
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        self._series: dict[tuple, list] = {}   # key -> [counts..., +inf, sum, n]
        self._lock = threading.Lock()

    def observe(self, value: float, **labels):
        key = _labelkey(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = [0] * (len(self.buckets) + 1) + [0.0, 0]
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    s[i] += 1
                    break
            else:
                s[len(self.buckets)] += 1
            s[-2] += float(value)
            s[-1] += 1

    def series(self) -> dict[tuple, list]:
        with self._lock:
            return {k: list(v) for k, v in self._series.items()}


# Default time buckets (ms): span two decades around typical tick times.
TIME_BUCKETS_MS = (0.05, 0.1, 0.25, 0.5, 1, 2.5, 5, 10, 25, 50, 100,
                   250, 500, 1000, 2500)
# Shape buckets for m (token rows per dispatch).
M_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


class MetricsRegistry:
    """Namespace of instruments plus snapshot-time collectors.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent,
    so call sites don't coordinate creation).  ``add_collector``
    registers a callback run at snapshot/export time — used to pull the
    gemm plan-cache and plan-store surfaces, which are process-global
    and cheapest to read on demand."""

    def __init__(self):
        self._instruments: dict[str, object] = {}
        self._collectors: list[Callable[["MetricsRegistry"], None]] = []
        self._lock = threading.Lock()

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, lambda: Counter(name, help), Counter)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, lambda: Gauge(name, help), Gauge)

    def histogram(self, name: str, buckets: tuple = TIME_BUCKETS_MS,
                  help: str = "") -> Histogram:
        return self._get(name, lambda: Histogram(name, buckets, help),
                         Histogram)

    def _get(self, name, make, cls):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = make()
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst

    def add_collector(self, fn: Callable[["MetricsRegistry"], None]):
        with self._lock:
            if fn not in self._collectors:
                self._collectors.append(fn)

    def _collect(self):
        for fn in list(self._collectors):
            fn(self)

    # --------------------------------------------------------- exporters
    def snapshot(self, *, collect: bool = True) -> dict:
        """JSON-able snapshot: sorted metric names, label sets as sorted
        ``k=v`` strings — byte-stable for identical inputs."""
        if collect:
            self._collect()
        out: dict = {}
        with self._lock:
            instruments = dict(self._instruments)
        for name in sorted(instruments):
            inst = instruments[name]
            entry: dict = {"kind": inst.kind}
            if inst.kind == "histogram":
                entry["buckets"] = list(inst.buckets)
                entry["series"] = {
                    _labelstr(k): {"counts": v[:-2], "sum": v[-2],
                                   "count": v[-1]}
                    for k, v in sorted(inst.series().items())}
            else:
                entry["series"] = {_labelstr(k): v for k, v in
                                   sorted(inst.series().items())}
            out[name] = entry
        return out

    def write_snapshot(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
        return path

    def prometheus_text(self, *, collect: bool = True) -> str:
        """Prometheus text exposition format (text/plain; version 0.0.4)."""
        if collect:
            self._collect()
        lines = []
        with self._lock:
            instruments = dict(self._instruments)
        for name in sorted(instruments):
            inst = instruments[name]
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            if inst.kind == "histogram":
                for key, s in sorted(inst.series().items()):
                    cum = 0
                    for ub, c in zip(inst.buckets, s[:-3]):
                        cum += c
                        lines.append(
                            f'{name}_bucket{{{_promlabels(key, le=ub)}}} {cum}')
                    cum += s[len(inst.buckets)]
                    lines.append(
                        f'{name}_bucket{{{_promlabels(key, le="+Inf")}}} {cum}')
                    lines.append(f"{name}_sum{_prombrace(key)} {s[-2]}")
                    lines.append(f"{name}_count{_prombrace(key)} {s[-1]}")
            else:
                for key, v in sorted(inst.series().items()):
                    lines.append(f"{name}{_prombrace(key)} {_fmt(v)}")
        return "\n".join(lines) + "\n"


def _labelstr(key: tuple) -> str:
    return ",".join(f"{k}={v}" for k, v in key) or "_"


def _promlabels(key: tuple, **extra) -> str:
    parts = [f'{k}="{v}"' for k, v in key]
    parts += [f'{k}="{v}"' for k, v in extra.items()]
    return ",".join(parts)


def _prombrace(key: tuple) -> str:
    return "{" + _promlabels(key) + "}" if key else ""


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(v)


# ----------------------------------------------------------------- views
# The four pre-existing stats surfaces, expressed over the registry.
# Dataclass/namedtuple APIs are untouched; these functions map them in.

def publish_serve_stats(stats, reg: "MetricsRegistry | None" = None) -> None:
    """Map a ``runtime.batching.ServeStats`` into the registry.  Called
    by the scheduler at the end of ``run()`` when metrics are active.
    Per-tick durations land in ``_ms`` histograms — wall-clock-valued,
    so excluded from the determinism contract by naming convention."""
    reg = reg or active_metrics()
    if reg is None:
        return
    g = reg.gauge
    c = reg.counter
    g("serve_prefill_tokens").set(stats.prefill_tokens)
    g("serve_decode_tokens").set(stats.decode_tokens)
    g("serve_prefill_ticks").set(len(stats.prefill_tick_ms))
    g("serve_decode_ticks").set(stats.decode_ticks)
    g("serve_decode_dispatches").set(stats.decode_dispatches)
    g("serve_host_syncs").set(stats.host_syncs)
    g("serve_megastep_depth").set(stats.megastep_depth)
    g("serve_requests_completed").set(stats.completed)
    g("serve_requests_failed").set(stats.failed)
    g("serve_dispatch_retries").set(stats.dispatch_retries)
    g("serve_backend_fallbacks").set(stats.backend_fallbacks)
    g("serve_stragglers").set(len(stats.stragglers))
    g("serve_trace_dropped").set(getattr(stats, "trace_dropped", 0))
    g("serve_vmem_clamped_plans").set(stats.vmem_clamped_plans)
    outcomes: dict[str, int] = {}
    for oc in stats.outcomes.values():
        outcomes[oc.state.value] = outcomes.get(oc.state.value, 0) + 1
    for state, n in sorted(outcomes.items()):
        c("serve_request_outcomes_total").inc(n, state=state)
    for reason, n in sorted(stats.degraded.items()):
        c("serve_degraded_total").inc(n, reason=reason)
    h_p = reg.histogram("serve_prefill_tick_ms")
    for v in stats.prefill_tick_ms:
        h_p.observe(v)
    h_d = reg.histogram("serve_decode_tick_ms")
    for v in stats.decode_tick_ms:
        h_d.observe(v)
    if stats.prefix is not None:
        publish_prefix_stats(stats.prefix, reg)


def publish_prefix_stats(stats, reg: "MetricsRegistry | None" = None) -> None:
    """Map a ``runtime.prefix_cache.PrefixCacheStats`` into the registry."""
    reg = reg or active_metrics()
    if reg is None:
        return
    g = reg.gauge
    g("prefix_cache_lookups").set(stats.lookups)
    g("prefix_cache_hits").set(stats.hits)
    g("prefix_cache_misses").set(stats.misses)
    g("prefix_cache_hit_tokens").set(stats.hit_tokens)
    g("prefix_cache_cow_forks").set(stats.cow_forks)
    g("prefix_cache_inserted_pages").set(stats.inserted_pages)
    g("prefix_cache_evicted_pages").set(stats.evicted_pages)
    g("prefix_cache_cached_pages").set(stats.cached_pages)


def gemm_collector(reg: "MetricsRegistry") -> None:
    """Snapshot-time collector for the gemm-layer surfaces: the in-proc
    plan cache (``gemm.plan_cache_info``) and, when a plan store is
    scoped, its ``StoreInfo``.  Lazy imports — obs never imports gemm
    at module level."""
    from repro import gemm
    info = gemm.plan_cache_info()
    g = reg.gauge
    g("plan_cache_hits").set(info.hits)
    g("plan_cache_misses").set(info.misses)
    g("plan_cache_size").set(info.currsize)
    g("plan_cache_maxsize").set(info.maxsize)
    g("plan_vmem_clamped").set(gemm.vmem_clamped_count())
    si = gemm.plan_store_info()
    if si is not None:
        g("plan_store_hits").set(si.hits)
        g("plan_store_misses").set(si.misses)
        g("plan_store_autotuned").set(si.autotuned)
        g("plan_store_entries").set(si.entries)
