"""Fenced phase timing — honest wall-clock measurement under JAX async
dispatch.

The bug this module exists to fix (ISSUE 9 satellite): the serving
loops wrapped jitted calls in bare ``perf_counter`` pairs.  JAX
dispatches asynchronously, so such a pair measures how long it took to
*enqueue* the computation, not to run it — the recorded "phase time"
was dispatch time misattributed as execution time, and the error grows
exactly when the pipeline is healthiest (deep async queues).

:class:`FencedTimer` makes the choice explicit.  ``fence=False``
measures dispatch time and says so (``fenced`` stays False on the
result); ``fence=True`` calls ``jax.block_until_ready`` on the values
handed to :meth:`fence` before closing the clock, which measures real
execution time *at the cost of serializing the pipeline* — the fence
itself is a host sync the unfenced run would not pay, so fenced
numbers are exact per-phase but pessimistic end-to-end
(docs/observability.md "Fencing").  The scheduler maps its
``sync_per_step`` flag onto the fence, which is why per-tick stats are
documented as exact under ``sync_per_step`` and dispatch-time
otherwise.
"""
from __future__ import annotations

import time


class FencedTimer:
    """``with FencedTimer(fence=...) as t: y = step(); t.fence(y)``.

    After exit, ``elapsed_s`` is the measured wall time and ``fenced``
    records whether a ``block_until_ready`` closed the clock (False
    means the number is dispatch time).  ``synced`` counts the host
    syncs the fence actually performed — the scheduler adds it to its
    ``host_syncs`` accounting so the fence's cost is visible, never
    silent."""

    __slots__ = ("fence_enabled", "fenced", "synced", "elapsed_s", "_t0")

    def __init__(self, *, fence: bool = False):
        self.fence_enabled = fence
        self.fenced = False
        self.synced = 0
        self.elapsed_s = 0.0
        self._t0 = 0.0

    def __enter__(self) -> "FencedTimer":
        self._t0 = time.perf_counter()
        return self

    def fence(self, *values) -> None:
        """Block until ``values`` are materialized — only when the timer
        was built with ``fence=True`` (so call sites can hand the result
        over unconditionally and let the timer own the decision)."""
        if self.fence_enabled:
            import jax
            jax.block_until_ready(values)
            self.fenced = True
            self.synced += 1

    def __exit__(self, *exc) -> bool:
        self.elapsed_s = time.perf_counter() - self._t0
        return False


def measure(fn, *, fence: bool = True, repeats: int = 1) -> float:
    """Best-of-``repeats`` wall seconds for ``fn()``, fencing the result
    when asked — the obs-layer primitive tests and the overhead
    benchmark share."""
    best = float("inf")
    for _ in range(max(1, repeats)):
        with FencedTimer(fence=fence) as t:
            y = fn()
            t.fence(y)
        best = min(best, t.elapsed_s)
    return best
