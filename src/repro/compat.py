"""Version shims for the jax APIs this repo spans.

The codebase is written against the current jax surface (``jax.shard_map``
with ``axis_names``/``check_vma``, ``pltpu.CompilerParams``); the baked-in
toolchain ships jax 0.4.37, where those live at
``jax.experimental.shard_map.shard_map`` (``auto``/``check_rep``) and
``pltpu.TPUCompilerParams``.  Everything that depends on one of these
imports it from here so the translation happens in exactly one place.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.experimental.pallas import tpu as _pltpu

# ------------------------------------------------------------ pallas params
# jax >= 0.6 renamed TPUCompilerParams -> CompilerParams.
TPUCompilerParams: Any = getattr(_pltpu, "CompilerParams",
                                 getattr(_pltpu, "TPUCompilerParams", None))


def tpu_compiler_params(**kw):
    """Build TPU Pallas compiler params under either jax naming."""
    return TPUCompilerParams(**kw)


# ---------------------------------------------------------------- make_mesh
def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates jax 0.4.x (no ``axis_types``)."""
    import inspect
    kw: dict[str, Any] = {}
    if devices is not None:
        kw["devices"] = devices
    params = inspect.signature(jax.make_mesh).parameters
    if axis_types is not None and "axis_types" in params:
        kw["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def axis_type_auto():
    """``jax.sharding.AxisType.Auto`` where it exists, else None."""
    return getattr(jax.sharding, "AxisType", None) and \
        jax.sharding.AxisType.Auto


# --------------------------------------------------------------- shard_map
def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None, check_rep=None, auto=None):
    """``jax.shard_map`` signature, executable on jax 0.4.x.

    New-API spellings are translated for the experimental version:
      * ``axis_names`` (manual axes)  -> ``auto`` (every other mesh axis)
      * ``check_vma``                 -> ``check_rep``
    """
    if hasattr(jax, "shard_map"):
        kw: dict[str, Any] = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        elif auto is not None:      # old-API spelling: manual = rest
            kw["axis_names"] = set(mesh.axis_names) - set(auto)
        if check_vma is not None or check_rep is not None:
            kw["check_vma"] = (check_vma if check_vma is not None
                               else check_rep)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)

    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if auto is None and axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    elif auto is not None:
        kw["auto"] = frozenset(auto)
    rep = check_rep if check_rep is not None else check_vma
    if rep is not None:
        kw["check_rep"] = rep
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
