"""AdamW / Adafactor, global-norm clipping, LR schedules.

Interface: ``opt = adamw(...)``; ``state = opt.init(params)``;
``new_params, new_state, stats = opt.update(grads, state, params, step)``.
Everything is a pytree transform — jit/scan/shard friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- schedule
def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Callable:
    """Linear warmup → cosine decay to ``floor * peak_lr``."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


# --------------------------------------------------------------------- util
def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable        # params -> opt_state
    update: Callable      # (grads, state, params, step) -> (params, state, stats)


# -------------------------------------------------------------------- adamw
def adamw(lr_fn: Callable, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        gl, treedef = jax.tree_util.tree_flatten(grads)
        pl = treedef.flatten_up_to(params)
        ml = treedef.flatten_up_to(state["mu"])
        vl = treedef.flatten_up_to(state["nu"])
        new_p, new_m, new_v = [], [], []
        for g, m, v, p in zip(gl, ml, vl, pl):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            # decoupled weight decay on matrices; vectors (norms) spared
            wd = weight_decay if p.ndim >= 2 else 0.0
            p32 = p.astype(jnp.float32)
            new_p.append((p32 - lr * (u + wd * p32)).astype(p.dtype))
            new_m.append(m)
            new_v.append(v)
        unf = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
        return (unf(new_p), {"mu": unf(new_m), "nu": unf(new_v)},
                {"grad_norm": gnorm, "lr": lr})

    return Optimizer(init, update)


# ---------------------------------------------------------------- adafactor
def adafactor(lr_fn: Callable, *, eps: float = 1e-30, clip_thresh: float = 1.0,
              decay_pow: float = 0.8, grad_clip: float = 1.0) -> Optimizer:
    """Factored second-moment Adafactor (no momentum).

    Arrays with ndim >= 2 keep row/col factored statistics over their last
    two dims (stacked layer params (L, K, N) factor per layer slice);
    vectors fall back to full second moment.
    """
    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(st, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay_pow)
        lr = lr_fn(step)

        gl, treedef = jax.tree_util.tree_flatten(grads)
        pl = treedef.flatten_up_to(params)
        sl = treedef.flatten_up_to(state["f"])
        new_p, new_s = [], []
        for g, s, p in zip(gl, sl, pl):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                vhat, ns = v, {"v": v}
            u = g * jax.lax.rsqrt(vhat + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_s.append(ns)
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                {"f": jax.tree_util.tree_unflatten(treedef, new_s)},
                {"grad_norm": gnorm, "lr": lr})

    return Optimizer(init, update)


def make(optimizer: str, lr_fn: Callable, *, weight_decay: float = 0.01,
         grad_clip: float = 1.0) -> Optimizer:
    if optimizer == "adamw":
        return adamw(lr_fn, weight_decay=weight_decay, grad_clip=grad_clip)
    if optimizer == "adafactor":
        return adafactor(lr_fn, grad_clip=grad_clip)
    raise ValueError(f"unknown optimizer {optimizer!r}")
