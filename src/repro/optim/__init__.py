"""Optimizers (self-contained, optax-like): AdamW + Adafactor + schedules.

Optimizer state inherits the parameter sharding (FSDP: state shards over
the data axis with its param — jit propagates the placement), which is
what keeps deepseek-v3-671b's update step inside 16 GB/chip.  Adafactor
(factored second moment, no momentum) is selected for the two largest
archs (deepseek-v3-671b, internvl2-76b) per DESIGN.md §4.
"""
from repro.optim.optimizers import (
    Optimizer, adafactor, adamw, clip_by_global_norm, global_norm, make,
    warmup_cosine,
)

__all__ = [
    "Optimizer", "adafactor", "adamw", "clip_by_global_norm", "global_norm",
    "make", "warmup_cosine",
]
