"""Atomic sharded checkpoint store (fault-tolerance substrate).

Layout: ``<dir>/step_00000420/`` holding one ``.npy`` per pytree leaf
(raw little-endian bytes; logical dtype recorded in ``manifest.json`` so
bfloat16 round-trips without pickle) plus the manifest (paths, shapes,
dtypes, step, user metadata).

Guarantees:
* **Atomicity** — writes land in ``step_X.tmp`` and are ``os.rename``d
  into place; a crash mid-write never corrupts the latest checkpoint and
  ``latest_step`` only ever sees complete directories.
* **Async** — ``CheckpointManager.save`` snapshots to host memory
  synchronously (consistent cut) and writes on a background thread, so
  the train loop stalls only for the device→host copy.
* **Keep-k GC** — old steps are pruned after a successful save.
* **Elastic restore** — leaves are stored UNSHARDED (gathered); restore
  takes target ``shardings`` computed for the *current* mesh, so a job
  restarted on a different topology (e.g. 256 → 128 chips) reshards on
  load.  The divisibility-guarded specs in parallel/sharding.py are
  mesh-shape-agnostic, which is what makes this legal.
* **Multi-host** — every process writes only the leaves it owns the first
  shard of (addressable check); on this single-host container that is all
  of them.  Restore is process-local reads + device_put.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        parts = []
        for e in path:
            for attr in ("key", "name", "idx"):
                if hasattr(e, attr):
                    parts.append(str(getattr(e, attr)))
                    break
        names.append("/".join(parts) or "leaf")
    return names, [leaf for _, leaf in flat]


def _to_host(x) -> np.ndarray:
    arr = np.asarray(jax.device_get(x))
    return arr


def save(directory: str, step: int, tree, *, metadata: dict | None = None):
    """Write one atomic checkpoint.  Blocking; see CheckpointManager for
    the async path."""
    names, leaves = _leaf_paths(tree)
    hosts = [_to_host(x) for x in leaves]
    _write(directory, step, names, hosts, metadata or {})


def _write(directory, step, names, hosts, metadata):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "metadata": metadata, "leaves": []}
    for i, (name, arr) in enumerate(zip(names, hosts)):
        fname = f"leaf_{i:05d}.npy"
        # raw bytes as uint8 so bfloat16/ml_dtypes round-trip pickle-free
        np.save(os.path.join(tmp, fname),
                np.frombuffer(arr.tobytes(), np.uint8))
        manifest["leaves"].append({
            "name": name, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(directory, d,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    s = steps(directory)
    return s[-1] if s else None


def restore(directory: str, step: int, like, *, shardings=None):
    """Rebuild the pytree of ``like`` from checkpoint ``step``.

    ``shardings``: optional matching pytree of Shardings for the current
    mesh (elastic restore).  Returns (tree, metadata).
    """
    import jax.numpy as jnp
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves = _leaf_paths(like)
    if len(names) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target tree "
            f"has {len(names)} — structure changed")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(names))
    out = []
    for like_leaf, entry, shard in zip(leaves, manifest["leaves"],
                                       shard_leaves):
        raw = np.load(os.path.join(final, entry["file"]))
        dtype = jnp.dtype(entry["dtype"])
        arr = np.frombuffer(raw.tobytes(), dtype).reshape(entry["shape"])
        if tuple(arr.shape) != tuple(like_leaf.shape):
            raise ValueError(
                f"{entry['name']}: checkpoint shape {arr.shape} != target "
                f"{tuple(like_leaf.shape)}")
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jnp.asarray(arr))
    _, treedef = jax.tree_util.tree_flatten(like)
    return (jax.tree_util.tree_unflatten(treedef, out),
            manifest["metadata"])


class CheckpointManager:
    """Async keep-k checkpointer with atomic publish."""

    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, *, metadata: dict | None = None):
        self.wait()                         # one write in flight at a time
        names, leaves = _leaf_paths(tree)
        hosts = [_to_host(x) for x in leaves]   # consistent snapshot, sync

        def work():
            try:
                _write(self.directory, step, names, hosts, metadata or {})
                self._gc()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def _gc(self):
        for s in steps(self.directory)[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def latest_step(self):
        return latest_step(self.directory)

    def restore(self, step: int, like, *, shardings=None):
        self.wait()
        return restore(self.directory, step, like, shardings=shardings)
