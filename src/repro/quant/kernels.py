"""Dequant-fused panel GEMM — the quantized formats' compute loop.

Same Goto-style (block_m, block_n, block_k) panel schedule, Z-discipline
and fused-epilogue store step as ``kernels/panel_gemm``, with ONE change
in the streamed operand: the weight tile arrives as int8 codes (or 2-bit
packed ternary bytes) plus a per-column scale row, is dequantized into
registers (`codes -> fp32 * scale`), and feeds the same fp32 MXU
accumulation.  The tile's HBM->VMEM traffic shrinks 4x (int8) / 16x
(ternary) while the accumulation semantics stay those of the fp32
kernel on the dequantized panels — which is exactly the contract the
structural gate below enforces bitwise.

Every ``EpilogueSpec`` composes: the store step applies bias /
activation / softcap / residual (and the glu two-accumulator combine)
on the fp32 accumulator through the SAME shared ``apply_epilogue`` /
``apply_epilogue_glu`` definitions, so fused-quant == unfused-quant
holds bit-identically just like the fp32 path.

The interpret-mode oracle: ``quant_panel_gemm(interpret=True)`` must be
BIT-IDENTICAL to ``ref.gemm_blocked(x, dequantize_padded(...),
block_k)`` (+ the jnp epilogue under jit) — dequantization is
elementwise identical tiled or whole, so the only degree of freedom
left is the K accumulation order, which the blocked oracle pins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.panel_gemm import (DECODE_BLOCK_M, DEFAULT_BLOCK_K,
                                      DEFAULT_BLOCK_M, DEFAULT_BLOCK_N,
                                      EpilogueSpec, _act_fn, _finish,
                                      apply_epilogue, apply_epilogue_glu,
                                      splitk_combine)
from repro.quant import formats as F


def _dequant_tile(w_vals, s_vals, fmt: str) -> jax.Array:
    """codes tile -> fp32 weight tile (the in-registers dequant).
    ``s_vals`` is the tile's ``[block_k // GROUP_K, block_n]`` scale
    slab (tiles never straddle a group, so the slab is exact).
    Elementwise identical to ``formats.dequantize_padded`` on the full
    array — the bitwise contract with the blocked oracle depends on it,
    so both route through the same unpack/cast/expand/multiply ops."""
    if fmt == "ternary":
        codes = F.unpack_ternary_codes(w_vals)
    else:
        codes = w_vals.astype(jnp.float32)
    return codes * F.expand_scales(s_vals, codes.shape[-2])


def _quant_gemm_kernel(x_ref, w_ref, s_ref, *refs, nk: int, fmt: str,
                       spec: EpilogueSpec | None = None):
    """One (i, j, k) grid step: acc += x @ dequant(codes, scale)."""
    refs = list(refs)
    acc_ref = refs.pop()
    o_ref = refs.pop()
    bias_ref = refs.pop(0) if spec is not None and spec.bias else None
    res_ref = refs.pop(0) if spec is not None and spec.residual else None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(w_ref[...], s_ref[...], fmt)
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        acc = acc_ref[...]
        if spec is not None:
            if spec.bias:
                acc = acc + bias_ref[...]
            if spec.act is not None:
                acc = _act_fn(spec.act)(acc)
            acc = _finish(spec, acc, res_ref[...] if res_ref is not None
                          else None)
        o_ref[...] = acc.astype(o_ref.dtype)


def _quant_glu_kernel(x_ref, wg_ref, wu_ref, sg_ref, su_ref, *refs,
                      nk: int, fmt: str, spec: EpilogueSpec):
    """GLU variant: gate/up column panels of one quantized fused pack,
    each dequantized into registers, two fp32 accumulators over the K
    grid, ``act(gate) * up`` combined in the store step."""
    refs = list(refs)
    acc_u_ref = refs.pop()
    acc_g_ref = refs.pop()
    o_ref = refs.pop()
    bg_ref = refs.pop(0) if spec.bias else None
    bu_ref = refs.pop(0) if spec.bias else None
    res_ref = refs.pop(0) if spec.residual else None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_g_ref[...] = jnp.zeros_like(acc_g_ref)
        acc_u_ref[...] = jnp.zeros_like(acc_u_ref)

    x = x_ref[...]
    acc_g_ref[...] += jnp.dot(
        x, _dequant_tile(wg_ref[...], sg_ref[...], fmt),
        preferred_element_type=jnp.float32)
    acc_u_ref[...] += jnp.dot(
        x, _dequant_tile(wu_ref[...], su_ref[...], fmt),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        acc = apply_epilogue_glu(
            acc_g_ref[...], acc_u_ref[...], spec,
            bias_g=bg_ref[...] if bg_ref is not None else None,
            bias_u=bu_ref[...] if bu_ref is not None else None,
            residual=res_ref[...] if res_ref is not None else None)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("weight_format", "block_m", "block_n", "block_k",
                     "interpret", "out_dtype", "epilogue"),
)
def quant_panel_gemm(
    x: jax.Array,               # [M_pad, K_pad] activations (pre-padded)
    data: jax.Array,            # codes: [K_pad, N_pad] int8 or
                                #        [K_pad // 4, N_pad] uint8 ternary
    scales: jax.Array,          # [K_pad // GROUP_K, N_pad] fp32 scales
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    *,
    weight_format: str,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    out_dtype=None,
    epilogue: EpilogueSpec | None = None,
    interpret: bool = False,
) -> jax.Array:
    """C = epilogue(x @ dequant(data, scales)) via dequant-fused tiles."""
    fmt = weight_format
    if fmt not in F.FORMATS:
        raise ValueError(f"unknown weight_format {fmt!r}")
    kdiv = 4 if fmt == "ternary" else 1
    m, k = x.shape
    krows, n = data.shape
    assert k == krows * kdiv, (
        f"contraction mismatch: x K={k} vs codes K={krows * kdiv}")
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{n},{k}) not aligned to blocks "
        f"({block_m},{block_n},{block_k}); pack first")
    assert block_k % kdiv == 0
    assert block_k % F.GROUP_K == 0, (
        f"block_k={block_k} must span whole GROUP_K={F.GROUP_K} scale "
        f"groups (tiles never straddle a group)")
    nk = k // block_k
    wbk = block_k // kdiv               # codes-row depth of one K tile
    out_dtype = out_dtype or x.dtype
    spec = epilogue
    if spec is not None and spec.is_noop:
        spec = None
    glu = spec is not None and spec.glu is not None
    n_out = n // 2 if glu else n
    if glu:
        assert n % 2 == 0 and n_out % block_n == 0, (
            f"glu epilogue needs block-aligned column halves; got N={n} "
            f"with block_n={block_n} — pack with quantize_pack_fused")
    assert (bias is not None) == bool(spec is not None and spec.bias)
    assert (residual is not None) == bool(spec is not None and spec.residual)

    sbk = block_k // F.GROUP_K          # scale rows per K tile
    assert scales.shape[-2:] == (k // F.GROUP_K, n), (
        f"scales {scales.shape} vs expected ({k // F.GROUP_K},{n})")
    s2 = scales.reshape(k // F.GROUP_K, n).astype(jnp.float32)
    half_tiles = n_out // block_n
    x_spec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    w_spec = pl.BlockSpec((wbk, block_n), lambda i, j, kk: (kk, j))
    s_spec = pl.BlockSpec((sbk, block_n), lambda i, j, kk: (kk, j))
    if glu:      # up panel + its scale slab: column-offset index maps
        ops = [x, data, data, s2, s2]
        in_specs = [
            x_spec, w_spec,
            pl.BlockSpec((wbk, block_n),
                         lambda i, j, kk: (kk, j + half_tiles)),
            s_spec,
            pl.BlockSpec((sbk, block_n),
                         lambda i, j, kk: (kk, j + half_tiles)),
        ]
    else:
        ops = [x, data, s2]
        in_specs = [x_spec, w_spec, s_spec]
    if spec is not None and spec.bias:
        b2 = bias.reshape(1, n).astype(jnp.float32)
        ops.append(b2)
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        if glu:
            ops.append(b2)
            in_specs.append(pl.BlockSpec(
                (1, block_n), lambda i, j, kk: (0, j + half_tiles)))
    if spec is not None and spec.residual:
        assert residual.shape == (m, n_out), (
            f"residual {residual.shape} vs output ({m},{n_out})")
        ops.append(residual.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((block_m, block_n),
                                     lambda i, j, kk: (i, j)))

    if glu:
        kernel = functools.partial(_quant_glu_kernel, nk=nk, fmt=fmt,
                                   spec=spec)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((block_m, block_n), jnp.float32)]
    else:
        kernel = functools.partial(_quant_gemm_kernel, nk=nk, fmt=fmt,
                                   spec=spec)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]

    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n_out // block_n, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n_out), out_dtype),
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*ops)


# ------------------------------------------------- sparse ternary lane
def _sparse_layout_arrays(sparse_layout):
    """Group-walk constants from a pack's static ``sparse_layout``
    descriptor: ``gidx`` int32 ``[occ]`` (compacted slot -> original
    group id, the x index map's lookup) and ``occ_mat`` int32
    ``[n_blocks, occ]`` (per-column-panel occupancy of each slot, the
    kernel's skip predicate).  Static per pack, so they bake into the
    jitted call as constants — no scalar prefetch machinery needed."""
    import numpy as np
    k_groups, group_index, occ_bitmap, _bn = sparse_layout
    gidx = np.asarray(group_index, np.int32).reshape(-1)
    occ = np.zeros((len(occ_bitmap), len(group_index)), np.int32)
    for b, bits in enumerate(occ_bitmap):
        for s, g in enumerate(group_index):
            occ[b, s] = (bits >> int(g)) & 1
    return gidx, occ


def _sparse_gemm_kernel(gidx_ref, occ_ref, x_ref, w_ref, s_ref, *refs,
                        ns: int, spec: EpilogueSpec | None = None):
    """One (i, j, s) grid step of the sparse walk: slot ``s`` is the
    s-th OCCUPIED group (union over column panels — ``gidx_ref`` holds
    its original K offset, consumed by the x index map); the accumulate
    is additionally predicated on this column panel's own occupancy
    (``occ_ref[j, s]``), so each panel touches only its nonzero groups.
    Skipping a group is bitwise identical to the dense kernel adding its
    all-zero product tile (fp32 ``acc + (+0.0)`` preserves ``acc``), so
    the dense Z-discipline contract carries over unchanged."""
    del gidx_ref
    refs = list(refs)
    acc_ref = refs.pop()
    o_ref = refs.pop()
    bias_ref = refs.pop(0) if spec is not None and spec.bias else None
    res_ref = refs.pop(0) if spec is not None and spec.residual else None
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[j, s] != 0)
    def _accum():
        w = _dequant_tile(w_ref[...], s_ref[...], "ternary")
        acc_ref[...] += jnp.dot(x_ref[...], w,
                                preferred_element_type=jnp.float32)

    @pl.when(s == ns - 1)
    def _store():
        acc = acc_ref[...]
        if spec is not None:
            if spec.bias:
                acc = acc + bias_ref[...]
            if spec.act is not None:
                acc = _act_fn(spec.act)(acc)
            acc = _finish(spec, acc, res_ref[...] if res_ref is not None
                          else None)
        o_ref[...] = acc.astype(o_ref.dtype)


def _sparse_glu_kernel(gidx_ref, occ_ref, x_ref, wg_ref, wu_ref, sg_ref,
                       su_ref, *refs, ns: int, half_tiles: int,
                       spec: EpilogueSpec):
    """GLU variant of the sparse walk: the gate and up column panels
    carry separate occupancy columns of the bitmap, so each half skips
    its own zero groups independently."""
    del gidx_ref
    refs = list(refs)
    acc_u_ref = refs.pop()
    acc_g_ref = refs.pop()
    o_ref = refs.pop()
    bg_ref = refs.pop(0) if spec.bias else None
    bu_ref = refs.pop(0) if spec.bias else None
    res_ref = refs.pop(0) if spec.residual else None
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_g_ref[...] = jnp.zeros_like(acc_g_ref)
        acc_u_ref[...] = jnp.zeros_like(acc_u_ref)

    x = x_ref[...]

    @pl.when(occ_ref[j, s] != 0)
    def _accum_g():
        acc_g_ref[...] += jnp.dot(
            x, _dequant_tile(wg_ref[...], sg_ref[...], "ternary"),
            preferred_element_type=jnp.float32)

    @pl.when(occ_ref[j + half_tiles, s] != 0)
    def _accum_u():
        acc_u_ref[...] += jnp.dot(
            x, _dequant_tile(wu_ref[...], su_ref[...], "ternary"),
            preferred_element_type=jnp.float32)

    @pl.when(s == ns - 1)
    def _store():
        acc = apply_epilogue_glu(
            acc_g_ref[...], acc_u_ref[...], spec,
            bias_g=bg_ref[...] if bg_ref is not None else None,
            bias_u=bu_ref[...] if bu_ref is not None else None,
            residual=res_ref[...] if res_ref is not None else None)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sparse_layout", "block_m", "block_n", "interpret",
                     "out_dtype", "epilogue"),
)
def sparse_quant_panel_gemm(
    x: jax.Array,               # [M_pad, K_pad] — padded to the LOGICAL K
    data: jax.Array,            # [occ * GROUP_K // 4, N_pad] uint8 codes
    scales: jax.Array,          # [occ, N_pad] fp32 survivor scale rows
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    *,
    sparse_layout: tuple,       # SparseTernaryPackedWeight.sparse_layout
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    out_dtype=None,
    epilogue: EpilogueSpec | None = None,
    interpret: bool = False,
) -> jax.Array:
    """C = epilogue(x @ dequant(compressed codes)) — the sparse walk.

    The K grid runs over the ``occ`` compacted slots (one ``GROUP_K``
    group per step, NOT the plan's ``block_k``: the compressed layout is
    group-granular by construction), and each column panel's accumulate
    is predicated on its occupancy bit.  The activations arrive padded
    to the LOGICAL ``K_pad``; the x index map jumps to each surviving
    group's original K offset via the baked-in ``gidx`` table.

    Bitwise contract: identical to ``quant_panel_gemm(block_k=GROUP_K)``
    on the decompressed codes (and hence, transitively, to
    ``ref.gemm_blocked`` at ``GROUP_K``) — the structural gate below
    asserts both.
    """
    k_groups, group_index, _occ_bitmap, pack_bn = sparse_layout
    assert block_n == pack_bn, (
        f"sparse occupancy is per pack column block: kernel block_n="
        f"{block_n} must equal the pack's block_n={pack_bn}")
    rpg = F.GROUP_K // 4
    m, k = x.shape
    rows, n = data.shape
    ns = len(group_index)
    assert k == k_groups * F.GROUP_K, (
        f"x K={k} vs logical padded K={k_groups * F.GROUP_K} "
        f"(pad activations to the LOGICAL depth, not the compacted one)")
    assert rows == ns * rpg, (
        f"compacted codes rows {rows} vs {ns} occupied groups x {rpg}")
    assert m % block_m == 0 and n % block_n == 0, (
        f"shapes ({m},{n}) not aligned to blocks ({block_m},{block_n})")
    assert scales.shape[-2:] == (ns, n), (
        f"scales {scales.shape} vs expected ({ns},{n})")
    out_dtype = out_dtype or x.dtype
    spec = epilogue
    if spec is not None and spec.is_noop:
        spec = None
    glu = spec is not None and spec.glu is not None
    n_out = n // 2 if glu else n
    if glu:
        assert n % 2 == 0 and n_out % block_n == 0, (
            f"glu epilogue needs block-aligned column halves; got N={n} "
            f"with block_n={block_n} — pack with quantize_pack_fused")
    assert (bias is not None) == bool(spec is not None and spec.bias)
    assert (residual is not None) == bool(spec is not None
                                          and spec.residual)

    if ns == 0:
        # fully-zero weight: the Z-discipline result is a zero
        # accumulator through the shared jnp epilogue (full width —
        # apply_epilogue splits the glu halves itself)
        z = jnp.zeros((m, n), jnp.float32)
        if spec is not None:
            z = apply_epilogue(z, spec, bias=bias, residual=residual)
        return z[:, :n_out].astype(out_dtype)

    gidx, occ_mat = _sparse_layout_arrays(sparse_layout)
    s2 = scales.reshape(ns, n).astype(jnp.float32)
    half_tiles = n_out // block_n
    # the group-walk tables ride in as SCALAR-PREFETCH operands (index
    # maps may not capture array constants): every index map receives
    # (i, j, s, gidx_ref, occ_ref) and the x map jumps to slot s's
    # original group offset
    x_spec = pl.BlockSpec((block_m, F.GROUP_K),
                          lambda i, j, s, gidx, occ: (i, gidx[s]))
    w_spec = pl.BlockSpec((rpg, block_n),
                          lambda i, j, s, gidx, occ: (s, j))
    s_spec = pl.BlockSpec((1, block_n),
                          lambda i, j, s, gidx, occ: (s, j))
    if glu:
        ops = [x, data, data, s2, s2]
        in_specs = [
            x_spec, w_spec,
            pl.BlockSpec((rpg, block_n),
                         lambda i, j, s, gidx, occ: (s, j + half_tiles)),
            s_spec,
            pl.BlockSpec((1, block_n),
                         lambda i, j, s, gidx, occ: (s, j + half_tiles)),
        ]
    else:
        ops = [x, data, s2]
        in_specs = [x_spec, w_spec, s_spec]
    if spec is not None and spec.bias:
        b2 = bias.reshape(1, n).astype(jnp.float32)
        ops.append(b2)
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda i, j, s, gidx, occ: (0, j)))
        if glu:
            ops.append(b2)
            in_specs.append(pl.BlockSpec(
                (1, block_n),
                lambda i, j, s, gidx, occ: (0, j + half_tiles)))
    if spec is not None and spec.residual:
        assert residual.shape == (m, n_out), (
            f"residual {residual.shape} vs output ({m},{n_out})")
        ops.append(residual.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((block_m, block_n),
                                     lambda i, j, s, gidx, occ: (i, j)))

    if glu:
        kernel = functools.partial(_sparse_glu_kernel, ns=ns,
                                   half_tiles=half_tiles, spec=spec)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((block_m, block_n), jnp.float32)]
    else:
        kernel = functools.partial(_sparse_gemm_kernel, ns=ns, spec=spec)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // block_m, n_out // block_n, ns),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, s, gidx, occ: (i, j)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n_out), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(gidx), jnp.asarray(occ_mat), *ops)


def sparse_ref(x, spw, *, epilogue=None, bias=None, residual=None):
    """The sparse lane's oracle: ``ref.gemm_blocked`` at ``GROUP_K``
    over the DECOMPRESSED panels + the shared jnp epilogue — the dense
    contract's oracle evaluated on the layout round-trip, so sparse
    correctness never re-derives a tolerance."""
    from repro.kernels import ref
    deq = F.dequantize(spw)     # decompresses first
    acc = ref.gemm_blocked(x, deq, F.GROUP_K, out_dtype=jnp.float32)
    spec = epilogue
    if spec is not None and spec.is_noop:
        spec = None
    if spec is None:
        return acc
    return jax.jit(
        lambda a, b, r: apply_epilogue(
            a, spec, bias=b, residual=r).astype(jnp.float32)
    )(acc, bias, residual)


# ----------------------------------------------------------- split-K lane
def _quant_splitk_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                         nks: int, fmt: str):
    """One (s, i, j, kk) grid step of the quantized split-K partials
    pass: the K-slice's codes+scales tile dequantizes into registers and
    accumulates the slice's fp32 partial — the decode lane's
    reduction-side occupancy with the 4x/16x tile-byte reduction decode
    most needs (weight bytes dominate at M <= 8)."""
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(w_ref[...], s_ref[...], fmt)
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(kk == nks - 1)
    def _store():
        o_ref[...] = acc_ref[...][None]


@functools.partial(
    jax.jit,
    static_argnames=("weight_format", "split_k", "block_m", "block_n",
                     "block_k", "interpret", "out_dtype", "epilogue"),
)
def quant_panel_gemm_splitk(
    x: jax.Array,
    data: jax.Array,
    scales: jax.Array,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    *,
    weight_format: str,
    split_k: int,
    block_m: int = DECODE_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    out_dtype=None,
    epilogue: EpilogueSpec | None = None,
    interpret: bool = False,
) -> jax.Array:
    """C = epilogue(splitk_combine(per-slice x @ dequant(codes, scales))).

    The dequant-fused analogue of ``panel_gemm_splitk``: grid
    ``(s, i, j, kk)`` with per-slice fp32 partials, combined by the
    shared deterministic tree and finished by the shared jnp epilogue.
    Bit-identical to ``ref.gemm_splitk`` over the dequantized panels at
    the same ``(block_k, split_k)`` — the structural gate below."""
    fmt = weight_format
    if fmt not in F.FORMATS:
        raise ValueError(f"unknown weight_format {fmt!r}")
    kdiv = 4 if fmt == "ternary" else 1
    m, k = x.shape
    krows, n = data.shape
    assert k == krows * kdiv, (
        f"contraction mismatch: x K={k} vs codes K={krows * kdiv}")
    assert split_k >= 1 and k % split_k == 0, (
        f"K={k} not divisible by split_k={split_k}")
    ks = k // split_k
    assert m % block_m == 0 and n % block_n == 0 and ks % block_k == 0, (
        f"shapes ({m},{n},{k}) / slice depth {ks} not aligned to blocks "
        f"({block_m},{block_n},{block_k}); pack first")
    assert block_k % kdiv == 0
    assert block_k % F.GROUP_K == 0, (
        f"block_k={block_k} must span whole GROUP_K={F.GROUP_K} scale "
        f"groups (tiles never straddle a group)")
    nks = ks // block_k
    wbk = block_k // kdiv
    sbk = block_k // F.GROUP_K
    out_dtype = out_dtype or x.dtype
    spec = epilogue
    if spec is not None and spec.is_noop:
        spec = None
    glu = spec is not None and spec.glu is not None
    n_out = n // 2 if glu else n
    if glu:
        assert n % 2 == 0 and n_out % block_n == 0, (
            f"glu epilogue needs block-aligned column halves; got N={n} "
            f"with block_n={block_n} — pack with quantize_pack_fused")
    assert (bias is not None) == bool(spec is not None and spec.bias)
    assert (residual is not None) == bool(spec is not None
                                          and spec.residual)
    assert scales.shape[-2:] == (k // F.GROUP_K, n), (
        f"scales {scales.shape} vs expected ({k // F.GROUP_K},{n})")
    s2 = scales.reshape(k // F.GROUP_K, n).astype(jnp.float32)

    partials = pl.pallas_call(
        functools.partial(_quant_splitk_kernel, nks=nks, fmt=fmt),
        grid=(split_k, m // block_m, n // block_n, nks),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda s, i, j, kk: (i, s * nks + kk)),
            pl.BlockSpec((wbk, block_n),
                         lambda s, i, j, kk: (s * nks + kk, j)),
            pl.BlockSpec((sbk, block_n),
                         lambda s, i, j, kk: (s * nks + kk, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda s, i, j, kk: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((split_k, m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x, data, s2)
    acc = splitk_combine(partials)
    if spec is not None:
        acc = apply_epilogue(acc, spec, bias=bias, residual=residual)
    return acc.astype(out_dtype)


# --------------------------------------------------- structural gate
_gate_memo: dict[tuple, bool] = {}


def quant_gate(bm: int, bn: int, bk: int, fmt: str, *,
               epilogue: EpilogueSpec | None = None,
               reduced_k_blocks: int = 2, seed: int = 0,
               split_k: int = 1, sparse: bool = False) -> bool:
    """The autotune reject protocol for a quantized block triple: the
    interpret-mode dequant-fused kernel on a reduced shape with a real
    K-carry must be BIT-IDENTICAL to ``ref.gemm_blocked`` over the
    dequantized panels (+ the jnp epilogue under jit).  ``split_k > 1``
    gates the decode lane's split-K variant against ``ref.gemm_splitk``
    over the same dequantized panels.  This attests the KERNEL (tiling,
    dequant placement, accumulation order); the format's numeric error
    vs fp32 is the error ledger's separate, tolerance-gated concern.

    ``sparse=True`` gates the compressed-ternary walk instead: on a
    reduced group-sparse weight (whole zero groups plus one group zeroed
    in only some column panels, exercising the per-panel skip), the
    sparse kernel must be bit-identical BOTH to the dense ternary kernel
    at ``block_k=GROUP_K`` on the same codes AND to ``sparse_ref`` (the
    blocked oracle over the decompressed layout).  The sparse walk is
    group-granular — it ignores the plan's ``block_k`` — so the sparse
    gate memoizes per (block_m, block_n, epilogue) only.
    """
    import numpy as np

    from repro.core import bitexact
    from repro.kernels import ref

    if sparse:
        if fmt != "ternary" or split_k != 1:
            return False            # the sparse lane is ternary, split_k=1
        bk = F.GROUP_K              # the walk's only K granularity
    key = (bm, bn, bk, fmt, epilogue, split_k, sparse)
    if key in _gate_memo:
        return _gate_memo[key]
    rng = np.random.default_rng(seed)
    glu = epilogue is not None and epilogue.glu is not None
    if sparse:
        kg_r = 8
        k_r = kg_r * F.GROUP_K
        n_r = 2 * bn if glu else bn
        x = jnp.asarray(rng.standard_normal((bm, k_r)), jnp.float32)
        wf = rng.standard_normal((k_r, n_r))
        G = F.GROUP_K
        wf[1 * G:2 * G] = 0.0           # whole zero groups (compress away)
        wf[4 * G:5 * G] = 0.0
        wf[6 * G:7 * G, :bn] = 0.0      # panel-local zero (occupancy skip)
        w = jnp.asarray(wf, jnp.float32)
        dq = F.quantize_pack(w, "ternary", block_n=bn, block_k=F.GROUP_K,
                             sparse=False, measure=False)
        sq = F.compress_ternary(dq)
        bias = (jnp.asarray(rng.standard_normal((n_r,)), jnp.float32)
                if epilogue is not None and epilogue.bias else None)
        n_out = bn if glu else n_r
        res = (jnp.asarray(rng.standard_normal((bm, n_out)), jnp.float32)
               if epilogue is not None and epilogue.residual else None)
        y_s = sparse_quant_panel_gemm(
            x, sq.data, sq.scales, bias, res,
            sparse_layout=sq.sparse_layout, block_m=bm, block_n=bn,
            epilogue=epilogue, interpret=True)
        y_d = quant_panel_gemm(
            x, dq.data, dq.scales, bias, res, weight_format="ternary",
            block_m=bm, block_n=bn, block_k=F.GROUP_K,
            epilogue=epilogue, interpret=True)
        oracle = sparse_ref(x, sq, epilogue=epilogue, bias=bias,
                            residual=res)
        ok = (bitexact.bit_identical(np.asarray(y_s), np.asarray(y_d))
              and bitexact.bit_identical(np.asarray(y_s),
                                         np.asarray(oracle)))
        _gate_memo[key] = ok
        return ok
    m_r, k_r = bm, reduced_k_blocks * bk * split_k
    n_r = 2 * bn if glu else bn
    x = jnp.asarray(rng.standard_normal((m_r, k_r)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k_r, n_r)), jnp.float32)
    q, s = F.quantize(w, fmt)
    data = F.pack_ternary_codes(q) if fmt == "ternary" else q
    deq = F.dequantize_padded(data, s, fmt)
    bias = (jnp.asarray(rng.standard_normal((n_r,)), jnp.float32)
            if epilogue is not None and epilogue.bias else None)
    n_out = bn if glu else n_r
    res = (jnp.asarray(rng.standard_normal((m_r, n_out)), jnp.float32)
           if epilogue is not None and epilogue.residual else None)
    if split_k > 1:
        y = quant_panel_gemm_splitk(x, data, s, bias, res,
                                    weight_format=fmt, split_k=split_k,
                                    block_m=bm, block_n=bn, block_k=bk,
                                    epilogue=epilogue, interpret=True)
        acc = ref.gemm_splitk(x, deq, bk, split_k, out_dtype=jnp.float32)
    else:
        y = quant_panel_gemm(x, data, s, bias, res, weight_format=fmt,
                             block_m=bm, block_n=bn, block_k=bk,
                             epilogue=epilogue, interpret=True)
        acc = ref.gemm_blocked(x, deq, bk, out_dtype=jnp.float32)
    if epilogue is None:
        oracle = acc
    else:
        oracle = jax.jit(
            lambda a, b, r: apply_epilogue(
                a, epilogue, bias=b, residual=r).astype(jnp.float32)
        )(acc, bias, res)
    ok = bitexact.bit_identical(np.asarray(y), np.asarray(oracle))
    _gate_memo[key] = ok
    return ok
