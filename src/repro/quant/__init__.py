"""Quantized pre-pack subsystem: weight formats that shrink the bytes
the inner loop streams, a dequant-fused panel kernel, and the error
ledger that keeps reduced precision honest.

    from repro.core import packing
    qpw = packing.pack(w, quant="int8")       # quantize + pack at load
    p   = gemm.plan_for_packed(m, qpw)        # plan carries weight_format
    y   = gemm.execute(p, x, qpw)             # dequant-fused compute loop

See docs/quantization.md for the format definitions, the tolerance
contract, the ledger schema, and the mixed-precision model policy.
"""
from repro.quant.formats import (FORMATS, GROUP_K,
                                 SPARSE_DENSITY_THRESHOLD,
                                 QuantFormatError, QuantizedPackedWeight,
                                 SparseTernaryPackedWeight,
                                 compress_ternary, decompress_ternary,
                                 density_bucket_of, dequantize,
                                 dequantize_padded, expand_scales,
                                 pack_ternary_codes, quantize,
                                 quantize_int8, quantize_pack,
                                 quantize_pack_fused, quantize_ternary,
                                 unpack_ternary_codes, weight_itemsize)
from repro.quant.kernels import (quant_gate, quant_panel_gemm,
                                 quant_panel_gemm_splitk,
                                 sparse_quant_panel_gemm, sparse_ref)
from repro.quant.ledger import (PROBE_M, TOLERANCES, LedgerEntry,
                                QuantToleranceError)
from repro.quant import ledger

__all__ = [
    "FORMATS", "GROUP_K", "LedgerEntry", "PROBE_M", "QuantFormatError",
    "QuantToleranceError", "QuantizedPackedWeight",
    "SPARSE_DENSITY_THRESHOLD", "SparseTernaryPackedWeight", "TOLERANCES",
    "compress_ternary", "decompress_ternary", "density_bucket_of",
    "dequantize", "dequantize_padded", "expand_scales", "ledger",
    "pack_ternary_codes", "quant_gate", "quant_panel_gemm",
    "quant_panel_gemm_splitk", "quantize",
    "quantize_int8", "quantize_pack", "quantize_pack_fused",
    "quantize_ternary", "sparse_quant_panel_gemm", "sparse_ref",
    "unpack_ternary_codes", "weight_itemsize",
]
