"""Quantized pre-pack formats — paper lever 2 extended below fp32.

The paper's load-issue-bound microbenchmark (610-680 GFLOPS once operand
loads interleave with the FMA stream) says the one lever left *inside*
the inner loop is bytes-loaded-per-tile.  These formats shrink the
packed weight the kernel streams:

  * ``int8``    — per-output-channel symmetric: one fp32 scale per
                  logical column, codes in [-127, 127].  4x fewer weight
                  bytes per tile than fp32.
  * ``ternary`` — 2-bit codes in {-1, 0, +1} + per-column scale
                  (TWN-style threshold, sparse-aware: the zero fraction
                  is recorded on the pack), four codes packed per byte
                  along K.  16x fewer weight bytes per tile than fp32.

Both are *pack-time* formats: ``core.packing.pack(quant=...)`` /
``pack_fused(quant=...)`` produce a :class:`QuantizedPackedWeight` once
at model load, and the dequant-fused kernel (``quant/kernels``)
dequantizes tiles into registers on the way to the fp32 accumulator.
Scale granularity is one scale per (output column, ``GROUP_K``-row K
group) — the production grouping of GGUF-class formats, and the reason
the error stays well inside the ledger tolerance at paper-scale K.
``GROUP_K`` divides every ``block_k`` the policy can resolve (both are
128-multiples), so a kernel tile never straddles a scale group, by
construction.

Reduced precision is done *honestly*, the way the paper reports BNNS
Graph's per-shape error: every concrete pack is measured against its
fp32 oracle and recorded in the error ledger (``quant/ledger``), which
ENFORCES the per-format tolerance at pack time.  Abstract packs
(``jax.eval_shape`` for sharding resolution) skip the measurement — no
values exist to measure.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PackedWeight, fit_block

FORMATS = ("int8", "ternary")

# TWN threshold factor: codes are 0 where |w| <= TERNARY_DELTA * mean|w|
# (the sparse-aware split of the ETH ternary-GEMM paper); the per-group
# scale is the mean magnitude of the surviving weights.
TERNARY_DELTA = 0.7

# K rows per scale group (per output column).  128 divides every
# block_k the policy can resolve (fit_block/_fit_vmem bottom out at the
# 128 lane), so one kernel K tile spans whole groups — the "tiles never
# straddle a scale group" alignment contract.
GROUP_K = 128

# Zero-group fraction at which a concrete ternary pack compresses by
# default (``quantize_pack(sparse=None)``): the sparse layout must skip
# enough whole GROUP_K K-groups to pay for its bitmap + group-offset
# index and the per-group (vs per-block_k) kernel schedule.  Set well
# above the analytic break-even ``gemm.policy.sparse_threshold()``
# resolves from the t_pred byte model (~0.03), because the MEASURED
# crossover is higher and shape-dependent: host dot kernels are not
# monotone in K (table8's density sweep caught a 1024x1024 shape whose
# compacted K' = 768 dot ran slower than the full K = 1024 dot, losing
# 15% at zero-group fraction 0.25), so the arm engages only where the
# sweep shows every paper shape winning.  measured_autotune can still
# override the arm per shape.
SPARSE_DENSITY_THRESHOLD = 0.3

# Four packed zero codes (code 0 stores as crumb 0b01): the byte value
# an all-zero ternary K-run packs to — also what pack padding packs to,
# so padded tail groups compress away like real zero groups.
_TERNARY_ZERO_BYTE = 0x55


def density_bucket_of(group_sparsity: float) -> int:
    """Plan-key bucket for a sparse pack's zero-group fraction:
    ``floor(gs * 10)`` clamped to 0..9.  ``-1`` (negative input) is the
    dense arm's sentinel — a plan is sparse iff its bucket is >= 0."""
    if group_sparsity < 0:
        return -1
    return min(9, max(0, int(group_sparsity * 10.0)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedPackedWeight(PackedWeight):
    """A weight quantized AND packed once at load (see module docstring).

    Subclasses :class:`~repro.core.packing.PackedWeight` so it flows
    through every existing consumer (``layers.linear``, ``fused_linear``,
    the packed-head branch, sharding walks); ``gemm.execute`` dispatches
    it to the backend's dequant-fused run.

    data:   codes — int8 ``[..., K_pad, N_pad]`` for ``int8``;
            uint8 ``[..., K_pad // 4, N_pad]`` for ``ternary`` (four
            2-bit codes per byte along K, code = value + 1).
    scales: fp32 ``[..., K_pad // GROUP_K, N_pad]`` per-(column,
            K-group) scales (all-padding groups carry scale 0 and codes
            0, so padded tiles dequantize to exact 0).
    fmt:    ``"int8"`` | ``"ternary"`` (static; rides onto the plan as
            ``weight_format``).
    sparsity: fraction of zero codes (ternary's sparse-aware stat;
            -1.0 when packed from abstract values).
    """
    scales: jax.Array | None = None
    fmt: str = dataclasses.field(default="int8",
                                 metadata=dict(static=True))
    sparsity: float = dataclasses.field(default=-1.0,
                                        metadata=dict(static=True))

    @property
    def k_pad(self) -> int:
        """Padded contraction depth (the codes' K rows, unpacked)."""
        rows = self.data.shape[-2]
        return rows * 4 if self.fmt == "ternary" else rows

    @property
    def n_pad(self) -> int:
        return self.data.shape[-1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseTernaryPackedWeight(QuantizedPackedWeight):
    """Compressed ternary pack: all-zero ``GROUP_K`` K-groups removed.

    The dense ternary layout streams every 2-bit code; at group-level
    sparsity the compressed layout stores only the K-groups that carry a
    nonzero code ANYWHERE (the union across column blocks and stacked
    layers, so one column layout serves every panel), plus two static
    index structures:

    data:       uint8 ``[..., occ * (GROUP_K // 4), N_pad]`` — the
                surviving code groups, dense-packed in ascending original
                order (``occ = len(group_index)``).
    scales:     fp32 ``[..., occ, N_pad]`` — the survivors' scale rows.
    k_groups:   total LOGICAL padded groups (``k_pad // GROUP_K``) —
                ``k_pad`` derives from this, NOT from the compacted rows.
    group_index:   surviving original group ids, ascending.
    group_offsets: original group id -> compacted slot, -1 when removed
                (the group-offset index; inverse of ``group_index``).
    occ_bitmap: one int bitmask per ``block_n`` column block, bit ``g``
                set iff group ``g`` has a nonzero code in that block —
                the per-(column-block, K-group) occupancy the sparse
                kernel's per-panel skip reads.

    Round-trip with the dense layout is exact by construction: a removed
    group is all ``0x55`` bytes (four zero codes) with all-zero scale
    rows, which is exactly what :func:`decompress_ternary` re-inserts.
    Flows through ``pack_for_inference``, stacked ``[L, K, N]`` packs
    and fused split maps unchanged — it subclasses the dense pack and
    keeps every inherited field's meaning.
    """
    k_groups: int = dataclasses.field(default=0,
                                      metadata=dict(static=True))
    group_index: tuple = dataclasses.field(default=(),
                                           metadata=dict(static=True))
    group_offsets: tuple = dataclasses.field(default=(),
                                             metadata=dict(static=True))
    occ_bitmap: tuple = dataclasses.field(default=(),
                                          metadata=dict(static=True))

    @property
    def k_pad(self) -> int:
        """LOGICAL padded contraction depth (what the activations pad
        to) — the compacted codes hold fewer rows than this."""
        return self.k_groups * GROUP_K

    @property
    def occupied(self) -> int:
        return len(self.group_index)

    @property
    def group_sparsity(self) -> float:
        """Zero-group fraction — the density-sweep knob (bench "density")
        and the quantity ``SPARSE_DENSITY_THRESHOLD`` thresholds."""
        if not self.k_groups:
            return 0.0
        return 1.0 - len(self.group_index) / self.k_groups

    @property
    def density(self) -> float:
        """Occupied-group fraction: effective weight bytes / dense."""
        return 1.0 - self.group_sparsity

    @property
    def density_bucket(self) -> int:
        """Plan-key bucket (0..9) — rides onto the plan so the sparse
        arm is cache-keyed separately per density decile."""
        return density_bucket_of(self.group_sparsity)

    @property
    def index_bytes(self) -> int:
        """Bytes of sparse metadata the kernel reads alongside the code
        tiles: the occupancy bitmaps + the group-offset index (int32
        slots) — the overhead side of the analytic threshold."""
        nb = max(1, self.n_pad // self.block_n)
        return nb * ((self.k_groups + 7) // 8) + 4 * self.k_groups

    @property
    def sparse_layout(self) -> tuple:
        """Hashable static descriptor of the compressed geometry —
        ``(k_groups, group_index, occ_bitmap, block_n)`` — the backends
        key their jitted sparse runs on it and rebuild the group-walk
        constants from it."""
        return (self.k_groups, self.group_index, self.occ_bitmap,
                self.block_n)


class QuantFormatError(ValueError):
    pass


def _check_fmt(fmt: str):
    if fmt not in FORMATS:
        raise QuantFormatError(
            f"unknown quant format {fmt!r}; choose from {FORMATS}")


def weight_itemsize(fmt: str | None) -> float:
    """Bytes per weight element the kernel streams (the VMEM-budget and
    bytes-per-tile model): fp32 4.0, int8 1.0, ternary 0.25."""
    if fmt in (None, "fp32"):
        return 4.0
    _check_fmt(fmt)
    return 1.0 if fmt == "int8" else 0.25


def _is_concrete(x) -> bool:
    return isinstance(x, np.ndarray) or (
        isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer))


# ------------------------------------------------------------ quantizers
def _grouped(w: jax.Array):
    """``[..., K, N]`` -> (``[..., Kg, GROUP_K, N]`` zero-padded view,
    pad rows added).  Group stats run over axis -2 of the view."""
    k = w.shape[-2]
    pk = (-k) % GROUP_K
    if pk:
        w = _pad_tail(w, pk, 0, w.ndim)
    kg = w.shape[-2] // GROUP_K
    return w.reshape(*w.shape[:-2], kg, GROUP_K, w.shape[-1]), pk


def _ungroup(codes_g: jax.Array, k: int) -> jax.Array:
    out = codes_g.reshape(*codes_g.shape[:-3],
                          codes_g.shape[-3] * GROUP_K, codes_g.shape[-1])
    return out[..., :k, :]


def expand_scales(scales: jax.Array, k: int) -> jax.Array:
    """Broadcast group scales ``[..., Kg, N]`` to per-row ``[..., k, N]``
    — the ONE expansion shared by the kernel tile path, the xla dequant
    run, and the oracle (bitwise-identical values either way)."""
    return jnp.repeat(scales, GROUP_K, axis=-2)[..., :k, :]


def quantize_int8(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Group-wise symmetric int8 for ``w[..., K, N]``: one scale per
    (output column, GROUP_K-row K group) — the GGUF-class production
    grouping.

    Returns (codes int8 ``[..., K, N]``, scales fp32 ``[..., ceil(K /
    GROUP_K), N]``).  Codes are ``round(w / scale)`` with ``scale =
    max_group |w| / 127`` — by construction ``|codes| <= 127`` and the
    round-trip error per element is bounded by its group's ``scale /
    2``.  All-zero groups get scale 0 / codes 0.
    """
    w = jnp.asarray(w, jnp.float32)
    k = w.shape[-2]
    g, _ = _grouped(w)
    amax = jnp.max(jnp.abs(g), axis=-2, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(g / safe), -127, 127).astype(jnp.int8)
    return _ungroup(q, k), scale[..., 0, :].astype(jnp.float32)


def quantize_ternary(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """TWN-style ternary for ``w[..., K, N]``: codes in {-1, 0, +1}
    (int8, NOT yet 2-bit packed — see :func:`pack_ternary_codes`) and a
    per-(column, K-group) fp32 scale.

    Threshold ``delta = TERNARY_DELTA * mean_group |w|`` zeroes the
    small weights (the sparse-aware split); the scale is the mean
    magnitude of the survivors, the L2-optimal reconstruction for that
    support.
    """
    w = jnp.asarray(w, jnp.float32)
    k = w.shape[-2]
    g, _ = _grouped(w)
    mag = jnp.abs(g)
    # group mean over the LOGICAL rows only (a padded tail group must
    # not dilute the threshold of its real rows)
    kg = g.shape[-3]
    last_real = k - (kg - 1) * GROUP_K          # rows of the tail group
    counts = jnp.full((kg, 1, 1), GROUP_K,
                      jnp.float32).at[-1, 0, 0].set(float(last_real))
    delta = (TERNARY_DELTA
             * jnp.sum(mag, axis=-2, keepdims=True) / counts)
    mask = mag > delta
    t = jnp.where(mask, jnp.sign(g), 0.0).astype(jnp.int8)
    cnt = jnp.sum(mask, axis=-2, keepdims=True)
    s = jnp.where(cnt > 0,
                  jnp.sum(jnp.where(mask, mag, 0.0), axis=-2,
                          keepdims=True) / jnp.maximum(cnt, 1),
                  0.0)
    return _ungroup(t, k), s[..., 0, :].astype(jnp.float32)


def quantize(w: jax.Array, fmt: str) -> tuple[jax.Array, jax.Array]:
    _check_fmt(fmt)
    return quantize_int8(w) if fmt == "int8" else quantize_ternary(w)


# ------------------------------------------------- ternary 2-bit packing
def pack_ternary_codes(t: jax.Array) -> jax.Array:
    """Pack ternary codes ``[..., K, N]`` (K % 4 == 0) into uint8
    ``[..., K // 4, N]`` — four consecutive K rows per byte, row ``4r+i``
    in bits ``[2i, 2i+2)``, stored as ``code + 1`` in {0, 1, 2}."""
    k = t.shape[-2]
    if k % 4:
        raise QuantFormatError(f"ternary packing needs K % 4 == 0; got "
                               f"K={k} (pad to the block first)")
    c = (t.astype(jnp.int32) + 1).astype(jnp.uint8)
    c4 = c.reshape(*t.shape[:-2], k // 4, 4, t.shape[-1])
    out = c4[..., 0, :]
    for i in range(1, 4):
        out = out | (c4[..., i, :] << (2 * i))
    return out


def unpack_ternary_codes(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_ternary_codes` — fp32 codes in {-1, 0, +1},
    ``[..., K, N]``.  The ONE unpack definition shared by the kernel tile
    path, the xla dequant run, and the oracle, so all three see
    elementwise-identical values (exact small integers)."""
    parts = [((packed >> (2 * i)) & 3).astype(jnp.float32) - 1.0
             for i in range(4)]
    stacked = jnp.stack(parts, axis=-2)          # [..., K//4, 4, N]
    return stacked.reshape(*packed.shape[:-2], packed.shape[-2] * 4,
                           packed.shape[-1])


# ------------------------------------------------------------ dequantize
def dequantize_padded(data: jax.Array, scales: jax.Array,
                      fmt: str) -> jax.Array:
    """Dequantize packed codes back to the padded fp32 panel layout
    ``[..., K_pad, N_pad]`` — elementwise the same ops the kernel applies
    per tile (codes -> fp32, times the group-expanded scales), so the
    full dequant is bit-identical to the tiled one."""
    _check_fmt(fmt)
    if fmt == "ternary":
        codes = unpack_ternary_codes(data)
    else:
        codes = data.astype(jnp.float32)
    return codes * expand_scales(scales.astype(jnp.float32),
                                 codes.shape[-2])


def dequantize(qpw: QuantizedPackedWeight) -> jax.Array:
    """Padded fp32 panels for a quantized pack (the dequant-then-sgemm
    baseline operand; also the error-ledger oracle's weight).  A sparse
    pack decompresses first — the oracle always sees the full logical
    ``[K_pad, N_pad]`` panel layout."""
    if isinstance(qpw, SparseTernaryPackedWeight):
        qpw = decompress_ternary(qpw)
    return dequantize_padded(qpw.data, qpw.scales, qpw.fmt)


# ----------------------------------------------- sparse ternary layout
def _group_occupancy(data: np.ndarray, block_n: int) -> np.ndarray:
    """``[kg, nb]`` bool: does (K-group, column block) hold any nonzero
    code?  Union over stacked leading dims — one column layout must
    serve every layer of a stacked pack."""
    rpg = GROUP_K // 4                       # packed code rows per group
    rows, n_pad = data.shape[-2], data.shape[-1]
    if rows % rpg or n_pad % block_n:
        raise QuantFormatError(
            f"codes {data.shape} not aligned to GROUP_K={GROUP_K} groups "
            f"/ block_n={block_n} panels — compress packed weights only")
    kg, nb = rows // rpg, n_pad // block_n
    g = data.reshape(-1, kg, rpg, nb, block_n)
    return (g != _TERNARY_ZERO_BYTE).any(axis=(0, 2, 4))


def compress_ternary(qpw: QuantizedPackedWeight) -> "SparseTernaryPackedWeight":
    """Dense ternary pack -> compressed layout (see the subclass doc).

    Host-side one-time scan at pack time: groups whose codes are all
    zero in EVERY column block (and every stacked layer) are dropped
    from ``data``/``scales``; the occupancy bitmap additionally records
    which surviving groups each column block can skip.  Refuses packs
    whose removed groups carry nonzero scales (cannot round-trip) —
    ``quantize_pack`` never produces those (all-zero groups get scale
    0), so this only fires on hand-built packs.
    """
    if qpw.fmt != "ternary":
        raise QuantFormatError(
            f"sparse layout is ternary-only; got {qpw.fmt!r}")
    if isinstance(qpw, SparseTernaryPackedWeight):
        return qpw
    if not (_is_concrete(qpw.data) and _is_concrete(qpw.scales)):
        raise QuantFormatError(
            "cannot compress an abstract pack (jax.eval_shape) — no "
            "code values exist to scan for occupancy")
    data = np.asarray(qpw.data)
    scales = np.asarray(qpw.scales)
    rpg = GROUP_K // 4
    occ = _group_occupancy(data, qpw.block_n)    # [kg, nb]
    kg, nb = occ.shape
    n_pad = data.shape[-1]
    lead = data.shape[:-2]
    occ_any = occ.any(axis=1)
    gidx = [int(i) for i in np.nonzero(occ_any)[0]]
    offs = np.full((kg,), -1, np.int64)
    offs[gidx] = np.arange(len(gidx))
    bitmap = tuple(
        int(sum(1 << g for g in range(kg) if occ[g, b]))
        for b in range(nb))
    removed = [g for g in range(kg) if not occ_any[g]]
    if removed and np.any(scales[..., removed, :] != 0):
        raise QuantFormatError(
            "pack has all-zero code groups with nonzero scales; the "
            "compressed layout cannot round-trip them (quantize_pack "
            "gives zero-code groups scale 0)")
    cd = data.reshape(*lead, kg, rpg, n_pad)[..., gidx, :, :]
    cd = cd.reshape(*lead, len(gidx) * rpg, n_pad)
    cs = scales[..., gidx, :]
    return SparseTernaryPackedWeight(
        data=jnp.asarray(cd), n=qpw.n, k=qpw.k, block_n=qpw.block_n,
        block_k=qpw.block_k, n_splits=qpw.n_splits,
        scales=jnp.asarray(cs), fmt="ternary", sparsity=qpw.sparsity,
        k_groups=kg, group_index=tuple(gidx),
        group_offsets=tuple(int(v) for v in offs), occ_bitmap=bitmap)


def decompress_ternary(spw: "SparseTernaryPackedWeight") \
        -> QuantizedPackedWeight:
    """Exact inverse of :func:`compress_ternary`: re-insert all-zero
    code groups (bytes ``0x55``) and zero scale rows at the removed
    slots — bit-for-bit the dense pack the sparse one was built from."""
    rpg = GROUP_K // 4
    data = np.asarray(spw.data)
    scales = np.asarray(spw.scales)
    lead = data.shape[:-2]
    n_pad = data.shape[-1]
    kg, occ = spw.k_groups, spw.occupied
    full = np.full((*lead, kg, rpg, n_pad), _TERNARY_ZERO_BYTE, np.uint8)
    if occ:
        full[..., list(spw.group_index), :, :] = \
            data.reshape(*lead, occ, rpg, n_pad)
    fs = np.zeros((*lead, kg, n_pad), scales.dtype)
    if occ:
        fs[..., list(spw.group_index), :] = scales
    return QuantizedPackedWeight(
        data=jnp.asarray(full.reshape(*lead, kg * rpg, n_pad)),
        n=spw.n, k=spw.k, block_n=spw.block_n, block_k=spw.block_k,
        n_splits=spw.n_splits, scales=jnp.asarray(fs), fmt="ternary",
        sparsity=spw.sparsity)


def _maybe_compress(qpw: QuantizedPackedWeight, sparse: bool | None):
    """The pack-time arm decision.  ``sparse=None`` (auto): compress a
    concrete ternary pack iff its zero-group fraction reaches
    ``SPARSE_DENSITY_THRESHOLD``; ``True`` forces the layout, ``False``
    pins dense.  Abstract packs (eval_shape) never compress — forcing
    one is an error, auto quietly keeps dense (real TWN packs sit near
    group-sparsity 0, so the auto arm leaves today's packs untouched)."""
    if sparse is False:
        return qpw
    if qpw.fmt != "ternary":
        if sparse:
            raise QuantFormatError(
                f"sparse layout is ternary-only; got {qpw.fmt!r}")
        return qpw
    if not (_is_concrete(qpw.data) and _is_concrete(qpw.scales)):
        if sparse:
            raise QuantFormatError(
                "sparse=True needs concrete weights (abstract packs "
                "have no codes to scan)")
        return qpw
    if sparse is None:
        occ = _group_occupancy(np.asarray(qpw.data), qpw.block_n)
        gs = 1.0 - occ.any(axis=1).mean()
        if gs < SPARSE_DENSITY_THRESHOLD:
            return qpw
    return compress_ternary(qpw)


# ------------------------------------------------------------- packing
def _pad_tail(x: jax.Array, pk: int, pn: int, ndim: int) -> jax.Array:
    if not (pk or pn):
        return x
    cfg = [(0, 0)] * (ndim - 2) + [(0, pk), (0, pn)]
    return jnp.pad(x, cfg)


def _sparsity(t) -> float:
    """Zero fraction of LOGICAL codes (callers pass pre-padding arrays —
    pack padding must not inflate the stat).  Device-side reduction: only
    the scalar crosses to host."""
    if not _is_concrete(t):
        return -1.0
    return float(jnp.mean((t == 0).astype(jnp.float32)))


def _fit_group_block_k(k: int, block_k: int | None) -> int:
    """Resolve a pack's block_k honoring BOTH contracts: it divides the
    padded K (fit_block) and spans whole GROUP_K scale groups (the
    tiles-never-straddle-a-group alignment the kernel asserts).  A
    requested value that fit_block keeps but GROUP_K does not divide
    (e.g. 192) rounds down to the next GROUP_K multiple — rounding down
    keeps the kernel grid exact because the pack pads K to whatever
    multiple this returns."""
    from repro.kernels import panel_gemm as _kernel
    bk = fit_block(k, block_k or _kernel.DEFAULT_BLOCK_K)
    if bk % GROUP_K:
        bk = max(GROUP_K, (bk // GROUP_K) * GROUP_K)
    return bk


def quantize_pack(
    w: jax.Array,
    fmt: str,
    *,
    transposed: bool = False,
    block_n: int | None = None,
    block_k: int | None = None,
    sharding=None,
    measure: bool = True,
    sparse: bool | None = None,
) -> QuantizedPackedWeight:
    """Quantize + pack ``w[..., K, N]`` (or ``[..., N, K]`` with
    ``transposed``) once at model load.  Leading dims (stacked ``[L, K,
    N]`` scan weights) ride through untouched.

    Quantization runs on the LOGICAL weight (padding never pollutes a
    group's scale), then codes pad with 0 and scales with 0 so padded
    tiles dequantize to exact zero.  ``measure=True`` (default) records
    the pack's error vs the fp32 oracle in the error ledger and enforces
    the per-format tolerance — skipped automatically for abstract
    weights (``jax.eval_shape``).  ``sparse`` picks the ternary storage
    layout (see :func:`_maybe_compress`): ``None`` auto-compresses at
    ``SPARSE_DENSITY_THRESHOLD`` group sparsity.
    """
    _check_fmt(fmt)
    from repro.kernels import panel_gemm as _kernel
    if transposed:
        w = jnp.swapaxes(w, -1, -2)
    k, n = int(w.shape[-2]), int(w.shape[-1])
    block_k = _fit_group_block_k(k, block_k)
    block_n = fit_block(n, block_n or _kernel.DEFAULT_BLOCK_N)
    q, s = quantize(w, fmt)
    sparsity = _sparsity(q) if fmt == "ternary" else -1.0
    pk, pn = (-k) % block_k, (-n) % block_n
    q = _pad_tail(q, pk, pn, q.ndim)
    s = _pad_tail(s, q.shape[-2] // GROUP_K - s.shape[-2], pn, s.ndim)
    data = pack_ternary_codes(q) if fmt == "ternary" else q
    qpw = QuantizedPackedWeight(data=data, n=n, k=k, block_n=block_n,
                                block_k=block_k, scales=s, fmt=fmt,
                                sparsity=sparsity)
    qpw = _maybe_compress(qpw, sparse)
    if sharding is not None:
        qpw = dataclasses.replace(qpw,
                                  data=jax.device_put(qpw.data, sharding))
    if measure and _is_concrete(w):
        from repro.quant import ledger
        ledger.measure(w, qpw, enforce=True)
    return qpw


def quantize_pack_fused(
    parts,
    fmt: str,
    *,
    transposed: bool = False,
    block_n: int | None = None,
    block_k: int | None = None,
    sharding=None,
    measure: bool = True,
    sparse: bool | None = None,
) -> QuantizedPackedWeight:
    """Horizontal fusion (``core.packing.pack_fused``) in a quantized
    format: each same-K part is quantized per its own output columns,
    padded to a ``block_n`` multiple, and concatenated along N — the
    static split map is preserved, tiles never straddle parts OR scale
    groups, and a glu pair's two column halves stay block-addressable.
    ``sparse`` behaves as in :func:`quantize_pack`; compression runs on
    the fused concat, so the group union spans every part."""
    _check_fmt(fmt)
    from repro.kernels import panel_gemm as _kernel
    ws = [jnp.swapaxes(w, -1, -2) if transposed else w for w in parts]
    if len(ws) < 2:
        raise ValueError("quantize_pack_fused needs at least two weights; "
                         "use quantize_pack for one")
    k = int(ws[0].shape[-2])
    if any(w.shape[-2] != k or w.ndim != ws[0].ndim for w in ws):
        raise ValueError(
            f"fused parts must share K and rank; got "
            f"{[tuple(w.shape) for w in ws]}")
    block_k = _fit_group_block_k(k, block_k)
    bn = min(fit_block(int(w.shape[-1]), block_n or _kernel.DEFAULT_BLOCK_N)
             for w in ws)
    n_splits = tuple(int(w.shape[-1]) for w in ws)
    pk = (-k) % block_k
    qs, ss, zeros, elems = [], [], 0.0, 0
    for w in ws:
        q, s = quantize(w, fmt)
        if fmt == "ternary" and _is_concrete(q):
            zeros += _sparsity(q) * q.size      # logical codes only
            elems += q.size
        pn = (-int(w.shape[-1])) % bn
        q = _pad_tail(q, pk, pn, q.ndim)
        qs.append(q)
        ss.append(_pad_tail(s, q.shape[-2] // GROUP_K - s.shape[-2],
                            pn, s.ndim))
    codes = jnp.concatenate(qs, axis=-1)
    scales = jnp.concatenate(ss, axis=-1)
    sparsity = (zeros / elems) if elems else -1.0
    data = pack_ternary_codes(codes) if fmt == "ternary" else codes
    qpw = QuantizedPackedWeight(
        data=data, n=int(codes.shape[-1]), k=k, block_n=bn,
        block_k=block_k, n_splits=n_splits, scales=scales, fmt=fmt,
        sparsity=sparsity)
    qpw = _maybe_compress(qpw, sparse)
    if sharding is not None:
        qpw = dataclasses.replace(qpw,
                                  data=jax.device_put(qpw.data, sharding))
    if measure and all(_is_concrete(w) for w in ws):
        from repro.quant import ledger
        ledger.measure(jnp.concatenate(
            [_pad_tail(w, 0, (-int(w.shape[-1])) % bn, w.ndim)
             for w in ws], axis=-1), qpw, enforce=True)
    return qpw
