"""The quantization error ledger — Table-4 discipline for reduced
precision.

The paper never ships a configuration whose numerics it has not
measured: its Table 4 reports BNNS Graph's per-shape max-abs error (up
to 1.4e-3) instead of hand-waving "close enough".  This ledger applies
the same discipline to our own quantized formats: every concrete
quantized pack is probed against its fp32 oracle, the per-(shape,
format) error is RECORDED, and the per-format tolerance is ENFORCED at
pack time — reduced precision cannot drift silently.

Schema (one entry per ``(n, k, fmt)``):

  * ``max_abs``  — max |y_quant - y_fp32| over the probe GEMM output
    (fp32 oracle: ``x @ w`` on the original weights).
  * ``max_rel``  — ``max_abs / max |y_fp32|``: output-normalized
    relative error.  Normalizing by the output's own magnitude (not
    elementwise) keeps near-zero outputs from exploding the metric —
    documented in docs/quantization.md.
  * ``tol``      — the format's declared ``max_rel`` tolerance.

``gemm.validate_plan`` consults the ledger for quantized plans: a plan
whose ledger entry exceeds its tolerance is rejected, mirroring the
autotune bit-exactness reject protocol for fp32 plans.
"""
from __future__ import annotations

import dataclasses
import threading

import jax.numpy as jnp
import numpy as np

# Per-format max_rel tolerances (the contract docs/quantization.md
# states).  int8 per-channel symmetric lands around 1e-3..9e-3 on
# gaussian weights (quant step = max|w|/127); ternary is a 2-bit format
# — its reconstruction error is O(0.5 sigma_w) per weight, so the
# output-normalized error sits near 0.4-0.5 on the paper shapes.  0.75
# is the enforced ceiling; anything above it means the quantizer (not
# the format) broke.
TOLERANCES = {"int8": 1e-2, "ternary": 0.75}

# Probe row count: enough rows that the max statistics are stable, small
# enough that packing a whole model stays cheap at load.
PROBE_M = 64


class QuantToleranceError(RuntimeError):
    """A quantized pack's measured error exceeded its format tolerance."""


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    n: int
    k: int
    fmt: str
    max_abs: float
    max_rel: float
    tol: float
    probe_m: int
    # occupied K-group fraction of the probed pack: 1.0 for every dense
    # pack; < 1.0 when a ternary pack crossed to the compressed
    # zero-group layout (SparseTernaryPackedWeight.density)
    density: float = 1.0

    @property
    def within_tol(self) -> bool:
        return self.max_rel <= self.tol

    @property
    def sparse(self) -> bool:
        return self.density < 1.0

    def row(self) -> dict:
        """Benchmark/report row (table8's ledger columns)."""
        return {"N": self.n, "K": self.k, "format": self.fmt,
                "max_abs_err": self.max_abs, "max_rel_err": self.max_rel,
                "tolerance": self.tol, "within_tol": self.within_tol,
                "density": round(self.density, 4)}


_entries: dict[tuple[int, int, str], LedgerEntry] = {}
_lock = threading.Lock()


def tolerance(fmt: str) -> float:
    try:
        return TOLERANCES[fmt]
    except KeyError:
        raise KeyError(f"no tolerance declared for format {fmt!r}; "
                       f"known: {sorted(TOLERANCES)}") from None


def record(entry: LedgerEntry) -> LedgerEntry:
    with _lock:
        _entries[(entry.n, entry.k, entry.fmt)] = entry
    return entry


def lookup(n: int, k: int, fmt: str) -> LedgerEntry | None:
    with _lock:
        return _entries.get((int(n), int(k), fmt))


def entries() -> list[LedgerEntry]:
    with _lock:
        return sorted(_entries.values(), key=lambda e: (e.fmt, e.k, e.n))


def clear() -> None:
    with _lock:
        _entries.clear()


def measure(w_fp32, qpw, *, enforce: bool = True,
            probe_m: int = PROBE_M) -> LedgerEntry:
    """Probe one quantized pack against its fp32 oracle and record the
    entry (pack-time enforcement path).

    The probe is a deterministic gaussian ``x [probe_m, K]`` seeded by
    the shape, the oracle is the plain fp32 ``x @ w``, and the quantized
    side multiplies the SAME x against the dequantized panels — the
    error measured is purely the format's, not the kernel's (the kernel
    vs dequant-oracle contract is the separate bit-exact gate in
    ``quant/kernels``).  A stacked ``[L, K, N]`` pack is probed per
    layer and the WORST layer's errors become the shape's entry, so
    scan-over-layers serving weights are gated exactly like 2-D packs.
    """
    from repro.quant import formats as F
    w = jnp.asarray(w_fp32, jnp.float32)
    k, n = int(w.shape[-2]), int(w.shape[-1])
    rng = np.random.default_rng((k * 1_000_003 + n) % (2**31))
    x = jnp.asarray(rng.standard_normal((probe_m, k)), jnp.float32)
    w3 = w.reshape((-1, k, n))
    deq3 = F.dequantize(qpw)[..., :k, :n].reshape((-1, k, n))
    max_abs = max_rel = 0.0
    for wl, dl in zip(w3, deq3):
        y_ref = np.asarray(jnp.dot(x, wl,
                                   preferred_element_type=jnp.float32))
        y_q = np.asarray(jnp.dot(x, dl,
                                 preferred_element_type=jnp.float32))
        abs_l = float(np.max(np.abs(y_q - y_ref))) if y_ref.size else 0.0
        denom = float(np.max(np.abs(y_ref))) if y_ref.size else 0.0
        rel_l = abs_l / max(denom, 1e-30)
        if rel_l >= max_rel:
            max_abs, max_rel = abs_l, rel_l
    entry = record(LedgerEntry(n=int(qpw.n), k=int(qpw.k), fmt=qpw.fmt,
                               max_abs=max_abs, max_rel=max_rel,
                               tol=tolerance(qpw.fmt), probe_m=probe_m,
                               density=float(getattr(qpw, "density",
                                                     1.0))))
    if enforce and not entry.within_tol:
        raise QuantToleranceError(
            f"quantized pack [{qpw.k}x{qpw.n}] fmt={qpw.fmt}: max_rel "
            f"error {max_rel:.3e} exceeds the {qpw.fmt} tolerance "
            f"{entry.tol:.1e} (error ledger enforcement; see "
            f"docs/quantization.md)")
    return entry
