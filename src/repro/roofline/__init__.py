"""Roofline: 3-term analysis from compiled dry-run artifacts."""
from repro.roofline.analysis import (
    CollectiveOp, collective_seconds, gemm_roofline, model_flops,
    parse_collectives, roofline_terms,
)

__all__ = ["CollectiveOp", "collective_seconds", "gemm_roofline",
           "model_flops", "parse_collectives", "roofline_terms"]
