"""Loop-aware cost model over compiled (post-GSPMD) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts each while-loop
body ONCE, but this framework's step functions are scan-shaped everywhere
(scan over layers × scan over grad-accum microbatches), so the built-in
numbers under-count FLOPs/bytes/collectives by the product of trip counts
(measured ~161× on deepseek-7b train_4k).  This walker parses the HLO
text, recovers each loop's trip count from its condition computation, and
propagates costs through the call graph:

  cost(computation) = Σ own ops + Σ_{while w} trip(w) · cost(body(w))
                      + Σ_{fusion/call f} cost(called(f))
                      + Σ_{conditional c} max over branches

Per-op model (per device — the module is the partitioned program):
  flops   : dot/convolution only — 2 · |result| · Π contract dims.
            Elementwise flops are ignored (MXU work is what the compute
            roofline prices; VPU work is covered by the memory term).
  bytes   : HBM traffic ≈ writes + reads of top-level op results.
            Fusion internals are invisible (their temporaries live in
            registers/VMEM — the right model for HBM).  View/metadata ops
            (bitcast, get-tuple-element, tuple, parameter, constant,
            reshape) are free; dynamic-update-slice counts the update
            operand, not the aliased buffer.
  coll    : wire bytes per collective (ring model, see analysis.py),
            scaled by the enclosing loops' trip counts.

Scope: a static cost model, not a simulator — no overlap, no cache reuse
beyond fusion boundaries.  Validated against analytic 6·N·D in
tests/test_roofline.py (agrees within the remat factor).
"""
from __future__ import annotations

import dataclasses
import re

from repro.roofline.analysis import (
    DTYPE_BYTES, _shape_bytes, _wire_bytes,
)

# computation headers start at column 0: "%name (params...) -> type {"
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(")
# result shape: tuple "(f32[..], /*index=5*/ bf16[..], ..)" (no nested
# parens, may contain comments) or a plain array type.
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\([^()]*\)|[\w\[\],{}]+)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>.*?)\)(?P<attrs>.*)$")
_DIMS_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "reshape", "after-all", "add-dependency", "partition-id", "replica-id",
    "iota",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start",
                "all-reduce-start", "collective-permute-start",
                "ragged-all-to-all"}


@dataclasses.dataclass
class Op:
    name: str
    op: str
    shape: str
    operands: list[str]
    attrs: str
    result_bytes: int = 0
    flops: float = 0.0


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op] = dataclasses.field(default_factory=list)
    symbols: dict = dataclasses.field(default_factory=dict)  # name -> dims
    consts: list[int] = dataclasses.field(default_factory=list)  # s32[] vals


def _first_dims(shape_str: str):
    m = _DIMS_RE.search(shape_str)
    if not m:
        return None, None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def parse_module(text: str) -> tuple[dict, str]:
    """Parse HLO text into {name: Computation}; returns (comps, entry)."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)      # column-0 headers only
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        operands = [o.strip().lstrip("%")
                    for o in _split_operands(m.group("operands"))]
        op = Op(m.group("name"), m.group("op"), m.group("shape"),
                operands, m.group("attrs"))
        op.result_bytes = _shape_bytes(op.shape)
        cur.ops.append(op)
        _, dims = _first_dims(op.shape)
        cur.symbols[op.name] = (dims, op.result_bytes)
        if op.op == "constant" and op.shape.strip().startswith("s32[]"):
            mv = re.match(r"\s*(-?\d+)", m.group("operands"))
            if mv:
                cur.consts.append(int(mv.group(1)))
    return comps, entry


def _split_operands(s: str) -> list[str]:
    """Split top-level comma-separated operand names (shapes may nest)."""
    out, depth, buf = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            tok = "".join(buf).strip()
            if tok.startswith("%"):
                out.append(tok)
            buf = []
        else:
            buf.append(ch)
    tok = "".join(buf).strip()
    if tok.startswith("%"):
        out.append(tok)
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    _, rdims = _first_dims(op.shape)
    if rdims is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    lhs = comp.symbols.get(op.operands[0], (None, 0))[0] if op.operands \
        else None
    contract = 1
    if m and lhs:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs[int(idx)]
    elif lhs:
        contract = lhs[-1]
    import math
    return 2.0 * math.prod(rdims) * contract


def _fused_dus_update_bytes(called: Computation | None) -> int | None:
    """If a fused computation's root is a dynamic-update-slice — or a
    tuple whose elements are all DUS — return the summed UPDATE operand
    bytes (the aliased big buffers are not HBM traffic).  None = not a
    DUS fusion."""
    if called is None or not called.ops:
        return None
    root = called.ops[-1]
    by_name = {op.name: op for op in called.ops}
    if root.op == "tuple":
        elems = [by_name.get(o) for o in root.operands]
        if not elems or any(e is None or e.op != "dynamic-update-slice"
                            for e in elems):
            return None
    elif root.op == "dynamic-update-slice":
        elems = [root]
    else:
        return None
    total = 0
    for e in elems:
        upd = e.operands[1] if len(e.operands) > 1 else None
        total += called.symbols.get(upd, (None, e.result_bytes))[1]
    return total


def _collective_wire(op: Op, default_group: int) -> float:
    kind = op.op.replace("-start", "")
    s = default_group
    m = _GROUPS_IOTA_RE.search(op.attrs)
    if m:
        s = int(m.group(2))
    else:
        m = _GROUPS_LIST_RE.search(op.attrs)
        if m:
            s = len([x for x in m.group(1).split(",") if x.strip()])
    return _wire_bytes(kind, op.result_bytes, s)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_wire_dcn: float = 0.0
    hbm_by_tag: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.hbm_bytes * k,
                    self.coll_wire_bytes * k,
                    {n: v * k for n, v in self.coll_by_kind.items()},
                    self.coll_wire_dcn * k,
                    {n: v * k for n, v in self.hbm_by_tag.items()})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.coll_wire_bytes += o.coll_wire_bytes
        for n, v in o.coll_by_kind.items():
            self.coll_by_kind[n] = self.coll_by_kind.get(n, 0.0) + v
        self.coll_wire_dcn += o.coll_wire_dcn
        for n, v in o.hbm_by_tag.items():
            self.hbm_by_tag[n] = self.hbm_by_tag.get(n, 0.0) + v
        return self


class HloCostModel:
    def __init__(self, text: str, *, total_devices: int,
                 dcn_group_size: int | None = None,
                 tags: dict[str, str] | None = None):
        """``tags``: {name: regex} matched against each op's metadata
        op_name; matching ops' HBM bytes are also bucketed per tag
        (named_scope regions — e.g. attention intermediates)."""
        self.comps, self.entry = parse_module(text)
        self.total = total_devices
        self.dcn_group = dcn_group_size
        self.tags = {k: re.compile(v) for k, v in (tags or {}).items()}
        self._trip_cache: dict[str, int] = {}
        self._cost_cache: dict[str, Cost] = {}
        self.loops: list[dict] = []

    # ------------------------------------------------------------- trips
    def trip_count(self, cond_name: str) -> int:
        """Loop bound: the largest s32[] constant reachable from the
        condition computation (scan conditions are `i < L` with i0 = 0)."""
        if cond_name in self._trip_cache:
            return self._trip_cache[cond_name]
        consts: list[int] = []
        stack, seen = [cond_name], set()
        while stack:
            name = stack.pop()
            if name in seen or name not in self.comps:
                continue
            seen.add(name)
            c = self.comps[name]
            consts.extend(c.consts)
            for op in c.ops:
                mc = _CALLS_RE.search(op.attrs)
                if mc:
                    stack.append(mc.group(1))
        t = max([c for c in consts if c > 0], default=1)
        self._trip_cache[cond_name] = t
        return t

    # -------------------------------------------------------------- cost
    def cost(self, comp_name: str | None = None, *,
             charge_bytes: bool = True) -> Cost:
        """Cost of one computation (recursive).

        ``charge_bytes=False`` when entered through a fusion ``calls=``
        edge: fusion internals live in registers/VMEM, so only their dot
        FLOPs count; HBM traffic is the fusion op's operands/result at
        the caller's level.
        """
        name = comp_name or self.entry
        key = (name, charge_bytes)
        if key in self._cost_cache:
            return self._cost_cache[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        reads: dict[str, int] = {}
        for op in comp.ops:
            for o in op.operands:
                reads[o] = reads.get(o, 0) + 1
        for op in comp.ops:
            kind = op.op
            if kind.endswith("-done"):
                continue
            if kind == "while":
                # loop state is aliased; traffic accrues inside the body
                body = _BODY_RE.search(op.attrs)
                cond = _COND_RE.search(op.attrs)
                trips = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    total += self.cost(body.group(1)).scaled(trips)
                    self.loops.append({"body": body.group(1),
                                       "trips": trips, "in": name})
                continue
            if kind == "conditional":
                mb = _BRANCH_RE.search(op.attrs)
                if mb:
                    branches = [b.strip().lstrip("%")
                                for b in mb.group(1).split(",")]
                    costs = [self.cost(b, charge_bytes=charge_bytes)
                             for b in branches]
                    if costs:
                        total += max(costs, key=lambda c: c.flops
                                     + c.hbm_bytes)
                continue
            mc = _CALLS_RE.search(op.attrs)
            if mc and kind == "fusion":
                total += self.cost(mc.group(1), charge_bytes=False)
            elif mc and kind in ("call", "async-start"):
                total += self.cost(mc.group(1), charge_bytes=charge_bytes)
            if kind in ("dot", "convolution"):
                total += Cost(flops=_dot_flops(op, comp))
            if kind in _COLLECTIVES:
                wire = _collective_wire(op, self.total)
                c = Cost(coll_wire_bytes=wire)
                base = kind.replace("-start", "")
                c.coll_by_kind[base] = wire
                if self.dcn_group is not None:
                    m = _GROUPS_IOTA_RE.search(op.attrs)
                    if m and int(m.group(2)) == self.dcn_group:
                        c.coll_wire_dcn = wire
                total += c
            # HBM bytes: write result once + read per use
            if kind not in _FREE_OPS and charge_bytes:
                uses = reads.get(op.name, 0)
                nbytes = op.result_bytes
                if kind == "dynamic-update-slice":
                    # result aliases the big buffer; traffic is the update
                    upd = op.operands[1] if len(op.operands) > 1 else None
                    nbytes = comp.symbols.get(upd, (None, nbytes))[1]
                elif kind == "fusion" and mc:
                    # scan accumulators: fusions whose root is a d-u-s (or
                    # a tuple of them — e.g. k+v cache writeback) alias
                    # their buffers; charge the updates, not the buffers
                    called = self.comps.get(mc.group(1))
                    dus_bytes = _fused_dus_update_bytes(called)
                    if dus_bytes is not None:
                        nbytes = dus_bytes
                c = Cost(hbm_bytes=nbytes * (1 + uses))
                for tag, pat in self.tags.items():
                    if pat.search(op.attrs):
                        c.hbm_by_tag[tag] = c.hbm_bytes
                total += c
        self._cost_cache[key] = total
        return total
