"""Three-term roofline from the compiled dry-run artifact.

No wall clock exists for the target (TPU v5e) on this CPU host, so the
§Roofline deliverable is derived statically, per (arch × shape × mesh):

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = Σ wire_bytes(op) / link_bw   over collective ops

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned module
(calibrated: a (256,512)x(512,1024) matmul over 8 devices reports
global/8 flops), so no division by chip count is applied to the first two
terms.  Collective ops are parsed from the compiled (post-GSPMD) HLO —
the pre-partitioning StableHLO has none — with wire bytes per device
derived from the result shape and replica group size under the standard
ring algorithms:

  all-gather      R·(s-1)/s      (R = result bytes, s = group size)
  all-reduce      2·R·(s-1)/s
  reduce-scatter  R·(s-1)
  all-to-all      R·(s-1)/s
  collective-permute  R

The single-link-bandwidth model (~50 GB/s ICI per the brief) treats every
group as ring-connected; cross-pod (DCN) groups are charged at
``dcn_bw`` when the group telescopes over the pod axis (group size == the
pod count on the multi-pod mesh) — recorded per-op so EXPERIMENTS.md can
show the DCN share.
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import HW

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (?P<shape>\([^)]*\)|[\w\[\],{}:]+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(",
    re.M)
_SHAPE_RE = re.compile(r"(?P<dt>\w+)\[(?P<dims>[\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    wire_bytes: float      # per device, ring model
    line: str = ""


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _wire_bytes(kind: str, result_bytes: int, s: int) -> float:
    if s <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (s - 1) / s
    if kind == "all-reduce":
        return 2.0 * result_bytes * (s - 1) / s
    if kind == "reduce-scatter":
        return float(result_bytes) * (s - 1)
    if kind == "all-to-all":
        return result_bytes * (s - 1) / s
    return float(result_bytes)           # collective-permute


def parse_collectives(hlo_text: str, total_devices: int,
                      ) -> list[CollectiveOp]:
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        if "-done" in line.split("=", 1)[0]:
            continue                       # async pair: count start only
        kind = m.group("op")
        rb = _shape_bytes(m.group("shape"))
        s = _group_size(line, total_devices)
        out.append(CollectiveOp(kind, rb, s, _wire_bytes(kind, rb, s),
                                line.strip()[:180]))
    return out


def collective_seconds(ops: list[CollectiveOp], *, link_bw: float,
                       dcn_bw: float | None = None,
                       dcn_group_size: int | None = None) -> dict:
    """Total collective seconds + per-kind/per-fabric breakdown."""
    total = 0.0
    by_kind: dict[str, float] = {}
    dcn_s = 0.0
    for op in ops:
        bw = link_bw
        is_dcn = (dcn_group_size is not None
                  and op.group_size == dcn_group_size)
        if is_dcn and dcn_bw:
            bw = dcn_bw
        t = op.wire_bytes / bw
        total += t
        by_kind[op.kind] = by_kind.get(op.kind, 0.0) + t
        if is_dcn:
            dcn_s += t
    return {"seconds": total, "by_kind": by_kind, "dcn_seconds": dcn_s,
            "num_ops": len(ops),
            "wire_bytes": sum(op.wire_bytes for op in ops)}


def model_flops(cfg, shape) -> float:
    """Useful FLOPs: 6·N_active·tokens (train) / 2·N_active·tokens
    (prefill) / 2·N_active·new_tokens (decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: 1 token


# Weight bytes per element by pack-time format (the quantized formats
# stream codes, not floats): fp32 full precision, int8 one byte,
# ternary 2-bit codes packed 4-per-byte.
_FORMAT_BYTES = {"fp32": 4.0, "int8": 1.0, "ternary": 0.25}


def gemm_roofline(m: int, n: int, k: int, *, weight_format: str = "fp32",
                  act_bytes: int = 4, weight_density: float = 1.0,
                  hw: dict = HW) -> float:
    """Analytic lower-bound seconds for ONE ``[m,k] @ [k,n]`` dispatch —
    the denominator of the flight recorder's ``roofline_frac``.

    Two terms, take the max: compute (``2mnk`` over fp32 peak — the
    GEMM accumulates in fp32 regardless of pack format) and memory (the
    operand/result traffic floor: activations + result at ``act_bytes``,
    weights at the pack format's bytes-per-element — the term decode's
    skinny-M dispatches live on, and why quantized decode beats fp32 at
    the same FLOPs).  Single-dispatch and collective-free by
    construction; the step-level three-term model stays
    :func:`roofline_terms`.

    ``weight_density`` is the occupied-group fraction of a sparse-
    ternary pack (``SparseTernaryPackedWeight.density``; 1.0 = dense):
    the compressed layout stores — and the sparse walk streams and
    multiplies — only the occupied K-groups, so both the weight-byte
    term and the FLOP term scale by it.  That makes ``roofline_frac``
    honest for sparse dispatches: measured against the work the layout
    actually implies, not the dense shape's."""
    flops = 2.0 * m * n * k * weight_density
    wb = _FORMAT_BYTES.get(weight_format, 4.0)
    bytes_moved = ((m * k + m * n) * act_bytes
                   + k * n * wb * weight_density)
    t_compute = flops / hw["peak_flops_fp32"]
    t_memory = bytes_moved / hw["hbm_bw"]
    return max(t_compute, t_memory)


def roofline_terms(*, flops_per_device: float, bytes_per_device: float,
                   collective: dict, chips: int, model_fl: float,
                   dtype: str = "bf16", hw: dict = HW) -> dict:
    peak = (hw["peak_flops_bf16"] if dtype == "bf16"
            else hw["peak_flops_fp32"])
    t_c = flops_per_device / peak
    t_m = bytes_per_device / hw["hbm_bw"]
    t_x = collective["seconds"]
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_x)
    total_hlo_flops = flops_per_device * chips
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        # fraction of roofline achieved if the dominant term were the
        # whole step (higher = closer to the compute roofline)
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
        "hlo_flops_per_device": flops_per_device,
        "hlo_flops_global": total_hlo_flops,
        "model_flops": model_fl,
        "useful_ratio": model_fl / total_hlo_flops if total_hlo_flops
        else 0.0,
        "mfu_upper_bound": (model_fl / (chips * peak)) / bound
        if bound > 0 else 0.0,
        "chips": chips,
        "dtype": dtype,
    }
