"""Config schema: model architecture + input-shape + run configs."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid
    modality: str = "text"           # text | audio | vlm
    source: str = ""                 # provenance tag from the assignment
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    attention_kind: str = "gqa"      # gqa | mla | none | parallel_ssm
    window: int | None = None        # uniform sliding window (SWA)
    local_global_period: int = 0     # >0: alternate local(window)/global
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    attn_scale: float | None = None
    rope_theta: float = 10000.0
    act: str = "silu"                # silu | gelu
    norm_eps: float = 1e-6
    norm_plus_one: bool = False      # gemma-style (1 + scale) RMSNorm
    post_norms: bool = False         # gemma2 post-attn/post-ffn norms
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d)
    tie_embeddings: bool = False
    # MLA (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_dispatch_groups: int = 0     # 0/1 = global cumsum; launchers set
                                     # this to the batch-shard count so
                                     # dispatch never crosses a shard
                                     # (§Perf cell 3)
    moe_dispatch: str = "grouped"    # grouped | global — offline-sweep
                                     # pick per arch (§Perf D2: 7.3x win
                                     # on qwen3; measured regression on
                                     # deepseek-v3, which keeps global)
    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_groups: int = 1
    ssm_chunk: int = 128
    conv_width: int = 4
    # dtypes / training
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots (dots saveable —
                                     # required under manual_dp: the
                                     # nothing_saveable policy trips an
                                     # XLA CHECK inside partial-auto
                                     # shard_map at high partition counts)
    optimizer: str = "adamw"         # adamw | adafactor
    # serving
    cache_kind: str = "auto"         # auto | full | window
    cache_dtype: str = "bfloat16"

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def is_windowed_only(self) -> bool:
        """True iff every attention layer is windowed (ring cache legal)."""
        return (self.window is not None and self.local_global_period == 0
                and self.attention_kind in ("gqa", "parallel_ssm"))

    @property
    def resolved_cache_kind(self) -> str:
        if self.cache_kind != "auto":
            return self.cache_kind
        return "window" if self.is_windowed_only else "full"

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, v, lyr = self.d_model, self.vocab_size, self.num_layers
        n = v * d                                     # embed
        if not self.tie_embeddings:
            n += v * d                                # lm head
        per = 2 * d                                   # 2 norms
        if self.post_norms:
            per += 2 * d
        if self.attention_kind == "gqa" or self.attention_kind == "parallel_ssm":
            per += d * self.num_heads * self.head_dim * 2  # wq, wo
            per += d * self.num_kv_heads * self.head_dim * 2
        if self.attention_kind == "mla":
            per += d * self.q_lora_rank
            per += self.q_lora_rank * self.num_heads * (
                self.qk_nope_dim + self.qk_rope_dim)
            per += d * (self.kv_lora_rank + self.qk_rope_dim)
            per += self.kv_lora_rank * self.num_heads * (
                self.qk_nope_dim + self.v_head_dim)
            per += self.num_heads * self.v_head_dim * d
        if self.attention_kind in ("none", "parallel_ssm"):
            d_in = self.ssm_heads * self.ssm_head_dim
            gn = self.ssm_groups * self.ssm_state
            per += d * (2 * d_in + 2 * gn + self.ssm_heads)
            per += d_in * d + d_in
        if self.family == "moe":
            per += d * self.num_experts                # router
            per += self.num_experts * d * self.moe_d_ff * 3
            per += self.num_shared_experts * d * self.moe_d_ff * 3
        elif self.d_ff:
            per += d * self.d_ff * 3
        return n + lyr * per

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k experts)."""
        if self.family != "moe":
            return self.param_count()
        inactive = (self.num_experts - self.experts_per_token) \
            * self.d_model * self.moe_d_ff * 3 * self.num_layers
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                        # train_4k | prefill_32k | ...
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatch_per_device: int = 1   # grad-accum: global_batch /
                                     # (data_shards * microbatch)
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    seed: int = 0
    grad_compression: str = "none"   # none | bf16 (wire dtype of grad sync)
    shard_grad_accum: bool = True    # FSDP grad accumulators (§Perf it. 1)
    gather_params_once: bool = False # hoist FSDP all-gather out of the
                                     # microbatch loop (§Perf it. 3; costs
                                     # full-d params resident per device)
    manual_dp: bool = False          # shard_map manual data axis: local
                                     # grad accum, ONE sync/step (§Perf 4)
