"""gemma2-9b [dense]: local/global alternating attention + logit softcap.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000
[arXiv:2408.00118; hf].  Local window 4096 every other layer; attention
softcap 50, final-logit softcap 30; (1+scale) RMSNorm with post-norms;
tied embeddings; GeGLU.  Global layers are full attention, so the
long_500k cell is SKIPPED for this arch (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118; hf",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attention_kind="gqa",
    window=4096,
    local_global_period=2,
    logit_softcap=30.0,
    attn_softcap=50.0,
    attn_scale=256 ** -0.5,
    act="gelu",
    norm_plus_one=True,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    compute_dtype="bfloat16",
)
