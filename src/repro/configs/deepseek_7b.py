"""deepseek-7b [dense]: llama-arch.  30L d_model=4096 32H (kv=32)
d_ff=11008 vocab=102400 [arXiv:2401.02954; hf].

This is the paper-representative arch: its projection GEMMs are the
paper's Llama-7B shape class (QKV (4096,4096), FFN1 (11008,4096),
FFN2 (4096,11008)) — see configs/paper_shapes.py.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    source="arXiv:2401.02954; hf",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    attention_kind="gqa",
    compute_dtype="bfloat16",
)
