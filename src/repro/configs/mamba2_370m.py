"""mamba2-370m [ssm]: SSD (state-space duality), attention-free.

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified].  d_inner = 2*d_model = 2048, head_dim 64
-> 32 SSM heads, 1 group, conv width 4, tied embeddings (matches the
~370M total).  Attention-free => long_500k decode is O(1) state.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060; unverified",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attention_kind="none",
    ssm_state=128,
    ssm_heads=32,
    ssm_head_dim=64,
    ssm_groups=1,
    tie_embeddings=True,
    compute_dtype="bfloat16",
)
