"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window
attention.  24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000
[arXiv:2401.16818; unverified].

Modeled with a uniform 8192-token sliding window on every layer (the
release interleaves SWA/full; uniform-SWA is recorded in DESIGN.md §6).
Because every layer is windowed, the KV cache is a ring buffer of the
window size, which is what makes the long_500k decode cell runnable.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="arXiv:2401.16818; unverified",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    attention_kind="gqa",
    window=8192,
    compute_dtype="bfloat16",
)
