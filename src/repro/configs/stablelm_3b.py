"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    attention_kind="gqa",
    compute_dtype="bfloat16",
)
