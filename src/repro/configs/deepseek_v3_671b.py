"""deepseek-v3-671b [moe]: MLA + 256-expert top-8 MoE.

61L d_model=7168 128H d_ff=2048(expert) vocab=129280, MoE 256e top-8,
1 shared expert [arXiv:2412.19437; hf].  Per the assignment all 61 layers
are MoE (the release model's 3 leading dense layers and the MTP head are
noted as omitted in DESIGN.md §6).  MLA runs in absorbed form: the cache
holds only the 512-d latent + 64-d rope key.  bf16 params + adafactor,
required to fit 16 GB/chip at 256 chips (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437; hf",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # q-head count; MLA caches the shared latent
    head_dim=192,              # qk_nope + qk_rope
    d_ff=2048,
    vocab_size=129280,
    attention_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    num_experts=256,
    num_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    moe_dispatch="global",     # offline sweep: grouped dispatch regressed
                               # here (GSPMD already picks a2a for 256e;
                               # the explicit constraints fought it) —
                               # EXPERIMENTS.md §Perf cell 3

    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adafactor",
)
