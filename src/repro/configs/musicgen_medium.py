"""musicgen-medium [audio]: decoder-only over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24 = MHA) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf].  The EnCodec frontend is a STUB: input_specs()
provides precomputed frame embeddings [B, S, d_model]; the single-codebook
LM head stands in for the 4-codebook interleaving (frontend detail, see
DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    modality="audio",
    source="arXiv:2306.05284; hf",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    attention_kind="gqa",
    act="gelu",
    compute_dtype="bfloat16",
)
