"""internvl2-76b [vlm]: InternViT + InternLM2 backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821; unverified].  The InternViT frontend is a STUB:
input_specs() provides precomputed patch embeddings [B, S, d_model];
only the language backbone is modeled (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="dense",
    modality="vlm",
    source="arXiv:2404.16821; unverified",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    attention_kind="gqa",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer="adafactor",
)
