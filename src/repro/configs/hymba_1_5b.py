"""hymba-1.5b [hybrid]: parallel attention + mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf].  Each layer runs GQA attention and a Mamba-2
mixer in parallel on the same input; the two outputs are normalized and
averaged (the release's learnable per-branch beta and meta-tokens are
simplifications recorded in DESIGN.md §6).  Uniform SWA window 2048 (the
release uses SWA on all but 3 layers), so the attention cache is a ring
buffer and long_500k is runnable with O(1) SSM state + O(window) KV.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676; hf",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attention_kind="parallel_ssm",
    window=2048,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=128,
    ssm_groups=1,
    compute_dtype="bfloat16",
)
