"""qwen3-moe-30b-a3b [moe]: 128 experts top-8.

48L d_model=2048 32H (GQA kv=4) d_ff=768(expert) vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    attention_kind="gqa",
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    rope_theta=1e6,
    compute_dtype="bfloat16",
)
