"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Local smoke driver for the full production loop: config → data → sharded
train step → checkpoints → fault-tolerant resume.  On a real cluster the
same entrypoint runs under ``jax.distributed.initialize()`` with the
production mesh; here it defaults to the host mesh and a reduced config
(pass --full to lower the assigned full-scale config — requires the
device memory to match, i.e. a real pod).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import SHAPES, TrainConfig
from repro.data import SyntheticLM, make_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model_zoo
from repro.runtime import fault_tolerance as ft
from repro.runtime import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=model_zoo.list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="full-scale config on the production mesh")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = model_zoo.get_config(args.arch)
    if args.full:
        mesh = make_production_mesh()
        shape = SHAPES["train_4k"]
        seq, batch = shape.seq_len, shape.global_batch
    else:
        cfg = model_zoo.reduced_config(cfg)
        mesh = make_host_mesh()
        seq, batch = args.seq_len, args.batch

    tc = TrainConfig(steps=args.steps, learning_rate=args.lr,
                     checkpoint_every=args.checkpoint_every,
                     warmup_steps=max(args.steps // 20, 2))
    src = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                      batch_size=batch)
    embed_dim = cfg.d_model if cfg.modality != "text" else None

    shutdown = ft.GracefulShutdown().install()
    watchdog = ft.StepWatchdog(
        on_straggler=lambda ev: print(
            f"[watchdog] straggler step {ev.step}: {ev.dt:.2f}s vs "
            f"EMA {ev.ema:.2f}s"))

    state, start = None, 0
    if args.resume and args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(args.ckpt_dir)
        like = train_loop.abstract_state(cfg, tc)
        state, start = ft.resume_or_init(
            mgr, lambda: train_loop.init_state(cfg, tc), like,
            shardings=train_loop.state_shardings(like, mesh))
        print(f"resume: starting at step {start}")

    data = make_batches(src, embed_dim=embed_dim, start_step=start)
    state, history = train_loop.train(
        cfg, tc, mesh, data, ckpt_dir=args.ckpt_dir,
        log_every=args.log_every, shutdown=shutdown, watchdog=watchdog,
        state=state, start_step=start)
    print(f"done: {len(history)} logged steps, "
          f"final loss {history[-1]['loss']:.4f}"
          if history else "done (no steps)")


if __name__ == "__main__":
    main()
