"""Plan-store autotuner: ``python -m repro.launch.autotune --plan-store P``.

Offline measured autotune (core/autotune.measured_autotune) over the
serving plan surface, committed to a persistent plan store that
``launch/serve --plan-store`` (and any Engine built with
``plan_store=``) then starts hot from:

  * the paper's twelve prefill GEMMs at M = PAPER_M, per weight format
    (fp32 and, with ``--quant``, int8 + ternary);
  * the decode ladder: the same shapes at every ``gemm.DECODE_M_BUCKETS``
    width under the decode policy arm (split-K candidates scored);
  * with ``--sparse-buckets``, the sparse-ternary arm: each shape swept
    at the given zero-group-fraction deciles with synthetic group-sparse
    weights, committed under density-bucketed store keys.

Every committed plan passed the bit-exactness gate; every measured win
cleared the retry-on-noise floor (mis-tune guard: a candidate that never
beats the analytic plan by ``NOISE_RTOL`` is NOT deployed — the analytic
plan stands, recorded as ``analytic_kept``).

``--dry-run`` (CI serving-smoke job) sweeps one tiny shape, then proves
the store ROUND-TRIPS: save, reload in a fresh PlanStore, and assert the
tuned plan comes back equal and validated — the contract a warm-started
server relies on.
"""
from __future__ import annotations

import argparse
import json
import time

from repro import gemm as gemm_api
from repro import obs
from repro.core import autotune
from repro.models.model_zoo import PAPER_GEMM_SHAPES, PAPER_M


def _sweep_one(m, n, k, *, weight_format, decode, label, args,
               density_bucket=-1):
    t0 = time.perf_counter()
    with obs.span("autotune_sweep", label=label, m=m, n=n, k=k,
                  format=weight_format, decode=decode,
                  density_bucket=density_bucket) as sp:
        mp = autotune.measured_autotune(
            m, n, k, weight_format=weight_format, decode=decode,
            trials=args.trials, max_retries=args.max_retries,
            max_candidates=args.max_candidates,
            density_bucket=density_bucket)
        sp.set(analytic_kept=mp.analytic, speedup=float(mp.speedup),
               candidates=mp.candidates, retries=mp.retries,
               rejected=mp.rejected)
    row = {"label": label, "M": m, "N": n, "K": k,
           "format": weight_format, "decode": decode,
           "density_bucket": density_bucket,
           "sweep_s": round(time.perf_counter() - t0, 3), **mp.row()}
    kind = "analytic kept" if mp.analytic else \
        f"tuned {mp.speedup:.2f}x"
    print(f"  {label:<28s} M={m:<4d} N={n:<5d} K={k:<5d} "
          f"{weight_format:<7s} {'decode' if decode else 'prefill'}: "
          f"{kind} ({mp.candidates} candidates, {mp.retries} retries, "
          f"{mp.rejected} gate-rejected)")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan-store", required=True, metavar="PATH",
                    help="store file to populate (loaded first if it "
                         "exists — re-runs extend, corrupt files are "
                         "discarded with a warning, never a crash)")
    ap.add_argument("--quant", action="store_true",
                    help="also sweep the quantized weight formats "
                         "(int8, ternary) per shape")
    ap.add_argument("--sparse-buckets", default=None, metavar="B,B",
                    help="comma-separated density buckets (0..9) to "
                         "sweep the sparse-ternary arm at, per shape "
                         "(e.g. '3,5,7')")
    ap.add_argument("--decode-buckets", action="store_true",
                    help="also sweep the decode ladder: every "
                         "gemm.DECODE_M_BUCKETS width per shape, under "
                         "the decode policy arm")
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--max-candidates", type=int, default=4)
    ap.add_argument("--out", default=None, metavar="JSON",
                    help="also write the sweep rows (MeasuredPlan.row "
                         "per dispatch) to this JSON file")
    ap.add_argument("--dry-run", action="store_true",
                    help="one tiny shape + store round-trip assert "
                         "(the CI smoke)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the sweep as a Chrome-trace/Perfetto "
                         "timeline (autotune_sweep spans with per-round "
                         "autotune_measure children)")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace_out:
        tracer = obs.Tracer()
        obs.set_tracer(tracer)

    store = gemm_api.PlanStore.load(args.plan_store)
    if store.invalidated:
        print(f"plan store {args.plan_store} discarded: "
              f"{store.invalidated} — starting fresh")
    elif len(store):
        print(f"plan store {args.plan_store}: extending "
              f"{store.info().entries} existing entries")

    rows = []
    with gemm_api.use_plan_store(store):
        if args.dry_run:
            rows.append(_sweep_one(32, 64, 64, weight_format="fp32",
                                   decode=False, label="dry", args=args))
            # sparse-ternary arm smoke: a density-bucketed key must
            # sweep, commit, and round-trip exactly like a dense one
            rows.append(_sweep_one(32, 128, 512, weight_format="ternary",
                                   decode=False, label="dry-sparse",
                                   args=args, density_bucket=5))
        else:
            formats = ["fp32"] + (["int8", "ternary"] if args.quant
                                  else [])
            for model, op, n, k in PAPER_GEMM_SHAPES:
                for fmt in formats:
                    rows.append(_sweep_one(
                        PAPER_M, n, k, weight_format=fmt, decode=False,
                        label=f"{model}/{op}", args=args))
            if args.sparse_buckets:
                buckets = [int(b) for b in
                           args.sparse_buckets.split(",") if b != ""]
                for model, op, n, k in PAPER_GEMM_SHAPES:
                    for db in buckets:
                        rows.append(_sweep_one(
                            PAPER_M, n, k, weight_format="ternary",
                            decode=False, density_bucket=db,
                            label=f"{model}/{op}@d{db}", args=args))
            if args.decode_buckets:
                for model, op, n, k in PAPER_GEMM_SHAPES:
                    for bucket in gemm_api.DECODE_M_BUCKETS:
                        rows.append(_sweep_one(
                            bucket, n, k, weight_format="fp32",
                            decode=True,
                            label=f"{model}/{op}@m{bucket}", args=args))

    path = store.save()
    info = store.info()
    print(f"plan store saved -> {path}: {info.entries} entries "
          f"({info.autotuned} measured-autotuned)")

    # round-trip proof: a FRESH store (a warm-starting server) reads
    # back every committed plan equal and pre-validated — no analytic
    # re-resolution, no gate re-runs
    fresh = gemm_api.PlanStore.load(path)
    assert not fresh.invalidated, fresh.invalidated
    assert len(fresh) == info.entries, (len(fresh), info.entries)
    for key in store.keys():
        p = fresh.lookup(key)
        assert p is not None, f"round-trip lost {key}"
        assert p == store.lookup(key), f"round-trip changed {key}"
        assert p.validated, f"round-trip entry not validated: {key}"
    print(f"round-trip OK: {len(fresh)} entries reload equal and "
          f"validated from a fresh store")
    if args.dry_run:
        print("dry-run OK: sweep committed a gate-passed plan and the "
              "store round-trips")

    if tracer is not None:
        obs.set_tracer(None)
        tracer.export_chrome_trace(args.trace_out)
        print(f"trace written -> {args.trace_out} "
              f"({len(tracer.events)} span events)")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": {"store": path,
                                "entries": info.entries,
                                "autotuned": info.autotuned},
                       "rows": rows}, f, indent=1)
        print(f"sweep rows -> {args.out}")
    return rows


if __name__ == "__main__":
    main()
