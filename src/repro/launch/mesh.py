"""Production meshes.

Single pod: 256 chips as (data=16, model=16) — TP stays inside the pod's
ICI where the 16-way axis has full bisection bandwidth.  Multi-pod: the
``pod`` axis (DCN-connected) composes with ``data`` for batch parallelism
only, so the sole cross-pod collective in a train step is the gradient
reduction (see parallel/sharding.py).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run pins device count via XLA_FLAGS before any
jax initialization).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = compat.axis_type_auto()
    return compat.make_mesh(
        shape, axes,
        axis_types=auto and (auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Local mesh over whatever devices exist (smoke tests, examples)."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    auto = compat.axis_type_auto()
    return compat.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=auto and (auto,) * 2)


# TPU v5e hardware model used by the roofline (single source of truth).
HW = {
    "name": "tpu-v5e",
    "peak_flops_bf16": 197e12,      # per chip
    "peak_flops_fp32": 98.5e12,
    "hbm_bw": 819e9,                # bytes/s per chip
    "ici_bw": 50e9,                 # bytes/s per link
    "hbm_bytes": 16 * 2**30,
    "chips_per_pod": 256,
}
