"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the packed-weight engine (paper deployment) against the per-call and
raw-XLA baselines on the same prompts, reporting prefill/decode
tokens-per-second — the framework-native form of the paper's llama.cpp
integration (§4.7).
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro import gemm as gemm_api
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo
from repro.runtime.serve_loop import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=model_zoo.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--backend", default=None,
                    choices=gemm_api.list_backends(),
                    help="GEMM backend for this engine's plans "
                         "(default: process default, xla on CPU)")
    ap.add_argument("--compare-percall", action="store_true",
                    help="also time the unpacked (per-call) engine")
    args = ap.parse_args()

    cfg = model_zoo.reduced_config(model_zoo.get_config(args.arch))
    mesh = make_host_mesh()
    params = model_zoo.build(cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    if cfg.modality != "text":
        prompts = jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)), cfg.cdtype)

    t0 = time.perf_counter()
    eng = Engine(cfg, params, mesh=mesh, max_len=args.max_len, packed=True,
                 backend=args.backend)
    print(f"model load + pack (untimed in per-call metrics): "
          f"{time.perf_counter() - t0:.2f}s")
    if cfg.modality != "text":
        logits, _ = eng.prefill(prompts)
        print(f"stub-frontend arch: prefill ok, logits {logits.shape}")
        return
    gen, stats = eng.generate(prompts, args.max_new)
    print(f"packed engine: prefill {stats.prefill_tps:,.0f} tok/s, "
          f"decode {stats.decode_tps:,.0f} tok/s")
    if args.compare_percall:
        eng2 = Engine(cfg, params, mesh=mesh, max_len=args.max_len,
                      packed=False, backend=args.backend)
        gen2, stats2 = eng2.generate(prompts, args.max_new)
        print(f"per-call engine: prefill {stats2.prefill_tps:,.0f} tok/s, "
              f"decode {stats2.decode_tps:,.0f} tok/s")
        print("outputs identical:", bool(jnp.array_equal(gen, gen2)))


if __name__ == "__main__":
    main()
