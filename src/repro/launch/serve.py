"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the packed-weight engine (paper deployment) against the per-call and
raw-XLA baselines on the same prompts, reporting prefill/decode
tokens-per-second — the framework-native form of the paper's llama.cpp
integration (§4.7).

With ``--requests N`` it also serves a mixed-length request stream
through the continuous-batching pool (``--batch-slots`` slots, chunked
prefill admission of ``--prefill-chunk`` rows) and reports per-request
latency percentiles: queue wait, time-to-first-token, and per-request
decode tokens/s — the stats fields docs/serving.md describes.

Observability (docs/observability.md): ``--trace-out PATH`` records the
whole run — pack, plan resolution, warmup, every scheduler tick, prefix
cache and fault events, plus the GEMM flight recorder — as a
Chrome-trace JSON loadable at ui.perfetto.dev and summarizable with
``repro.launch.trace_report``; ``--metrics-out PATH`` writes the unified
metrics registry's snapshot (JSON, plus Prometheus text at PATH.prom).
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro import gemm as gemm_api
from repro import obs
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo
from repro.runtime.serve_loop import Engine


def _pct(stats, field):
    return (stats.percentile(field, 50) * 1e3,
            stats.percentile(field, 95) * 1e3)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=model_zoo.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--backend", default=None,
                    choices=gemm_api.list_backends(),
                    help="GEMM backend for this engine's plans "
                         "(default: process default, xla on CPU)")
    ap.add_argument("--no-fusion", action="store_true",
                    help="disable horizontal QKV/gate-up fusion and the "
                         "fused epilogues (A/B escape hatch; default: "
                         "fusion on)")
    ap.add_argument("--quant", default=None, choices=["int8", "ternary"],
                    help="serve on quantized packed weights (mixed "
                         "precision: LM head + embeddings stay fp32); "
                         "every pack is tolerance-gated by the error "
                         "ledger (docs/quantization.md)")
    ap.add_argument("--compare-percall", action="store_true",
                    help="also time the unpacked (per-call) engine")
    ap.add_argument("--requests", type=int, default=0,
                    help="serve N mixed-length requests through the "
                         "continuous-batching pool and report "
                         "per-request percentiles")
    ap.add_argument("--batch-slots", type=int, default=4,
                    help="slot-pool width for continuous batching")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunked-prefill admission width (rows); padded "
                         "to a gemm.bucket_m bucket")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size (tokens); must divide --max-len")
    ap.add_argument("--megastep-depth", type=int, default=1,
                    help="decode ticks fused per host dispatch (the "
                         "decode megastep; 1 = per-tick dispatch)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cross-request prefix cache: requests sharing "
                         "a cached prompt prefix reuse its KV pages "
                         "(refcounted, COW-forked at the divergence "
                         "page) and prefill only the divergent tail")
    ap.add_argument("--plan-store", default=None, metavar="PATH",
                    help="persistent plan/autotune store (JSON): loaded "
                         "corruption-tolerantly at startup (a populated "
                         "store makes the engine start hot — zero "
                         "analytic re-resolution and zero bit-exactness "
                         "gate runs; measured-autotuned winners adopted), "
                         "updated with this run's plans, saved at exit. "
                         "Pre-populate with repro.launch.autotune")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-populate the plan cache and compile the "
                         "serving steps (prefill + decode buckets) "
                         "before the first request")
    ap.add_argument("--watchdog-factor", type=float, default=0.0,
                    help="arm the straggler watchdog over scheduler "
                         "ticks: a tick slower than FACTOR x the EMA is "
                         "flagged and reported (0 = off)")
    ap.add_argument("--ttft-budget-s", type=float, default=None,
                    help="per-request time-to-first-token deadline "
                         "(seconds); requests that miss it end "
                         "TIMED_OUT instead of occupying a slot")
    ap.add_argument("--total-budget-s", type=float, default=None,
                    help="per-request total wall-clock deadline "
                         "(seconds, enqueue-relative)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON timeline of "
                         "this run (span tracing + GEMM flight recorder; "
                         "load at ui.perfetto.dev, or summarize with "
                         "repro.launch.trace_report)")
    ap.add_argument("--trace-fence", action="store_true",
                    help="fence (block_until_ready) eagerly-dispatched "
                         "GEMMs so their recorder entries carry real "
                         "execution times and GFLOPS — serializes the "
                         "pipeline (docs/observability.md)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics snapshot at exit: JSON to PATH "
                         "and Prometheus text beside it (PATH + '.prom')")
    args = ap.parse_args()

    # obs activation happens BEFORE engine construction so pack /
    # plan-resolve / warmup spans and the jitted steps' GEMM manifests
    # land in the same timeline as the serve itself
    tracer = rec = reg = None
    if args.trace_out:
        tracer = obs.Tracer()
        obs.set_tracer(tracer)
        rec = obs.FlightRecorder(fence=args.trace_fence)
        obs.set_recorder(rec)
    if args.metrics_out:
        reg = obs.MetricsRegistry()
        reg.add_collector(obs.gemm_collector)
        obs.set_metrics(reg)

    cfg = model_zoo.reduced_config(model_zoo.get_config(args.arch))
    mesh = make_host_mesh()
    params = model_zoo.build(cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    if cfg.modality != "text":
        prompts = jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)), cfg.cdtype)

    store = (gemm_api.PlanStore.load(args.plan_store)
             if args.plan_store else None)
    if store is not None:
        info = store.info()
        print(f"plan store {args.plan_store}: {info.entries} entries "
              f"loaded ({info.autotuned} measured-autotuned)"
              + (f"  [invalidated: {store.invalidated}]"
                 if store.invalidated else ""))
    t0 = time.perf_counter()
    eng = Engine(cfg, params, mesh=mesh, max_len=args.max_len, packed=True,
                 backend=args.backend, fuse=not args.no_fusion,
                 quant=args.quant, plan_store=store)
    print(f"model load + pack (untimed in per-call metrics): "
          f"{time.perf_counter() - t0:.2f}s  "
          f"[fusion {'off' if args.no_fusion else 'on'}, "
          f"quant {args.quant or 'off'}]")
    if args.quant:
        from repro.quant import ledger
        ents = ledger.entries()
        if ents:
            worst = max(ents, key=lambda e: e.max_rel / e.tol)
            print(f"error ledger: {len(ents)} packs measured, all within "
                  f"tolerance; worst max_rel {worst.max_rel:.2e} "
                  f"(tol {worst.tol:.0e}, shape {worst.k}x{worst.n})")
            n_sparse = sum(1 for e in ents if e.sparse)
            if n_sparse:
                dens = [e.density for e in ents]
                print(f"  sparse-ternary: {n_sparse}/{len(ents)} packs on "
                      f"the compressed zero-group layout, mean occupied "
                      f"density {sum(dens) / len(dens):.2f}")
    if cfg.modality != "text":
        logits, _ = eng.prefill(prompts)
        print(f"stub-frontend arch: prefill ok, logits {logits.shape}")
        return
    if args.warmup:
        t0 = time.perf_counter()
        wt = eng.warmup_plans(batch_slots=args.batch_slots,
                              prefill_chunk=args.prefill_chunk,
                              page_size=args.page_size,
                              megastep_depth=args.megastep_depth)
        pc = wt.pop("plan_cache")
        ps = wt.pop("plan_store", None)
        n_bucket = wt.pop("decode_bucket_plans")
        steps = ", ".join(f"{k} {v * 1e3:.0f}ms" for k, v in wt.items())
        print(f"warmup ({time.perf_counter() - t0:.2f}s): {steps}; "
              f"{n_bucket} decode-bucket plans pre-resolved, "
              f"{pc.currsize} plans cached — first serving tick pays "
              f"no jit/plan latency")
        if ps is not None:
            print(f"  plan store: {ps.hits} hits / {ps.misses} misses "
                  f"({ps.autotuned} autotuned entries adopted)")
    gen, stats = eng.generate(prompts, args.max_new)
    qd = (f", density {stats.quant_density:.2f} "
          f"({stats.quant_sparse_packs} sparse packs)"
          if stats.quant_density is not None else "")
    print(f"packed engine (fused={stats.fused}, quant={stats.quant}{qd}): "
          f"prefill {stats.prefill_tps:,.0f} tok/s, "
          f"decode {stats.decode_tps:,.0f} tok/s")
    print(f"  plan cache: {stats.plan_cache.hits} hits / "
          f"{stats.plan_cache.misses} misses "
          f"({stats.plan_cache.currsize} cached, "
          f"{stats.vmem_clamped_plans} vmem-clamped)"
          if stats.plan_cache else "")
    if stats.plan_store is not None:
        sp = stats.plan_store
        print(f"  plan store: {sp.hits} hits / {sp.misses} misses "
              f"({sp.autotuned} autotuned, {sp.entries} entries)")
    if args.compare_percall:
        eng2 = Engine(cfg, params, mesh=mesh, max_len=args.max_len,
                      packed=False, backend=args.backend)
        gen2, stats2 = eng2.generate(prompts, args.max_new)
        print(f"per-call engine: prefill {stats2.prefill_tps:,.0f} tok/s, "
              f"decode {stats2.decode_tps:,.0f} tok/s")
        print("outputs identical:", bool(jnp.array_equal(gen, gen2)))

    if args.requests > 0:
        if args.prefix_cache:
            # shared-preamble traffic (the workload the cache exists
            # for): 80% of requests open with one fixed preamble of
            # half the prompt budget, then a unique tail
            pre = rng.integers(0, cfg.vocab_size,
                               max(args.prompt_len // 2, 1)) \
                .astype(np.int32)
            tail_hi = max(args.prompt_len - pre.size, 4)
            reqs = [np.concatenate(
                        [pre, rng.integers(0, cfg.vocab_size,
                                           rng.integers(1, tail_hi + 1))
                         .astype(np.int32)])
                    if rng.random() < 0.8 else
                    rng.integers(0, cfg.vocab_size,
                                 rng.integers(4, args.prompt_len + 1))
                    .astype(np.int32)
                    for _ in range(args.requests)]
        else:
            reqs = [rng.integers(0, cfg.vocab_size,
                                 rng.integers(4, args.prompt_len + 1))
                    .astype(np.int32) for _ in range(args.requests)]
        mns = [int(m) for m in
               rng.integers(2, args.max_new + 1, args.requests)]
        # graceful drain: SIGTERM finishes in-flight requests, cancels
        # the queue with structured outcomes, and still saves the plan
        # store below — the grace-window exit docs/serving.md describes
        from repro.runtime.fault_tolerance import GracefulShutdown
        gs = GracefulShutdown().install()
        try:
            outs, sstats = eng.serve(
                reqs, batch_slots=args.batch_slots, max_new_tokens=mns,
                prefill_chunk=args.prefill_chunk,
                page_size=args.page_size,
                megastep_depth=args.megastep_depth,
                prefix_cache=args.prefix_cache,
                watchdog_factor=args.watchdog_factor or None,
                shutdown=gs, ttft_budget_s=args.ttft_budget_s,
                total_budget_s=args.total_budget_s,
                sync_per_step=True)  # exact TTFT / queue-wait pctiles
        finally:
            gs.uninstall()
        qw = _pct(sstats, "queue_wait_s")
        tf = _pct(sstats, "ttft_s")
        print(f"continuous batching ({args.requests} requests, "
              f"{args.batch_slots} slots, chunk {args.prefill_chunk}, "
              f"megastep D={args.megastep_depth}, prefix cache "
              f"{'on' if args.prefix_cache else 'off'}):")
        print(f"  aggregate: {sstats.total_tps:,.0f} generated tok/s "
              f"({sstats.decode_tokens} tokens in {sstats.wall_s:.2f}s)")
        print(f"  queue wait  p50 {qw[0]:8.1f} ms   p95 {qw[1]:8.1f} ms")
        print(f"  TTFT        p50 {tf[0]:8.1f} ms   p95 {tf[1]:8.1f} ms")
        print(f"  per-request decode tok/s: "
              f"p50 {sstats.percentile('decode_tps', 50):,.0f}   "
              f"p5 {sstats.percentile('decode_tps', 5):,.0f}")
        print(f"  per-phase ticks: prefill "
              f"p50 {sstats.phase_percentile('prefill', 50):6.1f} ms / "
              f"p99 {sstats.phase_percentile('prefill', 99):6.1f} ms   "
              f"decode p50 {sstats.phase_percentile('decode', 50):6.1f} "
              f"ms / p99 {sstats.phase_percentile('decode', 99):6.1f} ms")
        print(f"  decode dispatch collapse: {sstats.decode_ticks} ticks "
              f"in {sstats.decode_dispatches} dispatches "
              f"({sstats.host_syncs} host syncs)")
        import collections as _coll
        by_state = _coll.Counter(o.state.value
                                 for o in sstats.outcomes.values())
        extras = ", ".join(f"{k} {v}" for k, v in sorted(by_state.items())
                           if k != "DONE")
        print(f"  outcomes: {by_state.get('DONE', 0)}/{args.requests} "
              f"DONE" + (f" ({extras})" if extras else ""))
        if gs.requested:
            print("  graceful shutdown: drained in-flight requests, "
                  "cancelled the queue")
        if sstats.degraded:
            print("  degraded: " + ", ".join(
                f"{k} x{v}" for k, v in sorted(sstats.degraded.items())))
        if args.watchdog_factor:
            print(f"  watchdog (factor {args.watchdog_factor:g}): "
                  f"{len(sstats.stragglers)} straggler ticks"
                  + ("".join(f"\n    tick {ev.step}: {ev.dt * 1e3:.1f} ms "
                             f"(EMA {ev.ema * 1e3:.1f} ms)"
                             for ev in sstats.stragglers[:5])))
        if sstats.prefix is not None:
            px = sstats.prefix
            print(f"  prefix cache: {px.hits}/{px.lookups} hits "
                  f"({px.hit_rate:.0%}), {px.hit_tokens} prompt tokens "
                  f"reused, {px.cow_forks} COW forks, "
                  f"{px.evicted_pages} pages evicted, "
                  f"{px.cached_pages} pages cached at end")

    if store is not None:
        store.save()
        print(f"plan store saved -> {store.path} "
              f"({store.info().entries} entries)")

    if tracer is not None:
        obs.set_tracer(None)
        obs.set_recorder(None)
        tracer.export_chrome_trace(args.trace_out, recorder=rec)
        s = rec.summary()
        print(f"trace written -> {args.trace_out} "
              f"({len(tracer.events)} span events"
              + (f", {tracer.dropped} dropped" if tracer.dropped else "")
              + f"; flight recorder: {s['total']} eager dispatches, "
              f"{s['traced']} traced registrations, "
              f"fence {'on' if s['fence'] else 'off'})")
        print("  load at ui.perfetto.dev, or summarize: "
              f"python -m repro.launch.trace_report {args.trace_out}")
    if reg is not None:
        obs.set_metrics(None)
        reg.write_snapshot(args.metrics_out)
        with open(args.metrics_out + ".prom", "w") as f:
            f.write(reg.prometheus_text(collect=False))
        print(f"metrics snapshot -> {args.metrics_out} "
              f"(+ {args.metrics_out}.prom, Prometheus text)")


if __name__ == "__main__":
    main()
