# XLA device-count pin: MUST precede every other import (jax locks the
# device count at first init).  512 host devices = 2 pods x 256 chips.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# compile-only analysis targets the TPU MXU: native-dtype dot operands
# (the CPU thunk runtime can't EXECUTE bf16 dots, but never executes here)
os.environ.setdefault("REPRO_MXU_DOTS", "1")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves, without hardware: (a) the sharding config is coherent (GSPMD
partitions every step over 256- and 512-chip meshes), (b) the memory plan
fits (memory_analysis), and (c) the cost/collective profile that feeds
§Roofline (cost_analysis + compiled-HLO collective parse).

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both      # every cell
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import gemm as gemm_api
from repro.configs.base import SHAPES, TrainConfig
from repro.launch.mesh import HW, make_production_mesh
from repro.models import model_zoo, transformer
from repro.parallel import sharding as Sh
from repro.roofline import analysis as R
from repro.roofline.hlo_cost import HloCostModel
from repro.runtime import train_loop


def _lower_cell(cfg, shape, mesh, *, packed: bool = True,
                microbatch_per_device: int = 1,
                train_overrides: dict | None = None):
    """Build + lower the step this cell exercises.  Returns (lowered,
    extras dict)."""
    if cfg.family == "moe" and cfg.moe_dispatch_groups == 0 \
            and cfg.moe_dispatch == "grouped":
        shards = Sh.axis_size(mesh, ("pod", "data"))
        if shape.global_batch % shards == 0:
            cfg = dataclasses.replace(cfg, moe_dispatch_groups=shards)
    if shape.kind == "train":
        tc = TrainConfig(microbatch_per_device=microbatch_per_device,
                         **(train_overrides or {}))
        step = train_loop.make_train_step(
            cfg, tc, mesh,
            batch_shardings=train_loop.batch_shardings(cfg, shape, mesh))
        state = train_loop.abstract_state(cfg, tc)
        batch = model_zoo.input_specs(cfg, shape)
        return step.lower(state, batch), {"step": "train_step"}

    raw = model_zoo.abstract_params(cfg)
    if packed:
        params = jax.eval_shape(
            lambda p: model_zoo.pack_for_inference(cfg, p), raw)
    else:
        params = raw
    # serving placement: TP-only (data-replicated) when weights fit —
    # §Perf iteration C1 (see parallel/sharding.serve_param_specs)
    p_sh = Sh.serve_param_shardings(params, mesh)
    shard_fn = Sh.activation_sharder(mesh)
    ins = model_zoo.input_specs(cfg, shape)

    if shape.kind == "prefill":
        def prefill_fn(params, inputs):
            return transformer.prefill(cfg, params, inputs,
                                       max_len=shape.seq_len,
                                       shard_fn=shard_fn)
        i_sh = jax.NamedSharding(
            mesh, Sh.batch_spec(shape.global_batch, mesh,
                                extra_dims=ins["inputs"].ndim - 1))
        fn = jax.jit(prefill_fn, in_shardings=(p_sh, i_sh))
        return fn.lower(params, ins["inputs"]), {"step": "prefill"}

    # decode: one new token against a seq_len-deep cache
    def decode_fn(params, cache, tokens):
        return transformer.decode_step(cfg, params, cache, tokens,
                                       shard_fn=shard_fn)
    c_sh = Sh.cache_shardings(ins["cache"], mesh)
    t_sh = jax.NamedSharding(
        mesh, Sh.batch_spec(shape.global_batch, mesh,
                            extra_dims=ins["tokens"].ndim - 1))
    fn = jax.jit(decode_fn, in_shardings=(p_sh, c_sh, t_sh),
                 out_shardings=(None, c_sh))
    return fn.lower(params, ins["cache"], ins["tokens"]), \
        {"step": "serve_step"}


def _attn_ideal_bytes(cfg, shape, chips: int) -> float:
    """Per-device HBM traffic of the DEPLOYED attention path — the Pallas
    flash kernel (kernels/flash_attention.py), which keeps score blocks
    in VMEM and touches HBM only for q/k/v/out (+grads in training).

    The XLA-CPU lowering of the jnp fallback materializes every score
    block (measured: the dominant memory term on SSM/hybrid train cells),
    so §Roofline reports both the XLA-path term and this kernel-adjusted
    term.  Model: bytes(q+k+v+out) × passes, where passes ≈ 2 (fwd r+w)
    for inference and 6 for training (fwd + bwd recompute + grad IO),
    × layers, global traffic ÷ chips.
    """
    if cfg.attention_kind == "none":
        return 0.0
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    t = shape.seq_len
    if cfg.window is not None and cfg.local_global_period == 0:
        t = min(t, cfg.window)
    if cfg.attention_kind == "mla":
        hq, dq_ = cfg.num_heads, cfg.kv_lora_rank + cfg.qk_rope_dim
        hkv, dk_ = 1, cfg.kv_lora_rank + cfg.qk_rope_dim
    else:
        hq, dq_ = cfg.num_heads, cfg.head_dim
        hkv, dk_ = cfg.num_kv_heads, cfg.head_dim
    per_layer = 4.0 * (b * s * hq * dq_ * 2        # q + out
                       + b * t * hkv * dk_ * 2)    # k + v
    passes = 6.0 if shape.kind == "train" else 2.0
    return per_layer * passes * cfg.num_layers / chips


def _ssd_ideal_bytes(cfg, shape, chips: int) -> float:
    """Per-device HBM traffic of the Pallas SSD kernel (kernels/ssd.py):
    x/a/b/c read + y written once per pass; quadratic intra-chunk blocks
    stay in VMEM.  passes ≈ 2 inference / 6 training (see
    _attn_ideal_bytes)."""
    if not cfg.ssm_heads:
        return 0.0
    b = shape.global_batch
    t = shape.seq_len if shape.kind != "decode" else 1
    h, p, n, g = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                  cfg.ssm_groups)
    per_layer = 4.0 * (b * t * h * p * 2        # x + y
                       + b * t * h              # a
                       + b * t * g * n * 2)     # b + c
    passes = 6.0 if shape.kind == "train" else 2.0
    return per_layer * passes * cfg.num_layers / chips


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             packed: bool = True, verbose: bool = True,
             microbatch_per_device: int = 1,
             train_overrides: dict | None = None,
             gemm_backend: str | None = None) -> dict:
    cfg = model_zoo.get_config(arch)
    shape = SHAPES[shape_name]
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size

    t0 = time.perf_counter()
    # the use_backend scope covers tracing/lowering, so every gemm plan in
    # the cell resolves to the requested backend (default: xla — Pallas
    # can't lower on the forced-host platform this dry-run pins)
    with gemm_api.use_backend(gemm_backend):
        lowered, extras = _lower_cell(
            cfg, shape, mesh, packed=packed,
            microbatch_per_device=microbatch_per_device,
            train_overrides=train_overrides)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()

    # Loop-aware walker (XLA's cost_analysis counts scan bodies once —
    # see roofline/hlo_cost.py); numbers below are per device.
    model = HloCostModel(hlo, total_devices=chips,
                         dcn_group_size=(2 if multi else None),
                         tags={"attn": r"flash_attn", "ssd": r"ssd_chunk"})
    cost = model.cost()
    ici_wire = cost.coll_wire_bytes - cost.coll_wire_dcn
    dcn_bw = HW["ici_bw"] / 2
    coll = {
        "seconds": ici_wire / HW["ici_bw"] + cost.coll_wire_dcn / dcn_bw,
        "dcn_seconds": cost.coll_wire_dcn / dcn_bw,
        "by_kind": {k: v / HW["ici_bw"]
                    for k, v in cost.coll_by_kind.items()},
        "num_ops": sum(1 for c in model.comps.values() for o in c.ops
                       if o.op in ("all-gather", "all-reduce",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute")),
        "wire_bytes": cost.coll_wire_bytes,
    }

    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
    }
    mem["peak_bytes_per_device"] = (mem["argument_bytes"]
                                    + mem["output_bytes"]
                                    + mem["temp_bytes"]
                                    - mem["alias_bytes"])
    terms = R.roofline_terms(
        flops_per_device=cost.flops,
        bytes_per_device=cost.hbm_bytes,
        collective=coll, chips=chips,
        model_fl=R.model_flops(cfg, shape),
        dtype=("bf16" if cfg.compute_dtype == "bfloat16" else "fp32"))
    # Pallas-kernel-adjusted memory term: replace the XLA-materialized
    # attention / SSD block traffic (tagged via named_scope) with the
    # kernels' analytic HBM traffic (kernels/flash_attention.py,
    # kernels/ssd.py keep those blocks in VMEM).
    attn_xla = cost.hbm_by_tag.get("attn", 0.0)
    ssd_xla = cost.hbm_by_tag.get("ssd", 0.0)
    attn_ideal = _attn_ideal_bytes(cfg, shape, chips)
    ssd_ideal = _ssd_ideal_bytes(cfg, shape, chips)
    adj_bytes = max(cost.hbm_bytes - attn_xla - ssd_xla, 0.0) \
        + min(attn_ideal, attn_xla) + min(ssd_ideal, ssd_xla)
    terms["memory_attn_xla_s"] = attn_xla / HW["hbm_bw"]
    terms["memory_ssd_xla_s"] = ssd_xla / HW["hbm_bw"]
    terms["memory_adjusted_s"] = adj_bytes / HW["hbm_bw"]
    adj_terms = {"compute": terms["compute_s"],
                 "memory": terms["memory_adjusted_s"],
                 "collective": terms["collective_s"]}
    terms["dominant_adjusted"] = max(adj_terms, key=adj_terms.get)
    terms["bound_adjusted_s"] = max(adj_terms.values())

    by_kind_bytes = dict(cost.coll_by_kind)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "step": extras["step"], "packed": packed,
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": {"flops_per_device": cost.flops,
                 "hbm_bytes_per_device": cost.hbm_bytes,
                 "loops": model.loops,
                 "xla_single_count_flops": float(ca.get("flops", 0.0)),
                 "xla_single_count_bytes": float(
                     ca.get("bytes accessed", 0.0))},
        "collectives": {"num_ops": coll["num_ops"],
                        "wire_bytes_per_device": coll["wire_bytes"],
                        "seconds": coll["seconds"],
                        "dcn_seconds": coll["dcn_seconds"],
                        "by_kind_s": coll["by_kind"],
                        "by_kind_bytes": by_kind_bytes},
        "roofline": terms,
        "fits_hbm": mem["peak_bytes_per_device"] <= HW["hbm_bytes"],
    }
    if verbose:
        print(f"== {arch} × {shape_name} × {mesh_name} "
              f"({extras['step']}, {chips} chips) ==")
        print("memory_analysis:", ma)
        print("cost_analysis (xla, single-count):",
              {k: v for k, v in ca.items() if "utilization" not in k
               and "bytes accessed" not in k or k == "bytes accessed"})
        print(f"cost walker: {cost.flops/1e12:.3f} TFLOP/device, "
              f"{cost.hbm_bytes/1e9:.2f} GB HBM/device, "
              f"loops={[(l['trips']) for l in model.loops]}")
        print(f"collectives: {coll['num_ops']} ops, "
              f"{coll['wire_bytes']/1e6:.1f} MB/device on the wire")
        print(f"roofline: compute {terms['compute_s']*1e3:.3f} ms | "
              f"memory {terms['memory_s']*1e3:.3f} ms "
              f"(pallas-adj {terms['memory_adjusted_s']*1e3:.3f}) | "
              f"collective {terms['collective_s']*1e3:.3f} ms "
              f"→ {terms['dominant']}-bound "
              f"(adj: {terms['dominant_adjusted']}); useful-FLOP ratio "
              f"{terms['useful_ratio']:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    choices=model_zoo.list_archs() + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="every non-skipped (arch × shape) cell")
    ap.add_argument("--raw", action="store_true",
                    help="serve steps with unpacked weights (baseline)")
    ap.add_argument("--gemm-backend", default=None,
                    choices=gemm_api.list_backends(),
                    help="GEMM backend the cells plan against")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s) for a, s, _skip in model_zoo.cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = (["single", "multi"] if args.mesh == "both"
              else [args.mesh])

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape_name in cells:
        for mesh_name in meshes:
            tag = f"{arch}__{shape_name}__{mesh_name}" \
                  + ("" if not args.raw else "__raw")
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        continue
            try:
                rec = run_cell(arch, shape_name, mesh_name,
                               packed=not args.raw,
                               microbatch_per_device=args.microbatch,
                               gemm_backend=args.gemm_backend)
            except Exception as e:                      # noqa: BLE001
                failures += 1
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": mesh_name, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"FAIL {tag}: {rec['error']}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            jax.clear_caches()          # bound compile-cache memory
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
