"""Trace summarizer: ``python -m repro.launch.trace_report TRACE.json``.

Reduces a trace written by ``launch/serve --trace-out`` (or any
``obs.Tracer.export_chrome_trace`` output) to the paper-style per-shape
GEMM characterization: one row per (m, n, k, weight_format) with the
dispatch count, lever mix, split-K settings, median achieved GFLOPS and
median fraction-of-roofline — the §4 table shape, produced from live
serving traffic instead of a dedicated benchmark run.

``apportioned`` counts samples whose duration is share-attributed from
a tick span via the step's GEMM manifest rather than directly measured
(the jitted serving path — see docs/observability.md); rows where it
equals ``dispatches`` carry no wall-clock measurement of their own and
their GFLOPS column derives entirely from the apportionment.

Also prints a span census (event counts and total self time by span
name) with ``--spans``, and writes the table as JSON with ``--json``.
"""
from __future__ import annotations

import argparse
import json

from repro.obs import report as _report
from repro.obs import spans as _spans


def _span_census(trace: dict) -> list[dict]:
    agg: dict[str, dict] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") not in ("X", "i") or "name" not in ev:
            continue
        g = agg.setdefault(ev["name"], {"count": 0, "total_ms": 0.0})
        g["count"] += 1
        g["total_ms"] += ev.get("dur", 0.0) / 1e3
    return [{"name": n, **v} for n, v in
            sorted(agg.items(), key=lambda kv: -kv[1]["total_ms"])]


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="summarize a --trace-out trace into the per-shape "
                    "GEMM table")
    ap.add_argument("trace", help="Chrome-trace JSON from --trace-out")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the table rows as JSON")
    ap.add_argument("--spans", action="store_true",
                    help="print a span census (count + total ms by name)")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        trace = json.load(f)
    problems = _spans.validate_chrome_trace(trace)
    if problems:
        print(f"WARNING: trace has {len(problems)} schema problems "
              f"(first: {problems[0]})")

    rows = _report.per_shape_table(trace)
    n_ev = len(trace.get("traceEvents", []))
    fr = trace.get("flightRecorder") or []
    mani = trace.get("gemmManifests") or {}
    print(f"{args.trace}: {n_ev} events, {len(fr)} flight-recorder "
          f"records, {len(mani)} step manifests "
          f"({sum(len(v) for v in mani.values())} manifest plans)")
    print()
    print("per-shape GEMM characterization "
          "(medians; apportioned = share-attributed, not measured):")
    print(_report.format_table(rows))

    if args.spans:
        print()
        print("span census:")
        for r in _span_census(trace):
            print(f"  {r['name']:<24} x{r['count']:<7} "
                  f"{r['total_ms']:10.2f} ms total")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"table": rows, "events": n_ev,
                       "flight_records": len(fr),
                       "manifest_steps": len(mani)}, f, indent=1)
        print(f"\ntable rows -> {args.json}")
    return rows


if __name__ == "__main__":
    main()
