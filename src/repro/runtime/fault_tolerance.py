"""Fault tolerance: graceful shutdown, straggler watchdog, elastic resume.

At 1000+ nodes the failure model is: (a) preemption signals (SIGTERM with
a grace window), (b) silent stragglers (one slow host stalls every
collective), (c) full job restarts onto a possibly different topology.
The pieces here map one-to-one:

  GracefulShutdown  — SIGTERM/SIGINT → flag; train loop checkpoints and
                      exits inside the grace window.
  StepWatchdog      — per-step wall-time EMA; a step > ``factor``× the EMA
                      is a straggler event.  On a real cluster the
                      escalation callback triggers host cordon + elastic
                      restart; here it logs and counts (tested by
                      injecting delays).
  resume_or_init    — newest complete checkpoint wins; elastic because
                      restore() reshards onto the *current* mesh's
                      shardings (checkpoints store unsharded leaves and
                      mesh-agnostic logical specs — parallel/sharding
                      refits them to any divisible topology).

The serving stack wires the first two in as well (the failure model in
docs/serving.md): ``ContinuousBatchingScheduler(watchdog_factor=...)``
arms a StepWatchdog over scheduler ticks and surfaces its events as
``ServeStats.stragglers``, and ``launch/serve`` installs a
GracefulShutdown around the serve loop — SIGTERM drains in-flight
requests to completion, cancels the queue with structured outcomes,
and still persists the plan store on exit.  Deterministic fault
*injection* (the chaos-testing side) lives in runtime/faults.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

import jax


class GracefulShutdown:
    """SIGTERM/SIGINT handler: sets ``requested``; second signal raises."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def install(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        if self.requested:                      # second signal: hard exit
            raise KeyboardInterrupt
        self.requested = True

    def uninstall(self):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        self._prev.clear()


@dataclasses.dataclass
class StragglerEvent:
    step: int
    dt: float
    ema: float


class StepWatchdog:
    """EMA step-time monitor with straggler escalation.

    ``factor``: a step slower than factor × EMA is flagged.  ``warmup``
    steps are observed but never flagged (compile + cache warmup).
    """

    def __init__(self, *, factor: float = 3.0, decay: float = 0.9,
                 warmup: int = 2,
                 on_straggler: Callable[[StragglerEvent], None] | None = None):
        self.factor = factor
        self.decay = decay
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.ema: float | None = None
        self.count = 0
        self.events: list[StragglerEvent] = []

    def record(self, dt: float) -> bool:
        self.count += 1
        is_straggler = False
        if self.ema is not None and self.count > self.warmup \
                and dt > self.factor * self.ema:
            ev = StragglerEvent(self.count, dt, self.ema)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            is_straggler = True
            # don't poison the EMA with the straggler sample
            return True
        self.ema = dt if self.ema is None else (
            self.decay * self.ema + (1 - self.decay) * dt)
        return is_straggler


def resume_or_init(mgr, init_fn: Callable, like, *, shardings=None):
    """Restore the newest checkpoint or build fresh state.

    Returns (state, start_step).  ``like``: abstract state matching the
    checkpoint tree; ``shardings``: target placement on the CURRENT mesh
    (elastic restore path).
    """
    step = mgr.latest_step()
    if step is None:
        state = init_fn()
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state, 0
    state, meta = mgr.restore(step, like, shardings=shardings)
    return state, int(meta.get("step", step))


def wall_time() -> float:
    return time.perf_counter()
