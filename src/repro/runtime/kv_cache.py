"""Paged KV cache: physical page pool + host-side slot allocator.

The continuous-batching pool (runtime/batching.py) keeps a *static* slot
batch alive across requests; what changes per request is only which KV
storage a slot reads and writes.  A dense ``[slots, max_len]`` cache
would force the refill path to re-zero (or worse, re-allocate) a full
row per admitted request.  Instead the cache is paged, vLLM-style:

  * the device holds one physical pool per cached tensor,
    ``[layers, num_pages, page_size, *feat]``;
  * each slot owns an ordered list of page ids — its *page table* row —
    mapping logical token position ``p`` to physical location
    ``(table[p // page_size], p % page_size)``;
  * finishing a request returns its pages to the free list, and the next
    admitted request reuses them — no allocation, no recompile, no shape
    change anywhere on the device.

Numerics contract: ``paged_gather`` reconstructs the *logical-order*
dense view ``[slots, max_len, *feat]``, so attention over a paged cache
is bit-identical to attention over the dense cache it replaces (asserted
by tests/test_kv_cache.py on random alloc/free/refill traces, including
the wrap case where a long-lived slot outlives several neighbors).

Allocation is host-side (numpy + a free list): the scheduler calls
``alloc``/``free`` between device steps, and ships ``page_table``/
``lens`` as small int32 arrays into the jitted step — values change,
shapes never do.
"""
from __future__ import annotations

import collections

import jax.numpy as jnp
import numpy as np

PAGE_FREE = -1


class OutOfPagesError(RuntimeError):
    """The free list cannot cover a requested allocation."""


class PageAliasError(RuntimeError):
    """A physical page is referenced by two live slots (or a live slot
    and the free list) — the invariant continuous batching must never
    break."""


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages covering ``tokens`` token positions."""
    return -(-int(tokens) // page_size) if tokens > 0 else 0


def leaf_specs_for(cfg) -> dict:
    """Per-token cached tensors for ``cfg`` as ``{name: (feat, dtype)}``.

    Only the full-cache GQA layout is paged today; window (ring) caches
    and SSM state are per-slot *constant-size* state with no paging win,
    and MLA's latent cache is a straightforward extension left until an
    MLA arch enters the serving matrix.
    """
    if cfg.attention_kind != "gqa" or cfg.resolved_cache_kind != "full":
        raise NotImplementedError(
            f"paged KV cache supports full-cache GQA archs; got "
            f"attention_kind={cfg.attention_kind!r} / "
            f"cache={cfg.resolved_cache_kind!r}")
    dt = jnp.dtype(cfg.cache_dtype)
    feat = (cfg.num_kv_heads, cfg.head_dim)
    return {"pages_k": (feat, dt), "pages_v": (feat, dt)}


# ------------------------------------------------------- device-side ops
def paged_gather(pages: jnp.ndarray, page_table: jnp.ndarray,
                 page_size: int) -> jnp.ndarray:
    """Dense logical view of a paged pool.

    pages: [P, page_size, *feat]; page_table: [B, n_view] int32 physical
    ids (-1 = unmapped).  Returns [B, n_view * page_size, *feat]; the
    contents of unmapped pages are arbitrary (physical page 0) — callers
    mask them by position, exactly as the dense cache masks its
    zero-initialized tail.
    """
    p_phys = pages.shape[0]
    feat = pages.shape[2:]
    b, n_view = page_table.shape
    flat = pages.reshape(p_phys * page_size, *feat)
    base = jnp.where(page_table >= 0, page_table, 0) * page_size
    idx = base[:, :, None] + jnp.arange(page_size)[None, None, :]
    return flat[idx.reshape(b, n_view * page_size)]


def paged_update(pages: jnp.ndarray, new: jnp.ndarray,
                 page_table: jnp.ndarray, lens: jnp.ndarray,
                 page_size: int, write_mask=None) -> jnp.ndarray:
    """Scatter ``new[b, i]`` to logical position ``lens[b] + i`` of slot b.

    pages: [P, page_size, *feat]; new: [B, s, *feat]; lens: [B] int32;
    write_mask: optional [B] bool — rows with False (slots that are
    admitted but not decoding this step, or idle) write nothing.  Writes
    through unmapped table entries (-1) or past the table end are
    dropped, so chunk padding rows and masked slots can never touch a
    freed or foreign page.
    """
    p_phys = pages.shape[0]
    feat = pages.shape[2:]
    b, s = new.shape[0], new.shape[1]
    n_view = page_table.shape[1]
    pos = lens[:, None] + jnp.arange(s)[None, :]              # [B, s]
    page_idx = pos // page_size
    phys = jnp.take_along_axis(page_table,
                               jnp.clip(page_idx, 0, n_view - 1), axis=1)
    valid = (phys >= 0) & (page_idx < n_view)
    if write_mask is not None:
        valid &= write_mask[:, None]
    oob = p_phys * page_size                                  # drop sentinel
    flat_idx = jnp.where(valid, phys * page_size + pos % page_size, oob)
    flat = pages.reshape(p_phys * page_size, *feat)
    flat = flat.at[flat_idx.reshape(b * s)].set(
        new.reshape(b * s, *feat).astype(flat.dtype), mode="drop")
    return flat.reshape(pages.shape)


# ------------------------------------------------------ host-side pool
class PagedKVCache:
    """Physical page pool + per-slot page tables and length counters.

    The device arrays in ``self.pages`` are *threaded* through the jitted
    serving steps (donated and replaced each call); ``page_table`` /
    ``lens`` live here as numpy and are shipped per call via
    ``table_device()`` / ``lens_device()``.
    """

    def __init__(self, *, num_layers: int, num_slots: int, max_len: int,
                 page_size: int, leaf_specs: dict, num_pages: int | None = None):
        if max_len % page_size:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"page_size={page_size} so the gathered view "
                             f"matches the dense cache length exactly")
        self.num_slots = num_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = max_len // page_size
        self.num_pages = (num_pages if num_pages is not None
                          else num_slots * self.pages_per_slot)
        self.pages = {
            name: jnp.zeros((num_layers, self.num_pages, page_size, *feat),
                            dtype)
            for name, (feat, dtype) in leaf_specs.items()}
        self.page_table = np.full((num_slots, self.pages_per_slot),
                                  PAGE_FREE, np.int32)
        self.lens = np.zeros((num_slots,), np.int32)
        self._n_pages = np.zeros((num_slots,), np.int32)
        self._free: collections.deque[int] = collections.deque(
            range(self.num_pages))

    # ------------------------------------------------------- allocation
    @property
    def free_count(self) -> int:
        return len(self._free)

    def held(self, slot: int) -> int:
        """Pages currently mapped by ``slot``."""
        return int(self._n_pages[slot])

    def alloc(self, slot: int, token_len: int) -> None:
        """Grow ``slot``'s mapping to cover ``token_len`` logical tokens."""
        target = pages_for(token_len, self.page_size)
        if target > self.pages_per_slot:
            raise ValueError(f"slot {slot}: {token_len} tokens exceed "
                             f"max_len={self.max_len}")
        while self._n_pages[slot] < target:
            if not self._free:
                raise OutOfPagesError(
                    f"slot {slot} needs page {int(self._n_pages[slot])} "
                    f"but the free list is empty "
                    f"({self.num_pages} pages total)")
            self.page_table[slot, self._n_pages[slot]] = self._free.popleft()
            self._n_pages[slot] += 1

    def free(self, slot: int) -> list[int]:
        """Release every page of ``slot``; returns the freed ids."""
        n = int(self._n_pages[slot])
        freed = [int(p) for p in self.page_table[slot, :n]]
        self.page_table[slot, :] = PAGE_FREE
        self._n_pages[slot] = 0
        self.lens[slot] = 0
        self._free.extend(freed)
        return freed

    def reset(self) -> None:
        for s in range(self.num_slots):
            if self._n_pages[s]:
                self.free(s)
        self.lens[:] = 0

    # -------------------------------------------------- device shipping
    def table_device(self, slots=None) -> jnp.ndarray:
        t = self.page_table if slots is None else self.page_table[slots]
        return jnp.asarray(t)

    def lens_device(self, slots=None) -> jnp.ndarray:
        l = self.lens if slots is None else self.lens[slots]
        return jnp.asarray(l)

    # ---------------------------------------------------- invariants
    def check_no_aliasing(self) -> None:
        """Raise PageAliasError unless live mappings and the free list
        partition the physical pool (no page in two rows, none both live
        and free, none leaked)."""
        live = [int(p) for row in self.page_table for p in row if p >= 0]
        if len(live) != len(set(live)):
            dup = sorted(p for p in set(live) if live.count(p) > 1)
            raise PageAliasError(f"pages {dup} mapped by two live slots")
        overlap = set(live) & set(self._free)
        if overlap:
            raise PageAliasError(
                f"pages {sorted(overlap)} both live and free")
        if len(live) + len(self._free) != self.num_pages:
            raise PageAliasError(
                f"page leak: {len(live)} live + {len(self._free)} free "
                f"!= {self.num_pages} total")
