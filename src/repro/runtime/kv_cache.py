"""Paged KV cache: physical page pool + host-side slot allocator.

The continuous-batching pool (runtime/batching.py) keeps a *static* slot
batch alive across requests; what changes per request is only which KV
storage a slot reads and writes.  A dense ``[slots, max_len]`` cache
would force the refill path to re-zero (or worse, re-allocate) a full
row per admitted request.  Instead the cache is paged, vLLM-style:

  * the device holds one physical pool per cached tensor,
    ``[layers, num_pages, page_size, *feat]``;
  * each slot owns an ordered list of page ids — its *page table* row —
    mapping logical token position ``p`` to physical location
    ``(table[p // page_size], p % page_size)``;
  * finishing a request returns its pages to the free list, and the next
    admitted request reuses them — no allocation, no recompile, no shape
    change anywhere on the device.

Numerics contract: ``paged_gather`` reconstructs the *logical-order*
dense view ``[slots, max_len, *feat]``, so attention over a paged cache
is bit-identical to attention over the dense cache it replaces (asserted
by tests/test_kv_cache.py on random alloc/free/refill traces, including
the wrap case where a long-lived slot outlives several neighbors).

Allocation is host-side (numpy + a free list): the scheduler calls
``alloc``/``free`` between device steps, and ships ``page_table``/
``lens`` as small int32 arrays into the jitted step — values change,
shapes never do.

Pages are REFCOUNTED so the cross-request prefix cache
(runtime/prefix_cache.py) can back many slots' tables with one physical
page: ``install`` maps already-written pages into a fresh slot
(incrementing their refcounts), ``fork`` is the copy-on-write escape —
a fresh page whose contents are copied from a shared one, so the new
slot can overwrite its tail without touching the original — and
``free`` only *decrements*; a page returns to the free list when its
last reference drops AND it is not registered as cached.  Cached pages
with refcount 0 are *reclaimable*: under pool pressure ``alloc``/
``fork`` call the registered evictor (the prefix cache's LRU sweep)
before declaring OutOfPages.  ``check_no_aliasing`` is refcount-aware
(a page in two live tables is legal exactly when its refcount says so)
and ``assert_all_free`` is the teardown leak audit: once every request
has been freed, every page must be free or cached-idle — a refcount
that never returned to zero is a leak the old free-list accounting
could not see.
"""
from __future__ import annotations

import collections

import jax.numpy as jnp
import numpy as np

from repro.runtime import faults as _faults

PAGE_FREE = -1


class OutOfPagesError(RuntimeError):
    """The free list cannot cover a requested allocation."""


class PageAliasError(RuntimeError):
    """A physical page's references disagree with its refcount (or a
    page is both live and free) — the invariant continuous batching
    must never break."""


class PageLeakError(PageAliasError):
    """A page kept a nonzero refcount (or a slot kept a mapping) after
    every request was freed — the silent leak ``assert_all_free``
    audits for at scheduler teardown."""


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages covering ``tokens`` token positions."""
    return -(-int(tokens) // page_size) if tokens > 0 else 0


def leaf_specs_for(cfg) -> dict:
    """Per-token cached tensors for ``cfg`` as ``{name: (feat, dtype)}``.

    Only the full-cache GQA layout is paged today; window (ring) caches
    and SSM state are per-slot *constant-size* state with no paging win,
    and MLA's latent cache is a straightforward extension left until an
    MLA arch enters the serving matrix.
    """
    if cfg.attention_kind != "gqa" or cfg.resolved_cache_kind != "full":
        raise NotImplementedError(
            f"paged KV cache supports full-cache GQA archs; got "
            f"attention_kind={cfg.attention_kind!r} / "
            f"cache={cfg.resolved_cache_kind!r}")
    dt = jnp.dtype(cfg.cache_dtype)
    feat = (cfg.num_kv_heads, cfg.head_dim)
    return {"pages_k": (feat, dt), "pages_v": (feat, dt)}


# ------------------------------------------------------- device-side ops
def paged_gather(pages: jnp.ndarray, page_table: jnp.ndarray,
                 page_size: int) -> jnp.ndarray:
    """Dense logical view of a paged pool.

    pages: [P, page_size, *feat]; page_table: [B, n_view] int32 physical
    ids (-1 = unmapped).  Returns [B, n_view * page_size, *feat]; the
    contents of unmapped pages are arbitrary (physical page 0) — callers
    mask them by position, exactly as the dense cache masks its
    zero-initialized tail.
    """
    p_phys = pages.shape[0]
    feat = pages.shape[2:]
    b, n_view = page_table.shape
    flat = pages.reshape(p_phys * page_size, *feat)
    base = jnp.where(page_table >= 0, page_table, 0) * page_size
    idx = base[:, :, None] + jnp.arange(page_size)[None, None, :]
    return flat[idx.reshape(b, n_view * page_size)]


def paged_update(pages: jnp.ndarray, new: jnp.ndarray,
                 page_table: jnp.ndarray, lens: jnp.ndarray,
                 page_size: int, write_mask=None) -> jnp.ndarray:
    """Scatter ``new[b, i]`` to logical position ``lens[b] + i`` of slot b.

    pages: [P, page_size, *feat]; new: [B, s, *feat]; lens: [B] int32;
    write_mask: optional [B] bool — rows with False (slots that are
    admitted but not decoding this step, or idle) write nothing.  Writes
    through unmapped table entries (-1) or past the table end are
    dropped, so chunk padding rows and masked slots can never touch a
    freed or foreign page.
    """
    p_phys = pages.shape[0]
    feat = pages.shape[2:]
    b, s = new.shape[0], new.shape[1]
    n_view = page_table.shape[1]
    pos = lens[:, None] + jnp.arange(s)[None, :]              # [B, s]
    page_idx = pos // page_size
    phys = jnp.take_along_axis(page_table,
                               jnp.clip(page_idx, 0, n_view - 1), axis=1)
    valid = (phys >= 0) & (page_idx < n_view)
    if write_mask is not None:
        valid &= write_mask[:, None]
    oob = p_phys * page_size                                  # drop sentinel
    flat_idx = jnp.where(valid, phys * page_size + pos % page_size, oob)
    flat = pages.reshape(p_phys * page_size, *feat)
    flat = flat.at[flat_idx.reshape(b * s)].set(
        new.reshape(b * s, *feat).astype(flat.dtype), mode="drop")
    return flat.reshape(pages.shape)


# ------------------------------------------------------ host-side pool
class PagedKVCache:
    """Physical page pool + per-slot page tables and length counters.

    The device arrays in ``self.pages`` are *threaded* through the jitted
    serving steps (donated and replaced each call); ``page_table`` /
    ``lens`` live here as numpy and are shipped per call via
    ``table_device()`` / ``lens_device()``.
    """

    def __init__(self, *, num_layers: int, num_slots: int, max_len: int,
                 page_size: int, leaf_specs: dict, num_pages: int | None = None):
        if max_len % page_size:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"page_size={page_size} so the gathered view "
                             f"matches the dense cache length exactly")
        self.num_slots = num_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = max_len // page_size
        self.num_pages = (num_pages if num_pages is not None
                          else num_slots * self.pages_per_slot)
        self.pages = {
            name: jnp.zeros((num_layers, self.num_pages, page_size, *feat),
                            dtype)
            for name, (feat, dtype) in leaf_specs.items()}
        self.page_table = np.full((num_slots, self.pages_per_slot),
                                  PAGE_FREE, np.int32)
        self.lens = np.zeros((num_slots,), np.int32)
        self._n_pages = np.zeros((num_slots,), np.int32)
        self._free: collections.deque[int] = collections.deque(
            range(self.num_pages))
        # prefix-cache support: per-page reference counts (slot-table
        # references only — the cache index itself holds none, which is
        # what makes refcount-0 cached pages the reclaimable set), the
        # cached-page registry, and the pressure evictor hook
        self.refcount = np.zeros((self.num_pages,), np.int32)
        self._cached: set[int] = set()
        self._evictor = None

    # ------------------------------------------------------- allocation
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def cached_count(self) -> int:
        """Pages registered by a prefix cache (live or idle)."""
        return len(self._cached)

    def reclaimable_count(self, exclude=()) -> int:
        """Cached pages with refcount 0 — what the evictor could return
        to the free list under pressure.  ``exclude`` discounts pages a
        caller is about to pin (an admission's own prefix hit must not
        count toward the budget that admits it)."""
        skip = set(int(p) for p in exclude)
        return sum(1 for p in self._cached
                   if self.refcount[p] == 0 and p not in skip)

    def set_evictor(self, fn) -> None:
        """Register ``fn(n_pages) -> freed`` called under pool pressure
        before OutOfPagesError; the prefix cache's LRU sweep."""
        self._evictor = fn

    def held(self, slot: int) -> int:
        """Pages currently mapped by ``slot``."""
        return int(self._n_pages[slot])

    def _take_free(self, why: str) -> int:
        """Pop a free page (evicting reclaimable cached pages first under
        pressure); the caller owns its single reference."""
        # chaos injection point: an injected OOM fires before any state
        # changes, so the scheduler's quarantine sees a consistent pool
        _faults.maybe_fire("alloc_oom", why=why)
        if not self._free and self._evictor is not None:
            self._evictor(1)
        if not self._free:
            raise OutOfPagesError(
                f"{why} but the free list is empty "
                f"({self.num_pages} pages total, "
                f"{self.cached_count} cached)")
        p = self._free.popleft()
        self.refcount[p] = 1
        return p

    def _release(self, p: int) -> bool:
        """Drop one reference to ``p``; True if it returned to the free
        list (last reference gone and not retained by the cache)."""
        if self.refcount[p] <= 0:
            raise PageAliasError(f"double free of page {p}")
        self.refcount[p] -= 1
        if self.refcount[p] == 0 and p not in self._cached:
            self._free.append(p)
            return True
        return False

    def alloc(self, slot: int, token_len: int) -> None:
        """Grow ``slot``'s mapping to cover ``token_len`` logical tokens."""
        target = pages_for(token_len, self.page_size)
        if target > self.pages_per_slot:
            raise ValueError(f"slot {slot}: {token_len} tokens exceed "
                             f"max_len={self.max_len}")
        while self._n_pages[slot] < target:
            p = self._take_free(
                f"slot {slot} needs page {int(self._n_pages[slot])}")
            self.page_table[slot, self._n_pages[slot]] = p
            self._n_pages[slot] += 1

    def install(self, slot: int, pages) -> None:
        """Map already-written ``pages`` (a cached prefix run, in logical
        order) as the head of ``slot``'s table, taking one reference
        each.  The slot must hold no mapping yet — prefix installation
        happens at admission, before any alloc."""
        if self._n_pages[slot]:
            raise PageAliasError(
                f"install into slot {slot} which already maps "
                f"{self.held(slot)} pages")
        if len(pages) > self.pages_per_slot:
            raise ValueError(f"slot {slot}: {len(pages)} shared pages "
                             f"exceed max_len={self.max_len}")
        for j, p in enumerate(pages):
            p = int(p)
            if self.refcount[p] == 0 and p not in self._cached:
                raise PageAliasError(
                    f"install of page {p} which is neither live nor "
                    f"cached (would alias the free list)")
            self.refcount[p] += 1
            self.page_table[slot, j] = p
        self._n_pages[slot] = len(pages)

    def fork(self, slot: int, src_page: int) -> int:
        """Copy-on-write: map a FRESH page as ``slot``'s next table entry
        with the contents of ``src_page`` copied in (device-side, every
        leaf pool), so the slot can overwrite the copied tail without
        touching the shared original.  Returns the new physical id."""
        j = int(self._n_pages[slot])
        if j >= self.pages_per_slot:
            raise ValueError(f"slot {slot}: fork past max_len")
        # pin the source across the take: under pressure the evictor
        # could otherwise reclaim src itself and hand it back as dst
        self.refcount[src_page] += 1
        try:
            dst = self._take_free(f"slot {slot} forking page {src_page}")
        finally:
            self._release(int(src_page))
        for name, arr in self.pages.items():
            self.pages[name] = arr.at[:, dst].set(arr[:, src_page])
        self.page_table[slot, j] = dst
        self._n_pages[slot] = j + 1
        return dst

    def free(self, slot: int) -> list[int]:
        """Release every reference of ``slot``; returns the ids that
        actually came back to the free list (shared pages survive with
        the remaining holders; cached pages are retained reclaimable)."""
        n = int(self._n_pages[slot])
        freed = [p for p in map(int, self.page_table[slot, :n])
                 if self._release(p)]
        self.page_table[slot, :] = PAGE_FREE
        self._n_pages[slot] = 0
        self.lens[slot] = 0
        return freed

    # ----------------------------------------- prefix-cache page registry
    def mark_cached(self, pages) -> None:
        """Register ``pages`` as retained by the prefix index: their last
        ``free`` keeps them out of the free list (reclaimable by the
        evictor instead of recycled)."""
        for p in pages:
            p = int(p)
            if self.refcount[p] == 0 and p not in self._cached:
                raise PageAliasError(
                    f"mark_cached on free page {p}")
            self._cached.add(p)

    def uncache(self, pages) -> list[int]:
        """Drop ``pages`` from the cached registry (eviction / index
        clear); idle ones return to the free list immediately."""
        freed = []
        for p in pages:
            p = int(p)
            if p in self._cached:
                self._cached.discard(p)
                if self.refcount[p] == 0:
                    self._free.append(p)
                    freed.append(p)
        return freed

    def reset(self) -> None:
        """Full pool reset: every slot freed AND the cached registry
        dropped (a prefix index over this pool must be discarded with
        it)."""
        for s in range(self.num_slots):
            if self._n_pages[s]:
                self.free(s)
        self.lens[:] = 0
        self.uncache(list(self._cached))

    # -------------------------------------------------- device shipping
    def table_device(self, slots=None) -> jnp.ndarray:
        t = self.page_table if slots is None else self.page_table[slots]
        return jnp.asarray(t)

    def lens_device(self, slots=None) -> jnp.ndarray:
        l = self.lens if slots is None else self.lens[slots]
        return jnp.asarray(l)

    # ---------------------------------------------------- invariants
    def check_no_aliasing(self) -> None:
        """Raise PageAliasError unless table references, refcounts, the
        cached registry and the free list are mutually consistent:
        every page's refcount equals its table references (sharing is
        legal exactly when the refcount says so), the free list holds
        no duplicates and no referenced or cached page, and
        free + live + cached-idle partitions the physical pool."""
        refs = np.zeros((self.num_pages,), np.int64)
        for row in self.page_table:
            for p in row:
                if p >= 0:
                    refs[p] += 1
        bad = np.flatnonzero(refs != self.refcount)
        if bad.size:
            detail = ", ".join(
                f"page {p}: {refs[p]} table refs vs refcount "
                f"{int(self.refcount[p])}" for p in bad[:4])
            raise PageAliasError(f"refcount mismatch ({detail})")
        free = list(self._free)
        if len(free) != len(set(free)):
            dup = sorted(p for p in set(free) if free.count(p) > 1)
            raise PageAliasError(f"pages {dup} twice on the free list")
        overlap = set(free) & set(np.flatnonzero(refs > 0).tolist())
        if overlap:
            raise PageAliasError(
                f"pages {sorted(overlap)} both live and free")
        overlap = set(free) & self._cached
        if overlap:
            raise PageAliasError(
                f"pages {sorted(overlap)} both cached and free")
        live = int(np.count_nonzero(refs > 0))
        idle_cached = sum(1 for p in self._cached if refs[p] == 0)
        if live + idle_cached + len(free) != self.num_pages:
            raise PageAliasError(
                f"page leak: {live} live + {idle_cached} cached-idle "
                f"+ {len(free)} free != {self.num_pages} total")

    def assert_all_free(self) -> None:
        """Teardown leak audit: with no request live, every page must be
        free or cached-idle.  A nonzero refcount here is the silent
        leak the plain free-list accounting missed when a request was
        freed while its pages were shared (raises PageLeakError)."""
        self.check_no_aliasing()
        held = np.flatnonzero(self._n_pages > 0)
        if held.size:
            raise PageLeakError(
                f"slots {held.tolist()} still hold mappings at teardown")
        live = np.flatnonzero(self.refcount > 0)
        if live.size:
            raise PageLeakError(
                f"pages {live.tolist()} kept nonzero refcounts at "
                f"teardown — a free() path dropped a reference short")
        if len(self._free) + len(self._cached) != self.num_pages:
            raise PageLeakError(
                f"{len(self._free)} free + {len(self._cached)} cached "
                f"!= {self.num_pages} pages at teardown")
