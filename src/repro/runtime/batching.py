"""Continuous batching: slot-refill scheduler + chunked prefill admission.

The paper's throughput discipline — pack once at load, keep every
compute block busy with fine panels each step — dies in a serving loop
that phase-locks a static batch: one slow request holds ``batch_slots``
slots hostage for ``max_new_tokens`` steps.  This module replaces that
loop with a real scheduler over a *static-shape* slot pool:

  * **Slot refill mid-generation.**  Requests queue FIFO; a slot whose
    request finishes is freed and refilled immediately.  Shapes never
    change — the decode step is always ``[batch_slots, 1]`` with a
    per-slot length vector and write mask — so nothing recompiles and no
    GEMM replans (``plan_cache_info().misses`` is flat in steady state).
  * **Paged KV** (runtime/kv_cache): a refilled slot reuses the pages its
    predecessor freed instead of re-allocating ``[B, max_len]``.
  * **Chunked prefill admission.**  New prompts prefill in fixed-width
    chunks (padded to a ``gemm.bucket_m`` bucket) interleaved with decode
    steps, so admission never stalls decode for a whole prompt and the
    K>=N fine-panel plans stay hot across both phases.

Scheduling is host-side and deliberately simple: per tick, (1) enforce
deadlines/cancellations, (2) admit from the queue into idle slots while
the page budget holds, (3) run one prefill chunk for the
earliest-admitted prefilling slot, (4) run one decode step for every
decoding slot.  The device work is the Engine's jitted
``prefill_chunk`` / ``decode_step``; this module never traces.

**Fault isolation** (the serving analogue of the paper's guarantee
discipline): every request carries a lifecycle state
(``RequestState``: QUEUED/RUNNING/DONE/FAILED/CANCELLED/TIMED_OUT) and
a structured :class:`RequestOutcome`; a fault is confined to the
requests it actually hits.  A dispatch exception walks a degradation
ladder — retry once on the engine's backend, then one attempt on the
``xla`` fallback backend (bit-exact, because every registered backend
passes the same gate) — and only then quarantines the victim: the
poisoned request's pages are freed (refcounts keep shared prefix pages
safe), its slot is recycled, and the batch continues.  Because batched
greedy decode is row-independent (each slot attends only to its own
pages and masked rows write nothing), **survivors stay bit-identical
to a fault-free run** — the gate ``tests/test_chaos.py`` and
``benchmarks/chaos_serving.py`` enforce under injected faults
(runtime/faults).

Outputs are bit-identical to per-request greedy ``Engine.generate`` —
the serving analogue of the paper's bit-exactness gate, enforced by
tests/test_serving.py.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import gemm as gemm_api
from repro.obs import metrics as _metrics
from repro.obs import spans as _spans
from repro.obs.timing import FencedTimer
from repro.runtime import fault_tolerance as FT
from repro.runtime import faults
from repro.runtime import kv_cache as KV
from repro.runtime.prefix_cache import PrefixCache, PrefixCacheStats


# --------------------------------------------------------------- lifecycle
class RequestState(str, enum.Enum):
    """Per-request lifecycle.  Terminal states other than DONE carry a
    structured reason in the request's :class:`RequestOutcome`."""
    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELLED = "CANCELLED"
    TIMED_OUT = "TIMED_OUT"


@dataclasses.dataclass
class RequestOutcome:
    """The structured per-request result record — every submitted
    request ends in exactly one of these, fault or not.

    ``tokens``: the full output for DONE requests; for evicted requests
    the tokens emitted before the fault (None if none).  ``error`` /
    ``error_type`` describe the terminal reason for non-DONE states."""
    rid: int
    state: RequestState
    prompt_len: int
    emitted: int = 0
    tokens: np.ndarray | None = None
    error: str | None = None
    error_type: str | None = None


class RejectedError(RuntimeError):
    """Admission refused (bounded queue overflow, or shutdown drain).
    ``snapshot`` carries the queue/slot/page-pool state at rejection —
    the backpressure signal a front-end load-sheds on."""

    def __init__(self, msg: str, *, snapshot: dict):
        super().__init__(msg)
        self.snapshot = snapshot


class SchedulerStallError(RuntimeError):
    """The tick loop exhausted its progress bound — a scheduler bug,
    not load.  ``snapshot`` carries the queue/slot/page-pool state so
    the stall is diagnosable instead of a bare "no progress"."""

    def __init__(self, msg: str, *, snapshot: dict):
        super().__init__(f"{msg}; state: {snapshot}")
        self.snapshot = snapshot


# ------------------------------------------------------------------ stats
@dataclasses.dataclass
class RequestStats:
    """Per-request serving latency record (all seconds / tokens)."""
    rid: int
    prompt_len: int
    new_tokens: int
    queue_wait_s: float          # enqueue -> admitted to a slot
    ttft_s: float                # enqueue -> first token emitted
    total_s: float               # enqueue -> finished
    decode_tps: float            # new_tokens over first-token -> finish


@dataclasses.dataclass
class ServeStats:
    """Aggregate + per-request serving stats.

    Token counts follow the live-slot, non-pad discipline:
    ``prefill_tokens`` counts true prompt tokens actually COMPUTED
    (never chunk padding or dead slots — and never positions the
    prefix cache served from shared pages; those are in
    ``prefix.hit_tokens``); ``decode_tokens`` counts tokens actually
    emitted to a request (the first, prefill-sampled token included).

    ``prefix`` (``prefix_cache=True`` runs only) carries the
    cross-request prefix cache's hit/evict/COW counters
    (:class:`repro.runtime.prefix_cache.PrefixCacheStats`).

    GEMM-dispatch observability: ``plan_cache`` snapshots
    ``gemm.plan_cache_info()`` at run end (plan churn — misses moving in
    steady state means chunk bucketing broke) and ``vmem_clamped_plans``
    counts cached plans whose blocks the policy shrank to fit the
    kernel VMEM budget; ``plan_store`` snapshots the engine's persistent
    plan-store counters (``gemm.StoreInfo``; None when the engine runs
    without a store); ``quant`` is the engine's quantized weight
    format (None: fp32).

    Per-phase latency breakdown (the decode fast lane's observability):
    ``prefill_tick_ms`` / ``decode_tick_ms`` record every tick's
    dispatch duration (a megastep drain of D ticks contributes D
    entries of drain/D — under ``sync_per_step`` these are exact
    device times, under async they are dispatch times); query p50/p99
    via :meth:`phase_percentile`.  ``decode_dispatches`` counts device
    decode calls (``decode_ticks / decode_dispatches`` ~= the realized
    megastep depth), ``host_syncs`` counts the host-blocking
    synchronization points the run actually paid (every
    ``sync_per_step`` block + the final materialize) and
    ``megastep_depth`` echoes the configured D.

    Fault-isolation observability: ``outcomes`` maps rid to
    :class:`RequestOutcome` (every submitted request, terminal states
    included); ``dispatch_retries`` / ``backend_fallbacks`` count the
    degradation ladder's rungs; ``degraded`` counts graceful
    degradations by reason (e.g. ``prefix_lookup`` — a prefix-cache
    error served cold); ``stragglers`` holds the serving watchdog's
    :class:`~repro.runtime.fault_tolerance.StragglerEvent` records
    (``watchdog_factor`` runs only); ``trace_dropped`` counts audit-log
    events the bounded scheduler trace dropped (oldest first) once past
    its cap.
    """
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    wall_s: float = 0.0
    fused: bool | None = None       # engine ran the fused GEMM path
    quant: str | None = None        # engine's quantized weight format
    quant_density: float | None = None   # mean occupied-group fraction
    quant_sparse_packs: int = 0     # packs on the compressed layout
    plan_cache: tuple | None = None
    vmem_clamped_plans: int = 0
    plan_store: tuple | None = None
    requests: list[RequestStats] = dataclasses.field(default_factory=list)
    prefill_tick_ms: list = dataclasses.field(default_factory=list)
    decode_tick_ms: list = dataclasses.field(default_factory=list)
    decode_dispatches: int = 0
    host_syncs: int = 0
    megastep_depth: int = 1
    prefix: PrefixCacheStats | None = None
    outcomes: dict = dataclasses.field(default_factory=dict)
    dispatch_retries: int = 0
    backend_fallbacks: int = 0
    degraded: dict = dataclasses.field(default_factory=dict)
    stragglers: list = dataclasses.field(default_factory=list)
    trace_dropped: int = 0

    @property
    def prefill_tps(self):
        return self.prefill_tokens / max(self.prefill_s, 1e-9)

    @property
    def decode_tps(self):
        return self.decode_tokens / max(self.decode_s, 1e-9)

    @property
    def total_tps(self):
        """Emitted tokens over wall time — the cross-engine comparable."""
        return self.decode_tokens / max(self.wall_s, 1e-9)

    @property
    def decode_ticks(self) -> int:
        return len(self.decode_tick_ms)

    @property
    def completed(self) -> int:
        return sum(1 for o in self.outcomes.values()
                   if o.state == RequestState.DONE)

    @property
    def failed(self) -> int:
        """Requests in a terminal state other than DONE."""
        return sum(1 for o in self.outcomes.values()
                   if o.state not in (RequestState.DONE,
                                      RequestState.QUEUED,
                                      RequestState.RUNNING))

    def percentile(self, field: str, q: float) -> float:
        vals = [getattr(r, field) for r in self.requests]
        return float(np.percentile(vals, q)) if vals else 0.0

    def phase_percentile(self, phase: str, q: float) -> float:
        """Percentile (ms) over per-tick durations of ``phase``
        ("prefill" | "decode")."""
        vals = {"prefill": self.prefill_tick_ms,
                "decode": self.decode_tick_ms}[phase]
        return float(np.percentile(vals, q)) if vals else 0.0


class _BoundedTrace:
    """The scheduler's audit log, bounded (ISSUE 9 satellite: the bare
    ``list`` grew without limit — a long-lived scheduler leaked memory
    at one tuple per event forever).  Drops the OLDEST events past
    ``cap`` and counts them in ``dropped`` (surfaced as
    ``ServeStats.trace_dropped`` and the ``serve_trace_dropped``
    metric), so the recent window the invariant audits replay stays
    intact while the log stops growing.  The cap is deliberately far
    above any test run's event count — the audits see complete logs."""

    __slots__ = ("cap", "dropped", "_buf")

    def __init__(self, cap: int = 100_000):
        self.cap = cap
        self.dropped = 0
        self._buf: collections.deque[tuple] = collections.deque(maxlen=cap)

    def append(self, ev: tuple) -> None:
        if len(self._buf) == self.cap:
            self.dropped += 1
        self._buf.append(ev)

    def __iter__(self):
        return iter(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self._buf)[idx]
        return self._buf[idx]


@dataclasses.dataclass
class _Request:
    rid: int
    tokens: np.ndarray
    max_new: int
    t_enqueue: float
    t_admit: float = 0.0
    t_first: float | None = None
    ttft_budget_s: float | None = None
    total_budget_s: float | None = None
    cancel: bool = False


class _Slot:
    __slots__ = ("request", "n_prefilled", "n_emitted", "first_tok",
                 "steps", "order")

    def __init__(self):
        self.request: _Request | None = None
        self.n_prefilled = 0
        self.n_emitted = 0
        self.first_tok = None      # device scalar from the final chunk
        self.steps: list[int] = []  # indices into the decode history
        self.order = -1            # admission sequence number (FIFO tie)

    @property
    def prefill_done(self):
        return (self.request is not None
                and self.n_prefilled == len(self.request.tokens))


# -------------------------------------------------------------- scheduler
class ContinuousBatchingScheduler:
    """Drives an Engine's paged ``prefill_chunk`` / ``decode_step`` over a
    FIFO request queue with slot refill.

    ``engine`` needs: ``cfg``, ``max_len``, and the two paged step
    methods — the invariant tests drive the scheduler with a stub engine
    to cover thousands of schedules without tracing.  Engines exposing
    ``supports_fallback`` additionally accept ``fallback=True`` on the
    paged steps (the ``xla``-backend escape hatch the dispatch
    degradation ladder uses).

    ``num_pages`` below the dense-equivalent total turns on real paging
    pressure: admission then waits until finished requests return enough
    pages (the reservation check keeps the pool deadlock-free — a request
    is only admitted when its *whole* worst-case footprint fits alongside
    the outstanding growth of every live slot).

    The token feedback loop stays on device: completion is a *count*
    (max_new), never a token value, so the scheduler dispatches steps
    without a host sync and materializes outputs once at the end — the
    same async pipelining ``generate`` gets from its device-side loop.
    ``sync_per_step=True`` blocks after every device call instead, making
    the per-phase timings and TTFT exact (the launcher's percentile
    report uses it); under async they are dispatch-time measurements.

    ``megastep_depth`` (D > 1) drains decode through the engine's fused
    megastep: up to D decode ticks run device-side per host dispatch
    (``Engine.decode_megastep`` — one jitted ``lax.fori_loop`` carrying
    greedy argmax, paged KV writes and the next-token embed), and the
    scheduler drains the emitted tokens every D ticks.  The realized
    depth of each drain is ``min(D, smallest remaining token budget
    among decoding slots)``, so no slot ever over-generates: the event
    trace, exactly-once completion and ``serve == generate`` bitwise
    parity hold at every depth (each megastep tick is the same jitted
    computation as a per-tick dispatch).  The trade: admission and
    chunked prefill interleave only at drain boundaries, so deep
    megasteps buy dispatch amortization at some TTFT cost
    (docs/serving.md).

    ``prefix_cache=True`` turns on the cross-request prefix cache
    (runtime/prefix_cache): admission looks the prompt up in a radix
    index over the page pool, installs the matched pages into the
    slot's table by reference (COW-forking the divergence page), and
    starts chunked prefill at the first uncovered token; a prompt's
    full pages are indexed once its prefill completes, and the index's
    LRU sweep is the pool's pressure evictor.  The cache lives as long
    as this scheduler — ``run`` may be called repeatedly and later
    requests hit earlier runs' prefixes.  Outputs stay bit-identical
    to per-request ``generate`` (the cached KV is bitwise what this
    request's own prefill would have written).  A prefix-cache error
    (lookup/admit/insert) never fails the request: the scheduler
    degrades to cold prefill and counts the reason in
    ``ServeStats.degraded``.

    Fault-isolation knobs: ``max_queue`` bounds the admission queue
    (``submit`` past the bound raises :class:`RejectedError` with a
    state snapshot — load shedding); ``watchdog_factor`` arms a
    :class:`~repro.runtime.fault_tolerance.StepWatchdog` over scheduler
    ticks (straggler events land in ``ServeStats.stragglers``);
    ``shutdown`` takes an object with a ``requested`` flag (a
    ``GracefulShutdown``) — once set, queued requests are drained to
    CANCELLED("shutdown") outcomes, new submissions are rejected, and
    in-flight requests run to completion; ``clock`` injects a fake
    monotonic clock for deterministic deadline tests (device timing
    stats always use the real clock).  Per-request deadlines ride on
    ``submit(..., ttft_budget_s=, total_budget_s=)`` and are enforced
    at tick boundaries, as is cooperative :meth:`cancel`.

    ``trace`` records ``(event, ...)`` tuples — the scheduler's own audit
    log, asserted over by the serving invariant tests.  It is BOUNDED
    (:class:`_BoundedTrace`): past the cap the oldest events are dropped
    and counted (``ServeStats.trace_dropped``), so a long-lived
    scheduler never grows its log without limit.  ``run`` ends
    with the pool's ``assert_all_free`` leak audit — on the success
    path AND on every exception path (try/finally): with every request
    freed, a page refcount that never returned to zero (possible only
    through a sharing bug) raises instead of leaking silently.
    """

    def __init__(self, engine, *, batch_slots: int, prefill_chunk: int = 32,
                 page_size: int = 16, num_pages: int | None = None,
                 check_invariants: bool = False,
                 sync_per_step: bool = False, megastep_depth: int = 1,
                 prefix_cache: bool = False, max_queue: int | None = None,
                 watchdog_factor: float | None = None, shutdown=None,
                 clock=None):
        cfg = engine.cfg
        if cfg.modality != "text":
            raise NotImplementedError("continuous batching serves token "
                                      "prompts; stub-embedding frontends "
                                      "go through Engine.prefill")
        self.engine = engine
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.page_size = page_size
        # static admission width: pad to a plan bucket so every chunk in
        # a mixed-length stream resolves the same GEMM plan keys
        self.chunk = gemm_api.bucket_m(prefill_chunk)
        self.check_invariants = check_invariants
        self.sync_per_step = sync_per_step
        if megastep_depth < 1:
            raise ValueError(f"megastep_depth={megastep_depth}: need >= 1")
        if megastep_depth > 1 and not hasattr(engine, "decode_megastep"):
            raise ValueError("megastep_depth > 1 needs an engine with "
                             "decode_megastep (Engine, or a stub "
                             "providing it)")
        self.megastep_depth = megastep_depth
        self.max_queue = max_queue
        self.watchdog = (FT.StepWatchdog(factor=watchdog_factor)
                         if watchdog_factor else None)
        self._shutdown = shutdown
        self._draining = False
        self._clock = clock if clock is not None else time.perf_counter
        self.kv = KV.PagedKVCache(
            num_layers=cfg.num_layers, num_slots=batch_slots,
            max_len=engine.max_len, page_size=page_size,
            leaf_specs=KV.leaf_specs_for(cfg), num_pages=num_pages)
        self.prefix = PrefixCache(self.kv) if prefix_cache else None
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: collections.deque[_Request] = collections.deque()
        self.trace = _BoundedTrace()
        self.stats = ServeStats(megastep_depth=megastep_depth)
        self.outcomes = self.stats.outcomes        # rid -> RequestOutcome
        self._results: dict[int, np.ndarray] = {}
        self._next_rid = 0
        self._admit_seq = 0
        self._ticks = 0
        # device-side run state: last emitted token per slot, and the
        # per-step [slots] token history (materialized at run end)
        self._last = jnp.zeros((batch_slots,), jnp.int32)
        self._history: list = []
        self._pending: list[tuple] = []   # (rid, slot, first_tok, steps)

    # ------------------------------------------------------------ intake
    def submit(self, tokens, max_new: int, *,
               ttft_budget_s: float | None = None,
               total_budget_s: float | None = None) -> int:
        """Enqueue one request; returns its rid.  ``ttft_budget_s`` /
        ``total_budget_s`` are per-request deadlines (enqueue-relative,
        enforced at tick boundaries — a request whose first token
        misses its TTFT budget, or whose wall clock exceeds its total
        budget, is evicted as TIMED_OUT with partial tokens in its
        outcome).  Raises :class:`RejectedError` when the bounded
        queue is full or the scheduler is draining for shutdown;
        ``ValueError`` for requests that could never be served."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        # written KV footprint: prompt + all but the final emitted token
        if tokens.size + max_new - 1 > self.engine.max_len:
            raise ValueError(
                f"prompt {tokens.size} + max_new {max_new} exceeds "
                f"engine max_len {self.engine.max_len}")
        need = KV.pages_for(tokens.size + max_new - 1, self.page_size)
        if need > self.kv.num_pages:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.kv.num_pages} — it could never be admitted")
        if self._draining or (self._shutdown is not None
                              and getattr(self._shutdown, "requested",
                                          False)):
            raise RejectedError("admission rejected: shutting down",
                                snapshot=self.snapshot())
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            raise RejectedError(
                f"admission rejected: queue full "
                f"({len(self.queue)}/{self.max_queue})",
                snapshot=self.snapshot())
        rid = self._next_rid
        self._next_rid += 1
        req = _Request(rid, tokens, max_new, t_enqueue=self._clock(),
                       ttft_budget_s=ttft_budget_s,
                       total_budget_s=total_budget_s)
        self.queue.append(req)
        self.outcomes[rid] = RequestOutcome(
            rid=rid, state=RequestState.QUEUED, prompt_len=tokens.size)
        self.trace.append(("enqueue", rid))
        return rid

    def cancel(self, rid: int) -> bool:
        """Cooperative cancellation, honored at the next tick boundary:
        a queued request is dropped, a running one is evicted (pages
        freed, slot recycled, partial tokens in its outcome).  Returns
        False if ``rid`` is unknown or already terminal."""
        for req in self.queue:
            if req.rid == rid:
                req.cancel = True
                return True
        for sl in self.slots:
            if sl.request is not None and sl.request.rid == rid:
                sl.request.cancel = True
                return True
        return False

    def snapshot(self) -> dict:
        """Queue/slot/page-pool state — attached to RejectedError and
        SchedulerStallError, and useful for live introspection."""
        return {
            "tick": self._ticks,
            "queue_depth": len(self.queue),
            "max_queue": self.max_queue,
            "queued_rids": [r.rid for r in self.queue],
            "live": {i: {"rid": sl.request.rid,
                         "prefilled": sl.n_prefilled,
                         "emitted": sl.n_emitted,
                         "max_new": sl.request.max_new}
                     for i, sl in enumerate(self.slots)
                     if sl.request is not None},
            "free_pages": self.kv.free_count,
            "num_pages": self.kv.num_pages,
            "cached_pages": self.kv.cached_count,
            "outstanding_growth": self._outstanding_growth(),
            "draining": self._draining,
        }

    # ------------------------------------------------------- page budget
    def _footprint(self, req: _Request) -> int:
        return KV.pages_for(len(req.tokens) + req.max_new - 1,
                            self.page_size)

    def _outstanding_growth(self) -> int:
        """Pages live slots may still demand before they finish."""
        need = 0
        for i, sl in enumerate(self.slots):
            if sl.request is not None:
                need += self._footprint(sl.request) - self.kv.held(i)
        return need

    # --------------------------------------------------- fault isolation
    def _degrade(self, reason: str, err: Exception) -> None:
        self.stats.degraded[reason] = self.stats.degraded.get(reason, 0) + 1
        self.trace.append(("degraded", reason, type(err).__name__))
        _spans.instant("degraded", reason=reason,
                       error=type(err).__name__)

    def _finalize_queued(self, req: _Request, state: RequestState,
                         error: str, error_type: str | None = None) -> None:
        oc = self.outcomes[req.rid]
        oc.state, oc.error, oc.error_type = state, error, error_type
        self.trace.append(("reject", req.rid, state.value))

    def _release_slot(self, i: int, state: RequestState, *,
                      error: str | None = None,
                      error_type: str | None = None) -> None:
        """Terminal transition for the request in slot ``i``: record its
        outcome, keep whatever tokens it emitted for materialization,
        free its pages (refcounted — shared prefix pages survive with
        their other holders), and recycle the slot.  The quarantine
        primitive: DONE and every eviction state go through here so no
        exit path can leak pages."""
        sl = self.slots[i]
        req = sl.request
        if sl.first_tok is not None:
            self._pending.append((req.rid, i, sl.first_tok,
                                  tuple(sl.steps)))
        oc = self.outcomes[req.rid]
        oc.state, oc.emitted = state, sl.n_emitted
        oc.error, oc.error_type = error, error_type
        if state == RequestState.DONE:
            now = self._clock()
            self.stats.requests.append(RequestStats(
                rid=req.rid, prompt_len=len(req.tokens),
                new_tokens=req.max_new,
                queue_wait_s=req.t_admit - req.t_enqueue,
                ttft_s=req.t_first - req.t_enqueue,
                total_s=now - req.t_enqueue,
                decode_tps=req.max_new / max(now - req.t_first, 1e-9)))
            self.trace.append(("finish", req.rid, i))
        else:
            self.trace.append(("evict", req.rid, i, state.value))
            _spans.instant("evict", rid=req.rid, slot=i,
                           state=state.value, error=error or "")
        freed = self.kv.free(i)
        self.trace.append(("free", i, tuple(freed)))
        sl.request, sl.first_tok = None, None
        sl.n_prefilled, sl.n_emitted, sl.steps = 0, 0, []

    def _guarded(self, point: str, dispatch, *, rid=None, rids=()):
        """The dispatch degradation ladder: attempt, one retry on the
        engine's own backend, then — for engines advertising
        ``supports_fallback`` — one attempt on the ``xla`` fallback
        backend (bit-exact: all registered backends pass the same
        gate).  The chaos injection point fires host-side before each
        attempt, so injected faults never leave donated buffers
        half-consumed.  Raises the final error; the caller
        quarantines the victims."""
        try:
            faults.maybe_fire(point, rid=rid, rids=rids, attempt=0)
            return dispatch(False)
        except Exception:
            self.stats.dispatch_retries += 1
            try:
                faults.maybe_fire(point, rid=rid, rids=rids, attempt=1)
                return dispatch(False)
            except Exception:
                if not getattr(self.engine, "supports_fallback", False):
                    raise
                self.stats.backend_fallbacks += 1
                faults.maybe_fire(point, rid=rid, rids=rids, attempt=2)
                return dispatch(True)

    def _enforce_deadlines(self) -> None:
        """Tick-boundary enforcement of deadlines, cancellation, and
        shutdown drain — the only places a request leaves the system
        outside DONE/quarantine."""
        if self._shutdown is not None and getattr(self._shutdown,
                                                  "requested", False):
            self._draining = True
        if self._draining:
            while self.queue:
                req = self.queue.popleft()
                self._finalize_queued(req, RequestState.CANCELLED,
                                      "shutdown")
        now = self._clock()
        if self.queue:
            keep: collections.deque[_Request] = collections.deque()
            while self.queue:
                req = self.queue.popleft()
                wait = now - req.t_enqueue
                if req.cancel:
                    self._finalize_queued(req, RequestState.CANCELLED,
                                          "cancelled while queued")
                elif (req.total_budget_s is not None
                        and wait > req.total_budget_s):
                    self._finalize_queued(
                        req, RequestState.TIMED_OUT,
                        f"total budget {req.total_budget_s}s exceeded "
                        f"while queued ({wait:.3f}s)")
                elif (req.ttft_budget_s is not None
                        and wait > req.ttft_budget_s):
                    self._finalize_queued(
                        req, RequestState.TIMED_OUT,
                        f"ttft budget {req.ttft_budget_s}s exceeded "
                        f"while queued ({wait:.3f}s)")
                else:
                    keep.append(req)
            self.queue = keep
        for i, sl in enumerate(self.slots):
            req = sl.request
            if req is None:
                continue
            age = now - req.t_enqueue
            if req.cancel:
                self._release_slot(i, RequestState.CANCELLED,
                                   error="cancelled")
            elif req.total_budget_s is not None \
                    and age > req.total_budget_s:
                self._release_slot(
                    i, RequestState.TIMED_OUT,
                    error=f"total budget {req.total_budget_s}s exceeded "
                          f"({age:.3f}s)")
            elif req.ttft_budget_s is not None and req.t_first is None \
                    and age > req.ttft_budget_s:
                self._release_slot(
                    i, RequestState.TIMED_OUT,
                    error=f"ttft budget {req.ttft_budget_s}s exceeded "
                          f"with no first token ({age:.3f}s)")

    # ------------------------------------------------------------- steps
    def _admit(self):
        for i, sl in enumerate(self.slots):
            if sl.request is not None or not self.queue:
                continue
            req = self.queue[0]
            # deadlock-free reservation: admit only if the request's full
            # footprint fits beside every live slot's remaining growth.
            # A prefix hit covers part of the footprint with shared
            # pages; reclaimable cached-idle pages extend the budget
            # (the allocator evicts them under pressure) except the
            # hit's own pages, which this admission is about to pin.
            need = self._footprint(req)
            hit = None
            avail = self.kv.free_count
            if self.prefix is not None:
                try:
                    hit = self.prefix.lookup(req.tokens)
                except Exception as e:
                    # degraded: budget with the full cold footprint
                    self._degrade("prefix_lookup", e)
                if hit is not None:
                    need -= len(hit.nodes)
                    pinned = hit.pages + (
                        [hit.fork_node.page] if hit.fork_node is not None
                        else [])
                    avail += self.kv.reclaimable_count(exclude=pinned)
            if need + self._outstanding_growth() > avail:
                break                      # FIFO: never skip the head
            self.queue.popleft()
            req.t_admit = self._clock()
            sl.request, sl.first_tok = req, None
            sl.n_prefilled, sl.n_emitted, sl.steps = 0, 0, []
            sl.order = self._admit_seq
            self._admit_seq += 1
            self.outcomes[req.rid].state = RequestState.RUNNING
            hit_tokens = 0
            if self.prefix is not None and hit is not None:
                try:
                    hit_tokens = self.prefix.admit(i, req.tokens, hit=hit)
                except Exception as e:
                    # cold-prefill degradation: drop any partial install
                    # (refcounts make the free safe) and start at 0
                    self._degrade("prefix_admit", e)
                    self.kv.free(i)
                    hit_tokens = 0
                if hit_tokens:
                    # shared pages cover positions [0, hit_tokens);
                    # chunked prefill resumes at the divergent token
                    self.kv.lens[i] = hit_tokens
                    sl.n_prefilled = hit_tokens
            self.trace.append(("admit", req.rid, i))
            if hit_tokens:
                self.trace.append(("prefix_hit", req.rid, i, hit_tokens))
                _spans.instant("prefix_hit", rid=req.rid, slot=i,
                               tokens=hit_tokens)
            if self.check_invariants:
                self.kv.check_no_aliasing()

    def _prefill_step(self) -> bool:
        cands = [(sl.order, i) for i, sl in enumerate(self.slots)
                 if sl.request is not None and not sl.prefill_done]
        if not cands:
            return False
        _, i = min(cands)                  # earliest admitted first
        sl = self.slots[i]
        req = sl.request
        start = sl.n_prefilled
        # chunk-tail bucketing: the last chunk of a prompt — and the
        # whole divergent remainder after a prefix hit — dispatches at
        # the smallest gemm.bucket_m width that holds it instead of the
        # full admission width, so a 3-token divergent tail does not
        # pay a chunk-wide GEMM.  The width set is the bucket ladder
        # <= chunk, which Engine.warmup_plans pre-resolves.
        rem = len(req.tokens) - start
        width = self.chunk if rem >= self.chunk else gemm_api.bucket_m(rem)
        end = min(start + width, len(req.tokens))
        final = end == len(req.tokens)
        try:
            self.kv.alloc(i, end)
        except Exception as e:
            # allocator fault (real OOM past the reservation, or
            # injected): quarantine this request only
            self._release_slot(i, RequestState.FAILED,
                               error=f"page allocation failed: {e}",
                               error_type=type(e).__name__)
            return True
        chunk = np.zeros((1, width), np.int32)
        chunk[0, :end - start] = req.tokens[start:end]

        def dispatch(fb):
            kw = {"fallback": True} if fb else {}
            return self.engine.prefill_chunk(
                self.kv.pages, self.kv.table_device([i]),
                self.kv.lens_device([i]), jnp.asarray(chunk),
                jnp.asarray(end - start - 1, jnp.int32),
                page_size=self.page_size, **kw)

        # tick timing through the obs fenced timer: under sync_per_step
        # the fence closes the clock AFTER the device finishes (real
        # execution time, one host sync — counted); unfenced, the number
        # is honestly a dispatch time (timer.fenced stays False).  The
        # span's ``step=`` attr names the jitted body's GEMM manifest so
        # the trace exporter can attribute per-dispatch GEMM work.
        timer = FencedTimer(fence=self.sync_per_step)
        with _spans.span("prefill_chunk", step=f"prefill_chunk_m{width}",
                         rid=req.rid, slot=i, tokens=end - start,
                         fenced=self.sync_per_step), timer:
            try:
                tok, pages = self._guarded("prefill_dispatch", dispatch,
                                           rid=req.rid)
            except Exception as e:
                self._release_slot(i, RequestState.FAILED,
                                   error=f"prefill dispatch failed: {e}",
                                   error_type=type(e).__name__)
                return True
            self.kv.pages = pages
            timer.fence(tok)
        self.stats.host_syncs += timer.synced
        dt = timer.elapsed_s
        self.stats.prefill_s += dt
        self.stats.prefill_tick_ms.append(dt * 1e3)
        self.stats.prefill_tokens += end - start
        self.kv.lens[i] = end
        sl.n_prefilled = end
        self.trace.append(("prefill", req.rid, i, start, end))
        if final:
            if self.prefix is not None:
                # prompt fully prefilled: its full pages are immutable
                # from here (decode writes land strictly past the
                # prompt) — index them BEFORE _emit can free the slot.
                # An index error only loses future hits: degrade.
                try:
                    self.prefix.insert(i, req.tokens)
                except Exception as e:
                    self._degrade("prefix_insert", e)
            # first token stays on device — it feeds the slot's decode
            # steps through the last-token row, no host sync needed
            self._last = self._last.at[i].set(tok)
            sl.first_tok = tok
            req.t_first = self._clock()
            self._emit(i)
        if self.check_invariants:
            self.kv.check_no_aliasing()
        return True

    def _decode_step(self) -> bool:
        dec = [i for i, sl in enumerate(self.slots) if sl.prefill_done]
        if not dec:
            return False
        # realized megastep depth: never let a slot over-generate — the
        # shallowest remaining budget among decoding slots caps the
        # drain, so a request finishes exactly at its max_new and the
        # trace/exactly-once invariants hold at every depth
        d = 1
        if self.megastep_depth > 1:
            d = min(self.megastep_depth,
                    min(self.slots[i].request.max_new
                        - self.slots[i].n_emitted for i in dec))
        # per-slot page growth, individually guarded: an allocator fault
        # growing one slot evicts that request only; the rest decode on
        ok = []
        for i in dec:
            try:
                self.kv.alloc(i, int(self.kv.lens[i]) + d)
            except Exception as e:
                self._release_slot(i, RequestState.FAILED,
                                   error=f"page allocation failed: {e}",
                                   error_type=type(e).__name__)
                continue
            ok.append(i)
        if not ok:
            return True                    # work happened: quarantines
        mask = np.zeros((self.batch_slots,), bool)
        for i in ok:
            mask[i] = True
        rids = tuple(self.slots[i].request.rid for i in ok)

        def dispatch(fb):
            kw = {"fallback": True} if fb else {}
            if d > 1:
                last, hist, pages = self.engine.decode_megastep(
                    self.kv.pages, self.kv.table_device(),
                    self.kv.lens_device(), jnp.asarray(mask), self._last,
                    d, page_size=self.page_size,
                    max_depth=self.megastep_depth, **kw)
                return last, [hist[t] for t in range(d)], pages
            last, pages = self.engine.decode_step(
                self.kv.pages, self.kv.table_device(),
                self.kv.lens_device(), jnp.asarray(mask), self._last,
                page_size=self.page_size, **kw)
            return last, [last], pages

        # same fenced-timer discipline as _prefill_step; ``ticks=d``
        # tells the trace exporter how many decode_step manifests this
        # one dispatch covers (a megastep drain runs d device ticks)
        timer = FencedTimer(fence=self.sync_per_step)
        with _spans.span("decode_tick", step="decode_step", ticks=d,
                         slots=len(ok), fenced=self.sync_per_step), timer:
            try:
                last, ticks, pages = self._guarded("decode_dispatch",
                                                   dispatch, rids=rids)
            except Exception as e:
                # single-victim attribution when the error names a rid
                # (an injected poison request, or any error carrying
                # .rid); otherwise the whole decoding set is poisoned
                bad_rid = getattr(e, "rid", None)
                victims = ([i for i in ok
                            if self.slots[i].request.rid == bad_rid]
                           if bad_rid in rids else ok)
                for i in victims:
                    self._release_slot(i, RequestState.FAILED,
                                       error=f"decode dispatch failed: {e}",
                                       error_type=type(e).__name__)
                return True
            self._last = last
            self.kv.pages = pages
            self.stats.decode_dispatches += 1
            timer.fence(self._last)
        self.stats.host_syncs += timer.synced
        dt = timer.elapsed_s
        self.stats.decode_s += dt
        self.stats.decode_tick_ms.extend([dt * 1e3 / d] * d)
        for tok_row in ticks:
            step_idx = len(self._history)
            self._history.append(tok_row)
            self.trace.append(("decode", rids))
            for i in ok:
                self.kv.lens[i] += 1
                self.slots[i].steps.append(step_idx)
                self._emit(i)
        if self.check_invariants:
            self.kv.check_no_aliasing()
        return True

    def _emit(self, i: int):
        sl = self.slots[i]
        sl.n_emitted += 1
        self.stats.decode_tokens += 1
        if sl.n_emitted == sl.request.max_new:
            self._release_slot(i, RequestState.DONE)

    def _materialize(self):
        """Pull the device-side token history to host and assemble each
        request's tokens (one transfer per run, not per step) — full
        outputs for DONE requests, partial tokens into the outcome
        record for evicted ones."""
        hist = (np.stack([np.asarray(h) for h in self._history])
                if self._history else np.zeros((0, self.batch_slots),
                                               np.int32))
        for rid, slot, first, steps in self._pending:
            toks = np.concatenate(
                [[np.asarray(first)], hist[list(steps), slot]]
                if steps else [[np.asarray(first)]]).astype(np.int32)
            oc = self.outcomes.get(rid)
            if oc is not None:
                oc.tokens = toks
            if oc is not None and oc.state == RequestState.DONE:
                self._results[rid] = toks
        self._pending.clear()

    # --------------------------------------------------------------- run
    def step(self) -> bool:
        """One scheduler tick: enforce deadlines/cancellations, admit,
        one prefill chunk, one decode step.  Returns False once no work
        remains."""
        t0 = time.perf_counter()
        self._ticks += 1
        # chaos point: delay specs model stragglers (the watchdog must
        # flag them); error specs model scheduler-internal failures
        # (the run()-level try/finally must still release every page)
        faults.maybe_fire("slow_tick", tick=self._ticks)
        self._enforce_deadlines()
        self._admit()
        did_p = self._prefill_step()
        did_d = self._decode_step()
        if self.watchdog is not None:
            self.watchdog.record(time.perf_counter() - t0)
        return did_p or did_d or bool(self.queue)

    def run(self, requests, max_new_tokens, *,
            ttft_budget_s=None, total_budget_s=None) \
            -> tuple[list[np.ndarray | None], ServeStats]:
        """Serve ``requests`` (list of int32 prompt arrays) to completion.
        ``max_new_tokens``: int, or a per-request sequence; the optional
        deadline budgets broadcast the same way.  Returns (per-request
        generated tokens in submission order — None for requests that
        ended FAILED/CANCELLED/TIMED_OUT, whose structured
        ``RequestOutcome`` in ``stats.outcomes`` carries the reason and
        any partial tokens — and the ServeStats).

        ``max_queue`` is not consulted for this bulk submission (the
        whole batch is enqueued up front); it guards incremental
        ``submit`` callers.  The page-pool leak audit
        (``assert_all_free``) runs on EVERY exit path, including
        exception exits, after live slots are released.
        """
        n = len(requests)
        mn = ([int(max_new_tokens)] * n if np.isscalar(max_new_tokens)
              else [int(m) for m in max_new_tokens])
        if len(mn) != n:
            raise ValueError("max_new_tokens list must match requests")

        def _bcast(v):
            if v is None or np.isscalar(v):
                return [v] * n
            return list(v)
        tbs, wbs = _bcast(ttft_budget_s), _bcast(total_budget_s)
        t0 = time.perf_counter()
        # bulk submission bypasses the incremental-admission guards
        # (bounded queue, shutdown rejection): the caller handed us the
        # whole batch, and a SIGTERM racing this loop must not raise —
        # the first tick's drain cancels the queue with structured
        # outcomes instead
        max_q, self.max_queue = self.max_queue, None
        sd, self._shutdown = self._shutdown, None
        draining, self._draining = self._draining, False
        try:
            rids = [self.submit(r, m, ttft_budget_s=tb, total_budget_s=wb)
                    for r, m, tb, wb in zip(requests, mn, tbs, wbs)]
        finally:
            self.max_queue = max_q
            self._shutdown = sd
            self._draining = draining
        # every tick either prefills a chunk or decodes >=1 token, so this
        # bound is generous; hitting it means a scheduler bug, not load
        max_ticks = 10 + 2 * (sum(mn) + sum(
            -(-len(np.atleast_1d(r)) // self.chunk) for r in requests))
        try:
            for _ in range(max_ticks):
                if not self.step():
                    break
            else:
                raise SchedulerStallError(
                    f"scheduler made no progress in {max_ticks} ticks",
                    snapshot=self.snapshot())
            self._materialize()
            self.stats.host_syncs += 1     # the end-of-run materialize
        except BaseException as e:
            # exception exit: confine the damage — every in-flight
            # request is evicted (pages freed), queued requests are
            # drained to outcomes, partial tokens are salvaged — so the
            # finally-audit below sees a clean pool and the caller sees
            # structured outcomes beside the raised error
            for i, sl in enumerate(self.slots):
                if sl.request is not None:
                    self._release_slot(
                        i, RequestState.FAILED,
                        error=f"run aborted: {e}",
                        error_type=type(e).__name__)
            while self.queue:
                req = self.queue.popleft()
                self._finalize_queued(req, RequestState.CANCELLED,
                                      f"run aborted: {e}",
                                      type(e).__name__)
            try:
                self._materialize()
            except Exception:
                pass                       # salvage only; keep original
            raise
        finally:
            self.stats.wall_s += time.perf_counter() - t0
            if self.watchdog is not None:
                self.stats.stragglers = list(self.watchdog.events)
            if self.prefix is not None:
                self.stats.prefix = self.prefix.snapshot_stats()
            self.stats.trace_dropped = self.trace.dropped
            # view publication: when a metrics registry is active, map
            # this run's ServeStats into it (the dataclass itself is
            # returned unchanged — the registry is a view, not a move)
            if _metrics._ANY:
                _metrics.publish_serve_stats(self.stats)
            # teardown leak audit — success AND error paths: every
            # request freed, so a page refcount still above zero (a
            # free() that dropped a shared reference short) is a leak
            # the free-list count alone cannot see
            self.kv.assert_all_free()
        return [self._results.get(r) for r in rids], self.stats
