"""Continuous batching: slot-refill scheduler + chunked prefill admission.

The paper's throughput discipline — pack once at load, keep every
compute block busy with fine panels each step — dies in a serving loop
that phase-locks a static batch: one slow request holds ``batch_slots``
slots hostage for ``max_new_tokens`` steps.  This module replaces that
loop with a real scheduler over a *static-shape* slot pool:

  * **Slot refill mid-generation.**  Requests queue FIFO; a slot whose
    request finishes is freed and refilled immediately.  Shapes never
    change — the decode step is always ``[batch_slots, 1]`` with a
    per-slot length vector and write mask — so nothing recompiles and no
    GEMM replans (``plan_cache_info().misses`` is flat in steady state).
  * **Paged KV** (runtime/kv_cache): a refilled slot reuses the pages its
    predecessor freed instead of re-allocating ``[B, max_len]``.
  * **Chunked prefill admission.**  New prompts prefill in fixed-width
    chunks (padded to a ``gemm.bucket_m`` bucket) interleaved with decode
    steps, so admission never stalls decode for a whole prompt and the
    K>=N fine-panel plans stay hot across both phases.

Scheduling is host-side and deliberately simple: per tick, (1) admit
from the queue into idle slots while the page budget holds, (2) run one
prefill chunk for the earliest-admitted prefilling slot, (3) run one
decode step for every decoding slot.  The device work is the Engine's
jitted ``prefill_chunk`` / ``decode_step``; this module never traces.

Outputs are bit-identical to per-request greedy ``Engine.generate`` —
the serving analogue of the paper's bit-exactness gate, enforced by
tests/test_serving.py.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import gemm as gemm_api
from repro.runtime import kv_cache as KV
from repro.runtime.prefix_cache import PrefixCache, PrefixCacheStats


# ------------------------------------------------------------------ stats
@dataclasses.dataclass
class RequestStats:
    """Per-request serving latency record (all seconds / tokens)."""
    rid: int
    prompt_len: int
    new_tokens: int
    queue_wait_s: float          # enqueue -> admitted to a slot
    ttft_s: float                # enqueue -> first token emitted
    total_s: float               # enqueue -> finished
    decode_tps: float            # new_tokens over first-token -> finish


@dataclasses.dataclass
class ServeStats:
    """Aggregate + per-request serving stats.

    Token counts follow the live-slot, non-pad discipline:
    ``prefill_tokens`` counts true prompt tokens actually COMPUTED
    (never chunk padding or dead slots — and never positions the
    prefix cache served from shared pages; those are in
    ``prefix.hit_tokens``); ``decode_tokens`` counts tokens actually
    emitted to a request (the first, prefill-sampled token included).

    ``prefix`` (``prefix_cache=True`` runs only) carries the
    cross-request prefix cache's hit/evict/COW counters
    (:class:`repro.runtime.prefix_cache.PrefixCacheStats`).

    GEMM-dispatch observability: ``plan_cache`` snapshots
    ``gemm.plan_cache_info()`` at run end (plan churn — misses moving in
    steady state means chunk bucketing broke) and ``vmem_clamped_plans``
    counts cached plans whose blocks the policy shrank to fit the
    kernel VMEM budget; ``plan_store`` snapshots the engine's persistent
    plan-store counters (``gemm.StoreInfo``; None when the engine runs
    without a store); ``quant`` is the engine's quantized weight
    format (None: fp32).

    Per-phase latency breakdown (the decode fast lane's observability):
    ``prefill_tick_ms`` / ``decode_tick_ms`` record every tick's
    dispatch duration (a megastep drain of D ticks contributes D
    entries of drain/D — under ``sync_per_step`` these are exact
    device times, under async they are dispatch times); query p50/p99
    via :meth:`phase_percentile`.  ``decode_dispatches`` counts device
    decode calls (``decode_ticks / decode_dispatches`` ~= the realized
    megastep depth), ``host_syncs`` counts the host-blocking
    synchronization points the run actually paid (every
    ``sync_per_step`` block + the final materialize) and
    ``megastep_depth`` echoes the configured D.
    """
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    wall_s: float = 0.0
    fused: bool | None = None       # engine ran the fused GEMM path
    quant: str | None = None        # engine's quantized weight format
    plan_cache: tuple | None = None
    vmem_clamped_plans: int = 0
    plan_store: tuple | None = None
    requests: list[RequestStats] = dataclasses.field(default_factory=list)
    prefill_tick_ms: list = dataclasses.field(default_factory=list)
    decode_tick_ms: list = dataclasses.field(default_factory=list)
    decode_dispatches: int = 0
    host_syncs: int = 0
    megastep_depth: int = 1
    prefix: PrefixCacheStats | None = None

    @property
    def prefill_tps(self):
        return self.prefill_tokens / max(self.prefill_s, 1e-9)

    @property
    def decode_tps(self):
        return self.decode_tokens / max(self.decode_s, 1e-9)

    @property
    def total_tps(self):
        """Emitted tokens over wall time — the cross-engine comparable."""
        return self.decode_tokens / max(self.wall_s, 1e-9)

    @property
    def decode_ticks(self) -> int:
        return len(self.decode_tick_ms)

    def percentile(self, field: str, q: float) -> float:
        vals = [getattr(r, field) for r in self.requests]
        return float(np.percentile(vals, q)) if vals else 0.0

    def phase_percentile(self, phase: str, q: float) -> float:
        """Percentile (ms) over per-tick durations of ``phase``
        ("prefill" | "decode")."""
        vals = {"prefill": self.prefill_tick_ms,
                "decode": self.decode_tick_ms}[phase]
        return float(np.percentile(vals, q)) if vals else 0.0


@dataclasses.dataclass
class _Request:
    rid: int
    tokens: np.ndarray
    max_new: int
    t_enqueue: float
    t_admit: float = 0.0
    t_first: float = 0.0


class _Slot:
    __slots__ = ("request", "n_prefilled", "n_emitted", "first_tok",
                 "steps", "order")

    def __init__(self):
        self.request: _Request | None = None
        self.n_prefilled = 0
        self.n_emitted = 0
        self.first_tok = None      # device scalar from the final chunk
        self.steps: list[int] = []  # indices into the decode history
        self.order = -1            # admission sequence number (FIFO tie)

    @property
    def prefill_done(self):
        return (self.request is not None
                and self.n_prefilled == len(self.request.tokens))


# -------------------------------------------------------------- scheduler
class ContinuousBatchingScheduler:
    """Drives an Engine's paged ``prefill_chunk`` / ``decode_step`` over a
    FIFO request queue with slot refill.

    ``engine`` needs: ``cfg``, ``max_len``, and the two paged step
    methods — the invariant tests drive the scheduler with a stub engine
    to cover thousands of schedules without tracing.

    ``num_pages`` below the dense-equivalent total turns on real paging
    pressure: admission then waits until finished requests return enough
    pages (the reservation check keeps the pool deadlock-free — a request
    is only admitted when its *whole* worst-case footprint fits alongside
    the outstanding growth of every live slot).

    The token feedback loop stays on device: completion is a *count*
    (max_new), never a token value, so the scheduler dispatches steps
    without a host sync and materializes outputs once at the end — the
    same async pipelining ``generate`` gets from its device-side loop.
    ``sync_per_step=True`` blocks after every device call instead, making
    the per-phase timings and TTFT exact (the launcher's percentile
    report uses it); under async they are dispatch-time measurements.

    ``megastep_depth`` (D > 1) drains decode through the engine's fused
    megastep: up to D decode ticks run device-side per host dispatch
    (``Engine.decode_megastep`` — one jitted ``lax.fori_loop`` carrying
    greedy argmax, paged KV writes and the next-token embed), and the
    scheduler drains the emitted tokens every D ticks.  The realized
    depth of each drain is ``min(D, smallest remaining token budget
    among decoding slots)``, so no slot ever over-generates: the event
    trace, exactly-once completion and ``serve == generate`` bitwise
    parity hold at every depth (each megastep tick is the same jitted
    computation as a per-tick dispatch).  The trade: admission and
    chunked prefill interleave only at drain boundaries, so deep
    megasteps buy dispatch amortization at some TTFT cost
    (docs/serving.md).

    ``prefix_cache=True`` turns on the cross-request prefix cache
    (runtime/prefix_cache): admission looks the prompt up in a radix
    index over the page pool, installs the matched pages into the
    slot's table by reference (COW-forking the divergence page), and
    starts chunked prefill at the first uncovered token; a prompt's
    full pages are indexed once its prefill completes, and the index's
    LRU sweep is the pool's pressure evictor.  The cache lives as long
    as this scheduler — ``run`` may be called repeatedly and later
    requests hit earlier runs' prefixes.  Outputs stay bit-identical
    to per-request ``generate`` (the cached KV is bitwise what this
    request's own prefill would have written).

    ``trace`` records ``(event, ...)`` tuples — the scheduler's own audit
    log, asserted over by the serving invariant tests.  ``run`` ends
    with the pool's ``assert_all_free`` leak audit: with every request
    freed, a page refcount that never returned to zero (possible only
    through a sharing bug) raises instead of leaking silently.
    """

    def __init__(self, engine, *, batch_slots: int, prefill_chunk: int = 32,
                 page_size: int = 16, num_pages: int | None = None,
                 check_invariants: bool = False,
                 sync_per_step: bool = False, megastep_depth: int = 1,
                 prefix_cache: bool = False):
        cfg = engine.cfg
        if cfg.modality != "text":
            raise NotImplementedError("continuous batching serves token "
                                      "prompts; stub-embedding frontends "
                                      "go through Engine.prefill")
        self.engine = engine
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.page_size = page_size
        # static admission width: pad to a plan bucket so every chunk in
        # a mixed-length stream resolves the same GEMM plan keys
        self.chunk = gemm_api.bucket_m(prefill_chunk)
        self.check_invariants = check_invariants
        self.sync_per_step = sync_per_step
        if megastep_depth < 1:
            raise ValueError(f"megastep_depth={megastep_depth}: need >= 1")
        if megastep_depth > 1 and not hasattr(engine, "decode_megastep"):
            raise ValueError("megastep_depth > 1 needs an engine with "
                             "decode_megastep (Engine, or a stub "
                             "providing it)")
        self.megastep_depth = megastep_depth
        self.kv = KV.PagedKVCache(
            num_layers=cfg.num_layers, num_slots=batch_slots,
            max_len=engine.max_len, page_size=page_size,
            leaf_specs=KV.leaf_specs_for(cfg), num_pages=num_pages)
        self.prefix = PrefixCache(self.kv) if prefix_cache else None
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: collections.deque[_Request] = collections.deque()
        self.trace: list[tuple] = []
        self.stats = ServeStats(megastep_depth=megastep_depth)
        self._results: dict[int, np.ndarray] = {}
        self._next_rid = 0
        self._admit_seq = 0
        # device-side run state: last emitted token per slot, and the
        # per-step [slots] token history (materialized at run end)
        self._last = jnp.zeros((batch_slots,), jnp.int32)
        self._history: list = []
        self._pending: list[tuple] = []   # (rid, slot, first_tok, steps)

    # ------------------------------------------------------------ intake
    def submit(self, tokens, max_new: int) -> int:
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        # written KV footprint: prompt + all but the final emitted token
        if tokens.size + max_new - 1 > self.engine.max_len:
            raise ValueError(
                f"prompt {tokens.size} + max_new {max_new} exceeds "
                f"engine max_len {self.engine.max_len}")
        need = KV.pages_for(tokens.size + max_new - 1, self.page_size)
        if need > self.kv.num_pages:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.kv.num_pages} — it could never be admitted")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(_Request(rid, tokens, max_new,
                                   t_enqueue=time.perf_counter()))
        self.trace.append(("enqueue", rid))
        return rid

    # ------------------------------------------------------- page budget
    def _footprint(self, req: _Request) -> int:
        return KV.pages_for(len(req.tokens) + req.max_new - 1,
                            self.page_size)

    def _outstanding_growth(self) -> int:
        """Pages live slots may still demand before they finish."""
        need = 0
        for i, sl in enumerate(self.slots):
            if sl.request is not None:
                need += self._footprint(sl.request) - self.kv.held(i)
        return need

    # ------------------------------------------------------------- steps
    def _admit(self):
        for i, sl in enumerate(self.slots):
            if sl.request is not None or not self.queue:
                continue
            req = self.queue[0]
            # deadlock-free reservation: admit only if the request's full
            # footprint fits beside every live slot's remaining growth.
            # A prefix hit covers part of the footprint with shared
            # pages; reclaimable cached-idle pages extend the budget
            # (the allocator evicts them under pressure) except the
            # hit's own pages, which this admission is about to pin.
            need = self._footprint(req)
            hit = None
            avail = self.kv.free_count
            if self.prefix is not None:
                hit = self.prefix.lookup(req.tokens)
                need -= len(hit.nodes)
                pinned = hit.pages + (
                    [hit.fork_node.page] if hit.fork_node is not None
                    else [])
                avail += self.kv.reclaimable_count(exclude=pinned)
            if need + self._outstanding_growth() > avail:
                break                      # FIFO: never skip the head
            self.queue.popleft()
            req.t_admit = time.perf_counter()
            sl.request, sl.first_tok = req, None
            sl.n_prefilled, sl.n_emitted, sl.steps = 0, 0, []
            sl.order = self._admit_seq
            self._admit_seq += 1
            hit_tokens = 0
            if self.prefix is not None:
                hit_tokens = self.prefix.admit(i, req.tokens, hit=hit)
                if hit_tokens:
                    # shared pages cover positions [0, hit_tokens);
                    # chunked prefill resumes at the divergent token
                    self.kv.lens[i] = hit_tokens
                    sl.n_prefilled = hit_tokens
            self.trace.append(("admit", req.rid, i))
            if hit_tokens:
                self.trace.append(("prefix_hit", req.rid, i, hit_tokens))
            if self.check_invariants:
                self.kv.check_no_aliasing()

    def _prefill_step(self) -> bool:
        cands = [(sl.order, i) for i, sl in enumerate(self.slots)
                 if sl.request is not None and not sl.prefill_done]
        if not cands:
            return False
        _, i = min(cands)                  # earliest admitted first
        sl = self.slots[i]
        req = sl.request
        start = sl.n_prefilled
        # chunk-tail bucketing: the last chunk of a prompt — and the
        # whole divergent remainder after a prefix hit — dispatches at
        # the smallest gemm.bucket_m width that holds it instead of the
        # full admission width, so a 3-token divergent tail does not
        # pay a chunk-wide GEMM.  The width set is the bucket ladder
        # <= chunk, which Engine.warmup_plans pre-resolves.
        rem = len(req.tokens) - start
        width = self.chunk if rem >= self.chunk else gemm_api.bucket_m(rem)
        end = min(start + width, len(req.tokens))
        final = end == len(req.tokens)
        self.kv.alloc(i, end)
        chunk = np.zeros((1, width), np.int32)
        chunk[0, :end - start] = req.tokens[start:end]
        t0 = time.perf_counter()
        tok, pages = self.engine.prefill_chunk(
            self.kv.pages, self.kv.table_device([i]),
            self.kv.lens_device([i]), jnp.asarray(chunk),
            jnp.asarray(end - start - 1, jnp.int32),
            page_size=self.page_size)
        self.kv.pages = pages
        if self.sync_per_step:
            jax.block_until_ready(tok)
            self.stats.host_syncs += 1
        dt = time.perf_counter() - t0
        self.stats.prefill_s += dt
        self.stats.prefill_tick_ms.append(dt * 1e3)
        self.stats.prefill_tokens += end - start
        self.kv.lens[i] = end
        sl.n_prefilled = end
        self.trace.append(("prefill", req.rid, i, start, end))
        if final:
            if self.prefix is not None:
                # prompt fully prefilled: its full pages are immutable
                # from here (decode writes land strictly past the
                # prompt) — index them BEFORE _emit can free the slot
                self.prefix.insert(i, req.tokens)
            # first token stays on device — it feeds the slot's decode
            # steps through the last-token row, no host sync needed
            self._last = self._last.at[i].set(tok)
            sl.first_tok = tok
            req.t_first = time.perf_counter()
            self._emit(i)
        if self.check_invariants:
            self.kv.check_no_aliasing()
        return True

    def _decode_step(self) -> bool:
        dec = [i for i, sl in enumerate(self.slots) if sl.prefill_done]
        if not dec:
            return False
        # realized megastep depth: never let a slot over-generate — the
        # shallowest remaining budget among decoding slots caps the
        # drain, so a request finishes exactly at its max_new and the
        # trace/exactly-once invariants hold at every depth
        d = 1
        if self.megastep_depth > 1:
            d = min(self.megastep_depth,
                    min(self.slots[i].request.max_new
                        - self.slots[i].n_emitted for i in dec))
        mask = np.zeros((self.batch_slots,), bool)
        for i in dec:
            self.kv.alloc(i, int(self.kv.lens[i]) + d)
            mask[i] = True
        t0 = time.perf_counter()
        if d > 1:
            self._last, hist, pages = self.engine.decode_megastep(
                self.kv.pages, self.kv.table_device(),
                self.kv.lens_device(), jnp.asarray(mask), self._last,
                d, page_size=self.page_size,
                max_depth=self.megastep_depth)
            ticks = [hist[t] for t in range(d)]
        else:
            self._last, pages = self.engine.decode_step(
                self.kv.pages, self.kv.table_device(),
                self.kv.lens_device(), jnp.asarray(mask), self._last,
                page_size=self.page_size)
            ticks = [self._last]
        self.kv.pages = pages
        self.stats.decode_dispatches += 1
        if self.sync_per_step:
            jax.block_until_ready(self._last)
            self.stats.host_syncs += 1
        dt = time.perf_counter() - t0
        self.stats.decode_s += dt
        self.stats.decode_tick_ms.extend([dt * 1e3 / d] * d)
        rids = tuple(self.slots[i].request.rid for i in dec)
        for tok_row in ticks:
            step_idx = len(self._history)
            self._history.append(tok_row)
            self.trace.append(("decode", rids))
            for i in dec:
                self.kv.lens[i] += 1
                self.slots[i].steps.append(step_idx)
                self._emit(i)
        if self.check_invariants:
            self.kv.check_no_aliasing()
        return True

    def _emit(self, i: int):
        sl = self.slots[i]
        req = sl.request
        sl.n_emitted += 1
        self.stats.decode_tokens += 1
        if sl.n_emitted == req.max_new:
            now = time.perf_counter()
            self._pending.append((req.rid, i, sl.first_tok,
                                  tuple(sl.steps)))
            self.stats.requests.append(RequestStats(
                rid=req.rid, prompt_len=len(req.tokens),
                new_tokens=req.max_new,
                queue_wait_s=req.t_admit - req.t_enqueue,
                ttft_s=req.t_first - req.t_enqueue,
                total_s=now - req.t_enqueue,
                decode_tps=req.max_new / max(now - req.t_first, 1e-9)))
            self.trace.append(("finish", req.rid, i))
            freed = self.kv.free(i)
            self.trace.append(("free", i, tuple(freed)))
            sl.request, sl.first_tok = None, None
            sl.n_prefilled, sl.n_emitted, sl.steps = 0, 0, []

    def _materialize(self):
        """Pull the device-side token history to host and assemble each
        finished request's output (one transfer per run, not per step)."""
        hist = (np.stack([np.asarray(h) for h in self._history])
                if self._history else np.zeros((0, self.batch_slots),
                                               np.int32))
        for rid, slot, first, steps in self._pending:
            toks = np.concatenate(
                [[np.asarray(first)], hist[list(steps), slot]]
                if steps else [[np.asarray(first)]])
            self._results[rid] = toks.astype(np.int32)
        self._pending.clear()

    # --------------------------------------------------------------- run
    def step(self) -> bool:
        """One scheduler tick: admit, one prefill chunk, one decode step.
        Returns False once no work remains."""
        self._admit()
        did_p = self._prefill_step()
        did_d = self._decode_step()
        return did_p or did_d or bool(self.queue)

    def run(self, requests, max_new_tokens) -> tuple[list[np.ndarray],
                                                     ServeStats]:
        """Serve ``requests`` (list of int32 prompt arrays) to completion.
        ``max_new_tokens``: int, or a per-request sequence.  Returns
        (per-request generated tokens in submission order, ServeStats).
        """
        n = len(requests)
        mn = ([int(max_new_tokens)] * n if np.isscalar(max_new_tokens)
              else [int(m) for m in max_new_tokens])
        if len(mn) != n:
            raise ValueError("max_new_tokens list must match requests")
        t0 = time.perf_counter()
        rids = [self.submit(r, m) for r, m in zip(requests, mn)]
        # every tick either prefills a chunk or decodes >=1 token, so this
        # bound is generous; hitting it means a scheduler bug, not load
        max_ticks = 10 + 2 * (sum(mn) + sum(
            -(-len(np.atleast_1d(r)) // self.chunk) for r in requests))
        for _ in range(max_ticks):
            if not self.step():
                break
        else:
            raise RuntimeError("scheduler made no progress")
        self._materialize()
        self.stats.host_syncs += 1     # the one end-of-run materialize
        self.stats.wall_s += time.perf_counter() - t0
        if self.prefix is not None:
            self.stats.prefix = self.prefix.snapshot_stats()
        # teardown leak audit: every request freed — a page refcount
        # still above zero (a free() that dropped a shared reference
        # short) is a leak the free-list count alone cannot see
        self.kv.assert_all_free()
        return [self._results[r] for r in rids], self.stats
