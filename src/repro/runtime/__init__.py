"""Runtime: training loop, serving engine + continuous batching, fault
tolerance and deterministic fault injection."""
from repro.runtime import (batching, fault_tolerance, faults, kv_cache,
                           prefix_cache, serve_loop, train_loop)
from repro.runtime.batching import (ContinuousBatchingScheduler,
                                    RejectedError, RequestOutcome,
                                    RequestState, SchedulerStallError,
                                    ServeStats)
from repro.runtime.fault_tolerance import GracefulShutdown, StepWatchdog
from repro.runtime.faults import (FaultInjected, FaultPlan, FaultSpec,
                                  use_faults)
from repro.runtime.kv_cache import PagedKVCache
from repro.runtime.prefix_cache import PrefixCache, PrefixCacheStats
from repro.runtime.train_loop import TrainState, make_train_step, train
from repro.runtime.serve_loop import Engine

__all__ = ["batching", "fault_tolerance", "faults", "kv_cache",
           "prefix_cache", "serve_loop", "train_loop", "TrainState",
           "make_train_step", "train", "Engine",
           "ContinuousBatchingScheduler", "ServeStats", "RequestState",
           "RequestOutcome", "RejectedError", "SchedulerStallError",
           "GracefulShutdown", "StepWatchdog", "FaultInjected",
           "FaultPlan", "FaultSpec", "use_faults", "PagedKVCache",
           "PrefixCache", "PrefixCacheStats"]
