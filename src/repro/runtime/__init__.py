"""Runtime: training loop, serving engine, fault tolerance."""
from repro.runtime import fault_tolerance, serve_loop, train_loop
from repro.runtime.train_loop import TrainState, make_train_step, train
from repro.runtime.serve_loop import Engine

__all__ = ["fault_tolerance", "serve_loop", "train_loop", "TrainState",
           "make_train_step", "train", "Engine"]
