"""Cross-request prefix cache: a radix index over the paged KV pool.

Production traffic shares system prompts and few-shot preambles; the
biggest deployment lever above the inner loop is not recomputing that
work at all.  The paged KV cache already gives page-granular identity —
a physical page holds the KV of exactly ``page_size`` consecutive
tokens of one token run — so cached prefixes compose out of pages:

  * **The index is a trie over page-aligned token runs.**  Each node
    owns one physical page and the ``page_size``-token run it encodes;
    the path from the root spells a cached prefix.  Children are keyed
    by their full token run, so a full-page match is one dict lookup,
    and sibling runs that share a head diverge exactly like a radix
    tree's edges split.
  * **Hits install pages, not values.**  ``admit`` maps the matched
    run's pages into the new slot's page table (``PagedKVCache.install``
    increments each page's refcount) and the scheduler starts chunked
    prefill at the first token the cache does not cover.  At least one
    token is always recomputed — the final prompt position's logits
    seed generation and are never cached.
  * **Copy-on-write at the divergence page.**  When the prompt runs
    into a cached page but diverges (or ends) inside it, the page
    cannot be shared — the new request must overwrite its tail — so it
    is COW-forked: ``PagedKVCache.fork`` copies the page into a fresh
    one mapped privately to the slot, the matching head positions ride
    along for free, and prefill resumes mid-page at the divergent
    token.
  * **Insertion at prefill completion.**  Once a prompt is fully
    prefilled its full prompt pages are immutable (decode writes land
    strictly past the prompt; a partial final page is never indexed),
    so the trie walks the prompt and registers the slot's pages for
    every run not already cached (``mark_cached`` keeps them off the
    free list when the request finishes).
  * **Eviction is LRU over refcount-0 leaves.**  The index holds no
    refcounts itself: a cached page referenced by no live slot is
    *reclaimable*.  Under pool pressure the allocator calls
    :meth:`PrefixCache._evict`, which removes least-recently-touched
    refcount-0 leaf nodes (cascading upward as parents become leaves)
    until the demand is met.  Because a hit always installs the full
    root path, a live page's ancestors are live too — so every
    refcount-0 page is reachable by the leaf cascade and
    ``reclaimable_count`` is exact.

Numerics contract: a cached page holds bit-identical KV to what the
admitted request's own prefill would have written — chunked prefill
writes the same values as one-shot prefill (the PR 2 serving contract),
and KV at position p depends only on tokens 0..p, which match by
construction of the trie path.  ``serve`` with the cache on therefore
stays token-identical to per-request ``generate`` — cold, warm,
COW-forked, under eviction pressure, and on quantized packs
(tests/test_serving.py, tests/test_prefix_cache.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import spans as _spans
from repro.runtime import faults as _faults


@dataclasses.dataclass
class PrefixCacheStats:
    """Hit/evict/COW counters surfaced through ``ServeStats.prefix``.

    ``hit_tokens`` counts prompt tokens whose KV was reused (full shared
    pages plus the head of each COW fork) — the prefill work the cache
    deleted; ``cached_pages`` snapshots the index size at run end."""
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    hit_tokens: int = 0
    cow_forks: int = 0
    inserted_pages: int = 0
    evicted_pages: int = 0
    cached_pages: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)


@dataclasses.dataclass
class PrefixHit:
    """A lookup result: ``nodes`` are the full-page matches (their pages
    install verbatim), ``fork_node``/``fork_reuse`` the divergence-page
    COW candidate (reuse the first ``fork_reuse`` positions of that
    page), ``tokens`` the total prompt positions covered."""
    nodes: list
    fork_node: "object | None"
    fork_reuse: int
    tokens: int

    @property
    def pages(self) -> list[int]:
        return [n.page for n in self.nodes]


class _Node:
    __slots__ = ("run", "page", "parent", "children", "last_used")

    def __init__(self, run, page, parent):
        self.run = run                # tuple of page_size token ids
        self.page = page              # physical page id in the pool
        self.parent = parent
        self.children: dict[tuple, _Node] = {}
        self.last_used = 0


class PrefixCache:
    """Radix/trie prefix index over one :class:`PagedKVCache`.

    The cache registers itself as the pool's pressure evictor; all
    mutation happens host-side between device steps, like the allocator
    it extends.
    """

    def __init__(self, pool, *, page_size: int | None = None):
        self.pool = pool
        self.page_size = (page_size if page_size is not None
                          else pool.page_size)
        if self.page_size != pool.page_size:
            raise ValueError(
                f"prefix cache page_size={self.page_size} must match "
                f"the pool's {pool.page_size}")
        self.root = _Node(run=None, page=-1, parent=None)
        self.stats = PrefixCacheStats()
        self._clock = 0
        pool.set_evictor(self._evict)

    # ------------------------------------------------------------ lookup
    def lookup(self, tokens) -> PrefixHit:
        """Longest cached prefix of ``tokens``, capped at
        ``len(tokens) - 1`` positions (the last prompt token is always
        recomputed — its logits seed generation).  Pure: no refcount,
        LRU or pool mutation."""
        # chaos point: fires before any walk — the scheduler degrades a
        # failed lookup to cold prefill (full footprint, no install)
        _faults.maybe_fire("prefix_cache", op="lookup")
        tokens = np.asarray(tokens).reshape(-1)
        with _spans.span("prefix_lookup", prompt=len(tokens)) as sp:
            P = self.page_size
            limit = len(tokens) - 1
            node, nodes, pos = self.root, [], 0
            while pos + P <= limit:
                child = node.children.get(self._run(tokens, pos))
                if child is None:
                    break
                nodes.append(child)
                node = child
                pos += P
            # divergence page: the deepest frontier child sharing the
            # longest head with the remaining tokens is the COW candidate
            fork, reuse = None, 0
            want = tuple(int(t) for t in tokens[pos:min(pos + P, limit)])
            if want:
                for run, child in node.children.items():
                    r = 0
                    for a, b in zip(run, want):
                        if a != b:
                            break
                        r += 1
                    if r > reuse:
                        fork, reuse = child, r
            sp.set(hit_tokens=pos + reuse, pages=len(nodes),
                   cow=fork is not None and reuse > 0)
            return PrefixHit(nodes=nodes, fork_node=fork, fork_reuse=reuse,
                             tokens=pos + reuse)

    def _run(self, tokens, pos) -> tuple:
        return tuple(int(t) for t in tokens[pos:pos + self.page_size])

    # ------------------------------------------------------------- admit
    def admit(self, slot: int, tokens, hit: PrefixHit | None = None) -> int:
        """Install the longest cached prefix of ``tokens`` into
        ``slot``'s (empty) page table: shared pages by reference, the
        divergence page by COW fork.  Returns the number of prompt
        positions covered — the scheduler sets the slot's length there
        and starts chunked prefill at the first uncovered token."""
        # chaos point: fires before the install — a failed admit leaves
        # the slot empty and the scheduler prefills cold (any partial
        # install from a deeper failure is freed by the scheduler)
        _faults.maybe_fire("prefix_cache", op="admit", slot=slot)
        if hit is None:
            hit = self.lookup(tokens)
        self.stats.lookups += 1
        if hit.tokens == 0:
            self.stats.misses += 1
            return 0
        with _spans.span("prefix_admit", slot=slot,
                         hit_tokens=hit.tokens, pages=len(hit.nodes)):
            self.pool.install(slot, hit.pages)
            if hit.fork_node is not None and hit.fork_reuse > 0:
                self.pool.fork(slot, hit.fork_node.page)
                self.stats.cow_forks += 1
                self._touch(hit.fork_node)
            for n in hit.nodes:
                self._touch(n)
        self.stats.hits += 1
        self.stats.hit_tokens += hit.tokens
        return hit.tokens

    # ------------------------------------------------------------ insert
    def insert(self, slot: int, tokens) -> int:
        """Index ``slot``'s full prompt pages once its prompt is fully
        prefilled.  Runs already cached keep their existing page (a
        racing cold duplicate stays private and is freed normally);
        new runs register the slot's own page via ``mark_cached``.
        Returns the number of pages newly indexed."""
        # chaos point: a failed insert only loses future hits — the
        # request's own pages stay private and are freed normally
        _faults.maybe_fire("prefix_cache", op="insert", slot=slot)
        tokens = np.asarray(tokens).reshape(-1)
        with _spans.span("prefix_insert", slot=slot,
                         prompt=len(tokens)) as sp:
            P = self.page_size
            node, added = self.root, 0
            for j in range(len(tokens) // P):
                run = self._run(tokens, j * P)
                child = node.children.get(run)
                if child is None:
                    page = int(self.pool.page_table[slot, j])
                    if page < 0:
                        raise ValueError(
                            f"insert: slot {slot} has no page for prompt "
                            f"run {j} — prompt not fully prefilled?")
                    child = _Node(run=run, page=page, parent=node)
                    node.children[run] = child
                    self.pool.mark_cached([page])
                    added += 1
                self._touch(child)
                node = child
            sp.set(added_pages=added)
        self.stats.inserted_pages += added
        return added

    # ---------------------------------------------------------- eviction
    def _evict(self, need: int) -> int:
        """Pool-pressure hook: uncache least-recently-touched
        refcount-0 leaves (cascading as parents become leaves) until
        ``need`` pages came back to the free list or nothing is
        evictable."""
        with _spans.span("prefix_evict", need=need) as sp:
            freed = 0
            while freed < need:
                victim = None
                stack = list(self.root.children.values())
                while stack:
                    n = stack.pop()
                    if n.children:
                        stack.extend(n.children.values())
                    elif self.pool.refcount[n.page] == 0 and (
                            victim is None
                            or n.last_used < victim.last_used):
                        victim = n
                if victim is None:
                    break
                victim.parent.children.pop(victim.run)
                freed += len(self.pool.uncache([victim.page]))
                self.stats.evicted_pages += 1
            sp.set(freed=freed)
            return freed

    def clear(self) -> int:
        """Drop the whole index, returning idle pages to the free list."""
        pages = [n.page for n in self._walk()]
        self.root.children.clear()
        return len(self.pool.uncache(pages))

    # ------------------------------------------------------------- misc
    def _touch(self, node: _Node) -> None:
        """LRU clock: touch ``node`` and its ancestors (ancestors must
        never look colder than a descendant the sweep has to reach
        through them)."""
        self._clock += 1
        while node is not None and node is not self.root:
            node.last_used = self._clock
            node = node.parent

    def _walk(self):
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            yield n

    @property
    def num_pages(self) -> int:
        """Pages currently indexed."""
        return sum(1 for _ in self._walk())

    def snapshot_stats(self) -> PrefixCacheStats:
        self.stats.cached_pages = self.num_pages
        return self.stats
