"""Seeded, deterministic fault injection for the serving stack.

Chaos testing the scheduler's isolation guarantees needs faults that
are (a) *deterministic* — the same seed and trace must fire the same
faults at the same occurrences, or the survivor-parity gate cannot
diff a faulted run against a fault-free one — and (b) *host-side* —
an injected error must fire BEFORE a jitted dispatch consumes its
donated buffers, so the retry / fallback ladder always operates on
intact state.  This module provides both:

  * :class:`FaultSpec` — one injection rule: WHERE (an
    ``INJECTION_POINTS`` name), WHEN (explicit occurrence indices
    ``at`` and/or a seeded per-occurrence probability ``p``), WHO
    (``target_rid`` restricts a spec to dispatches involving one
    request — the deterministic poison-request selector), and WHAT
    (an exception to raise, or ``delay_s`` to sleep instead — the
    slow-tick/straggler injection).
  * :class:`FaultPlan` — a set of specs plus the seeded RNG and the
    per-spec occurrence counters; records every fire in ``events``
    and ``fired`` for test assertions.
  * :func:`use_faults` — scopes a plan over a block, thread-locally,
    exactly like ``gemm.use_backend``.  Nothing fires outside a
    scope: :func:`maybe_fire` is a no-op when no plan is active, so
    production code paths carry only a thread-local read.

Injection points (the WHERE vocabulary — each is a named call site in
the serving stack, all host-side):

  ``alloc_oom``         kv_cache.PagedKVCache._take_free (page pool)
  ``prefill_dispatch``  scheduler prefill-chunk dispatch (per attempt)
  ``decode_dispatch``   scheduler decode/megastep dispatch (per attempt)
  ``slow_tick``         top of every scheduler tick (delay or error)
  ``prefix_cache``      prefix_cache lookup / admit / insert entry
  ``plan_resolve``      gemm.policy.plan() miss path, before _resolve

``plan_resolve`` is wired through a hook global on ``gemm.policy``
(installed lazily at the first ``use_faults`` entry) rather than an
import, because ``repro.gemm`` must not import ``repro.runtime`` at
module level.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time

INJECTION_POINTS = frozenset({
    "alloc_oom", "prefill_dispatch", "decode_dispatch", "slow_tick",
    "prefix_cache", "plan_resolve",
})


class FaultInjected(RuntimeError):
    """The default injected error.  ``point`` names the injection site;
    ``rid`` carries the targeted request (``FaultSpec.target_rid``) so
    the scheduler's quarantine can attribute a batched-decode fault to
    the single poisoned request instead of failing the whole batch."""

    def __init__(self, point: str, msg: str | None = None, *,
                 rid: int | None = None):
        super().__init__(msg or f"injected fault at {point!r}"
                         + (f" (rid {rid})" if rid is not None else ""))
        self.point = point
        self.rid = rid


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule.

    ``at``: explicit 0-based occurrence indices (counted per spec, over
    the occurrences the spec is *eligible* for — see ``target_rid``) at
    which to fire.  ``p``: additionally fire with this per-occurrence
    probability, drawn from the plan's seeded RNG (deterministic for a
    deterministic schedule).  ``at=()`` with ``p=0`` fires on EVERY
    eligible occurrence.

    ``target_rid``: only occurrences whose context involves this
    request id are eligible (matched against the ``rid``/``rids``
    context the call site passes) — the poison-request selector.
    Firing with a target raises :class:`FaultInjected` carrying the
    rid, which the scheduler uses for single-victim quarantine.

    ``delay_s`` > 0 turns the spec into a straggler injection: firing
    sleeps instead of raising.  ``error`` overrides the raised
    exception (an instance, or a zero-arg callable returning one) —
    e.g. ``kv_cache.OutOfPagesError`` to exercise the exact production
    error type.
    """
    point: str
    at: tuple = ()
    p: float = 0.0
    delay_s: float = 0.0
    error: object = None
    target_rid: int | None = None

    def __post_init__(self):
        if self.point not in INJECTION_POINTS:
            raise ValueError(
                f"unknown injection point {self.point!r}; known: "
                f"{sorted(INJECTION_POINTS)}")
        object.__setattr__(self, "at", tuple(int(a) for a in self.at))


class FaultPlan:
    """A set of :class:`FaultSpec` rules plus the deterministic firing
    state: one occurrence counter per spec, the seeded RNG behind
    probabilistic specs, and the fire log (``events``: ``(point,
    occurrence, ctx)`` tuples; ``fired``: per-point counts)."""

    def __init__(self, *specs: FaultSpec, seed: int = 0):
        self.specs = tuple(specs)
        self.seed = seed
        self._rng = random.Random(seed)
        self._seen = [0] * len(self.specs)
        self.fired: dict[str, int] = {}
        self.events: list[tuple] = []

    def check(self, point: str, ctx: dict) -> FaultSpec | None:
        """Advance the counters for ``point`` and return the first spec
        that fires at this occurrence (None: nothing fires)."""
        hit = None
        for idx, spec in enumerate(self.specs):
            if spec.point != point:
                continue
            if spec.target_rid is not None:
                rid = ctx.get("rid")
                rids = ctx.get("rids") or ()
                if spec.target_rid != rid and spec.target_rid not in rids:
                    continue                    # not eligible: no count
            occ = self._seen[idx]
            self._seen[idx] += 1
            fire = (occ in spec.at if (spec.at or spec.p <= 0)
                    else False) or (spec.p > 0
                                    and self._rng.random() < spec.p)
            if not spec.at and spec.p <= 0:
                fire = True                      # fire every occurrence
            if fire and hit is None:
                hit = spec
                self.fired[point] = self.fired.get(point, 0) + 1
                self.events.append((point, occ, dict(ctx)))
        return hit


_tls = threading.local()


def active_plan() -> FaultPlan | None:
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_faults(plan: FaultPlan):
    """Scope ``plan`` over the block (thread-local, nestable — the
    innermost plan wins), mirroring ``gemm.use_backend``."""
    _install_policy_hook()
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    stack.append(plan)
    try:
        yield plan
    finally:
        stack.pop()


def maybe_fire(point: str, **ctx) -> None:
    """The call-site hook: no-op unless a plan is active and one of its
    specs fires at this occurrence.  A firing delay spec sleeps
    ``delay_s``; anything else raises (``FaultInjected`` by default,
    carrying the spec's ``target_rid``)."""
    plan = active_plan()
    if plan is None:
        return
    spec = plan.check(point, ctx)
    if spec is None:
        return
    if spec.delay_s > 0:
        time.sleep(spec.delay_s)
        return
    err = spec.error
    if callable(err):
        err = err()
    if err is not None:
        raise err
    raise FaultInjected(point, rid=spec.target_rid)


def _install_policy_hook() -> None:
    """Install :func:`maybe_fire` as ``gemm.policy``'s plan-resolution
    fault hook.  Lazy and idempotent: ``repro.gemm`` cannot import
    ``repro.runtime`` at module level, so the wiring runs the other
    way, at the first ``use_faults`` entry."""
    from repro.gemm import policy
    if getattr(policy, "_FAULT_HOOK", None) is not maybe_fire:
        policy._FAULT_HOOK = maybe_fire
