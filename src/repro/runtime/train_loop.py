"""Training loop: grad-accumulated, sharded, fault-tolerant.

Structure of one compiled step (all inside a single jit, donated state):

  microbatch scan (lax.scan over grad-accum slices)
    └─ value_and_grad of transformer.loss_fn
         └─ scan-over-layers forward (+ remat policy from the config)
  fp32 grad accumulation  →  clip  →  optimizer update

Mixed precision: parameters are kept in ``cfg.param_dtype`` (master) and
cast to ``cfg.compute_dtype`` for the forward/backward.  With bf16
compute this makes every gradient all-reduce/reduce-scatter bf16 on the
wire — the grad-compression lever of DESIGN.md §4 — while accumulation
across microbatches and the update stay fp32.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.models import transformer
from repro.optim import optimizers as O
from repro.parallel import sharding as Sh


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: dict
    opt_state: dict


def init_state(cfg, tc, *, key=None):
    params = transformer.init_params(cfg, key or jax.random.key(tc.seed))
    opt = make_optimizer(cfg, tc)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt.init(params))


def abstract_state(cfg, tc):
    return jax.eval_shape(lambda: init_state(cfg, tc))


def make_optimizer(cfg, tc):
    lr = O.warmup_cosine(tc.learning_rate, tc.warmup_steps,
                         max(tc.steps, 1))
    return O.make(cfg.optimizer, lr, weight_decay=tc.weight_decay,
                  grad_clip=tc.grad_clip)


def num_microbatches(global_batch: int, batch_shards: int,
                     per_device: int) -> int:
    """Grad-accum slice count: the largest divisor of the per-shard batch
    that brings each slice down to <= per_device rows per shard."""
    per_shard = global_batch // max(batch_shards, 1)
    n = max(per_shard // max(per_device, 1), 1)
    while per_shard % n:
        n -= 1
    return n


def state_shardings(state, mesh):
    """NamedShardings for a TrainState.

    Optimizer state inherits its parameter's spec (FSDP: shards with the
    param).  Adafactor's factored stats drop a trailing dim: ``vr`` keeps
    the spec prefix, ``vc`` keeps prefix + last entry.
    """
    pspecs = Sh.param_specs(state.params, mesh)

    def _mirror(node, spec_node):
        if isinstance(node, dict) and isinstance(spec_node, dict):
            return {k: _mirror(node[k], spec_node[k]) for k in node}
        if isinstance(node, dict):   # factored {"vr","vc"} / {"v"} leaf dict
            ps = tuple(spec_node)
            out = {}
            for k, v in node.items():
                if k == "vc":        # (..., last-dim): prefix + last entry
                    sp = ps[:v.ndim - 1] + ps[-1:] if v.ndim else ()
                else:                # "vr"/"v": spec prefix
                    sp = ps[:v.ndim]
                out[k] = Sh.fit_spec(P(*sp), v.shape, mesh)
            return out
        return Sh.fit_spec(spec_node, node.shape, mesh)

    ospecs = {k: _mirror(sub, pspecs) for k, sub in state.opt_state.items()}
    specs = TrainState(step=P(), params=pspecs, opt_state=ospecs)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(cfg, shape, mesh):
    """Shardings for the {"inputs", "labels"} batch dict."""
    b = shape.global_batch
    tok = NamedSharding(mesh, Sh.batch_spec(b, mesh, extra_dims=1))
    if cfg.modality != "text":
        inp = NamedSharding(mesh, Sh.batch_spec(b, mesh, extra_dims=2))
    else:
        inp = tok
    return {"inputs": inp, "labels": tok}


def _cast_for_compute(params, cdtype):
    """Master→compute cast (matrices only; vectors stay fp32-safe)."""
    return jax.tree.map(
        lambda p: p.astype(cdtype) if p.ndim >= 2 else p, params)


def make_train_step(cfg, tc, mesh, *, donate: bool = True,
                    batch_shardings=None):
    """Build the jitted (state, batch) -> (state, metrics) step."""
    opt = make_optimizer(cfg, tc)
    shard_fn = Sh.activation_sharder(mesh)
    batch_shards = Sh.axis_size(mesh, ("pod", "data"))
    if tc.manual_dp:
        return _make_manual_dp_step(cfg, tc, mesh, opt, donate=donate,
                                    batch_shardings=batch_shardings)

    def loss_fn(params_c, micro):
        return transformer.loss_fn(cfg, params_c, micro, shard_fn=shard_fn)

    pspecs = Sh.param_specs(abstract_state(cfg, tc).params, mesh)

    def _constrain_like_params(tree):
        """Pin gradients to their parameter's sharding (FSDP).

        §Perf iteration 1: without this, the fp32 grad accumulator is
        replicated over the data axis and EVERY microbatch's gradients
        are all-reduced at full width (measured 536 GB/device/step on
        deepseek-7b train_4k).  Constrained, GSPMD reduce-scatters each
        microbatch's grads into data-sharded accumulators — 1/(2·shards)
        the wire bytes — and the unsharded tensors never materialize.
        """
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, s)),
            tree, pspecs, is_leaf=lambda x: isinstance(x, P))

    def _drop_data_axes(spec: P) -> P:
        drop = {"data", "pod"}

        def keep(entry):
            if entry is None:
                return None
            names = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(n for n in names if n not in drop)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return P(*(keep(e) for e in spec))

    def step_fn(state: TrainState, batch: dict):
        n_micro = num_microbatches(
            batch["labels"].shape[0], batch_shards, tc.microbatch_per_device)
        params_c = _cast_for_compute(state.params, cfg.cdtype)
        if tc.gather_params_once:
            # §Perf iteration 3: materialize the FSDP all-gather ONCE per
            # step instead of once per microbatch — the compute copy is
            # constrained replicated over the data axes, so the gather
            # hoists out of the scan (costs full-d params per device).
            params_c = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    p, NamedSharding(mesh, _drop_data_axes(s))),
                params_c, pspecs, is_leaf=lambda x: isinstance(x, P))

        def slice_micro(x):
            b = x.shape[0]
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        micro = jax.tree.map(slice_micro, batch)

        def accum(carry, mb):
            g_acc, loss_acc, ce_acc = carry
            (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params_c, mb)
            if tc.grad_compression == "bf16":
                # bf16 on the wire; fp32 accumulate after the collective
                g = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
            if tc.shard_grad_accum:
                g = _constrain_like_params(g)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g_acc, g)
            return (g, loss_acc + loss, ce_acc + aux["ce"]), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          state.params)
        if tc.shard_grad_accum:
            g0 = _constrain_like_params(g0)
        (grads, loss, ce), _ = jax.lax.scan(
            accum, (g0, jnp.zeros(()), jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        loss, ce = loss / n_micro, ce / n_micro

        new_params, new_opt, stats = opt.update(
            grads, state.opt_state, state.params, state.step)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt_state=new_opt)
        metrics = {"loss": loss, "ce": ce, **stats}
        return new_state, metrics

    abstract = abstract_state(cfg, tc)
    st_sh = state_shardings(abstract, mesh)
    return jax.jit(
        step_fn,
        in_shardings=(st_sh, batch_shardings),
        out_shardings=(st_sh, None),
        donate_argnums=(0,) if donate else (),
    )


def _make_manual_dp_step(cfg, tc, mesh, opt, *, donate: bool = True,
                         batch_shardings=None):
    """§Perf iteration 4: manual data parallelism, auto tensor parallelism.

    The naive GSPMD step syncs gradients at every (microbatch × layer)
    dot boundary — all-reduce wire bytes scale with n_micro (measured
    536 GB/device/step on deepseek-7b train_4k).  Under a shard_map whose
    MANUAL axes are (pod, data) and whose auto axis is model:

      * FSDP params are all-gathered over data ONCE per step (explicit
        `jax.lax.all_gather`, the A3 hoist made structural);
      * every microbatch's backward produces LOCAL grads — no data-axis
        collective inside the scan at all;
      * one `psum_scatter` per param per STEP syncs and re-shards the
        accumulated grads — and because we own the collective, the
        grad_compression="bf16" wire cast finally applies (the A2
        lesson: post-hoc casts can't reach GSPMD-inserted reductions).

    Expected: all-reduce wire ÷ ~n_micro; bf16 halves it again.
    """
    # nothing_saveable remat inside partial-auto shard_map trips an XLA
    # CHECK at 512 partitions ("Invalid binary instruction opcode copy");
    # dots-saveable avoids the pattern and saves less recompute anyway.
    if cfg.remat and cfg.remat_policy != "dots":
        cfg = dataclasses.replace(cfg, remat_policy="dots")

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    pspecs = Sh.param_specs(abstract_state(cfg, tc).params, mesh)
    # inner-region activation constraints may not name manual axes
    shard_fn = Sh.activation_sharder(
        mesh, drop_axes=frozenset(data_axes))

    def _data_dim(spec: P) -> int | None:
        for d, entry in enumerate(spec):
            names = entry if isinstance(entry, tuple) else (entry,)
            if any(n in data_axes for n in names if n):
                return d
        return None

    def _manual_specs(tree_specs):
        def keep(spec):
            d = _data_dim(spec)
            out = [None] * len(spec)
            if d is not None:
                out[d] = "data"      # data only; pod handled for batch
            return P(*out)
        return jax.tree.map(keep, tree_specs,
                            is_leaf=lambda x: isinstance(x, P))

    man_pspecs = _manual_specs(pspecs)

    def loss_fn(params_full, micro):
        return transformer.loss_fn(cfg, params_full, micro,
                                   shard_fn=shard_fn)

    def inner(params_local, batch_local):
        # 1. gather FSDP shards once per step
        def gather(p, spec):
            d = _data_dim(spec)
            if d is None:
                return p
            return jax.lax.all_gather(p, "data", axis=d, tiled=True)
        params_full = jax.tree.map(gather, params_local, pspecs,
                                   is_leaf=lambda x: isinstance(x, P))

        rows = batch_local["labels"].shape[0]
        n_micro = max(rows // max(tc.microbatch_per_device, 1), 1)
        while rows % n_micro:
            n_micro -= 1

        def slice_micro(x):
            return x.reshape(n_micro, rows // n_micro, *x.shape[1:])
        micro = jax.tree.map(slice_micro, batch_local)

        def accum(carry, mb):
            g_acc, loss_acc, ce_acc = carry
            (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params_full, mb)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g_acc, g)
            return (g, loss_acc + loss, ce_acc + aux["ce"]), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params_full)
        (grads, loss, ce), _ = jax.lax.scan(
            accum, (g0, jnp.zeros(()), jnp.zeros(())), micro)

        # 2. ONE grad sync per step (mean over data shards), re-sharded
        inv = 1.0 / (n_micro * Sh.axis_size(mesh, data_axes))

        def sync(g, spec):
            if tc.grad_compression == "bf16":
                g = g.astype(jnp.bfloat16)       # wire dtype
            d = _data_dim(spec)
            if d is None:
                g = jax.lax.psum(g, data_axes)
            else:
                g = jax.lax.psum_scatter(g, "data", scatter_dimension=d,
                                         tiled=True)
                if len(data_axes) > 1:           # cross-pod reduction
                    g = jax.lax.psum(g, "pod")
            return g.astype(jnp.float32) * inv
        grads = jax.tree.map(sync, grads, pspecs,
                             is_leaf=lambda x: isinstance(x, P))
        scale = 1.0 / n_micro
        loss = jax.lax.pmean(loss * scale, data_axes)
        ce = jax.lax.pmean(ce * scale, data_axes)
        return grads, loss, ce

    batch_rows_spec = P(data_axes if len(data_axes) > 1 else
                        data_axes[0])

    def batch_spec_for(tree):
        return jax.tree.map(
            lambda x: P(*(batch_rows_spec + (None,) * (x.ndim - 1))),
            tree)

    def step_fn(state: TrainState, batch: dict):
        params_c = _cast_for_compute(state.params, cfg.cdtype)
        inner_sm = compat.shard_map(
            inner, mesh=mesh,
            in_specs=(man_pspecs, batch_spec_for(batch)),
            out_specs=(man_pspecs, P(), P()),
            axis_names=set(data_axes), check_vma=False)
        grads, loss, ce = inner_sm(params_c, batch)
        new_params, new_opt, stats = opt.update(
            grads, state.opt_state, state.params, state.step)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt_state=new_opt)
        return new_state, {"loss": loss, "ce": ce, **stats}

    abstract = abstract_state(cfg, tc)
    st_sh = state_shardings(abstract, mesh)
    return jax.jit(step_fn, in_shardings=(st_sh, batch_shardings),
                   out_shardings=(st_sh, None),
                   donate_argnums=(0,) if donate else ())


def train(cfg, tc, mesh, data_iter, *, ckpt_dir: str | None = None,
          log_every: int = 10, shutdown=None, watchdog=None,
          state: TrainState | None = None, start_step: int = 0):
    """Run the loop.  Returns (state, history).

    ``shutdown``: fault_tolerance.GracefulShutdown — checkpoint-and-exit
    on SIGTERM.  ``watchdog``: fault_tolerance.StepWatchdog — straggler
    detection.  Resume: pass ``state``/``start_step`` from
    fault_tolerance.resume_or_init.
    """
    from repro.checkpoint import CheckpointManager
    step_fn = make_train_step(cfg, tc, mesh)
    if state is None:
        state = init_state(cfg, tc)
        state = jax.device_put(state, state_shardings(state, mesh))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    b_sh = None
    history = []
    t_last = time.perf_counter()
    for step, batch in data_iter:
        if step >= tc.steps:
            break
        if b_sh is None and mesh is not None:
            from repro.configs.base import ShapeConfig
            shape = ShapeConfig("run", "train", batch["labels"].shape[1],
                                batch["labels"].shape[0])
            b_sh = batch_shardings(cfg, shape, mesh)
        batch = jax.device_put(batch, b_sh)
        state, metrics = step_fn(state, batch)
        if watchdog is not None:
            now = time.perf_counter()
            watchdog.record(now - t_last)
            t_last = now
        if step % log_every == 0 or step == tc.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            print(f"step {step:5d}  loss {m['loss']:.4f}  "
                  f"ce {m['ce']:.4f}  gnorm {m['grad_norm']:.3f}  "
                  f"lr {m['lr']:.2e}")
        want_ckpt = mgr and (step + 1) % tc.checkpoint_every == 0
        if shutdown is not None and shutdown.requested:
            print(f"SIGTERM: checkpointing at step {step + 1} and exiting")
            want_ckpt = bool(mgr)
        if want_ckpt:
            mgr.save(step + 1, state, metadata={"step": step + 1})
        if shutdown is not None and shutdown.requested:
            break
    if mgr:
        mgr.wait()
    return state, history
