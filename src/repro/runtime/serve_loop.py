"""Serving engine: the paper's deployment, generalized.

The paper's result is a *deployment* discipline: pack every constant
weight once at model load, then every prefill/decode call pays only the
compute loop.  ``Engine`` is that discipline as a class:

  * ``__init__`` — the untimed model-load phase: weights are packed
    (transpose/pad/layout, paper §3.2) and placed with their serving
    shardings; prefill and decode are jitted against the packed tree.
  * ``prefill`` / ``decode`` — per-call compute only; no pack, no
    resharding collective in the step HLO (asserted by the dry-run).
  * per-call mode (``packed=False``) keeps raw weights — the
    cblas/BNNSMatMul analogue the benchmarks compare against.

Batched requests run through a static-shape slot pool (continuous
batching lite): finished rows are refilled from the queue without
recompiling, since shapes never change.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import gemm as gemm_api
from repro.models import model_zoo, transformer
from repro.parallel import sharding as Sh


@dataclasses.dataclass
class GenStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def prefill_tps(self):
        return self.prefill_tokens / max(self.prefill_s, 1e-9)

    @property
    def decode_tps(self):
        return self.decode_tokens / max(self.decode_s, 1e-9)


class Engine:
    def __init__(self, cfg, params, *, mesh=None, max_len: int = 2048,
                 packed: bool = True, block_n: int | None = None,
                 block_k: int | None = None, donate_cache: bool = True,
                 backend: str | None = None):
        """``backend`` pins this engine's GEMM backend (a registry name
        from ``repro.gemm.list_backends()``); None keeps the process
        default.  The choice is scoped to this engine's traces — two
        engines with different backends coexist in one process, which the
        old ``REPRO_GEMM_IMPL`` process global could not express."""
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.packed = packed
        self.backend = backend
        if backend is not None:
            gemm_api.get_backend(backend)       # fail fast on a typo

        shard_fn = Sh.activation_sharder(mesh) if mesh is not None else None
        if packed:
            # ---- model load: pack once (lever 2). Untimed by protocol.
            shardings = None
            if mesh is not None:
                packed_abs = jax.eval_shape(
                    lambda p: model_zoo.pack_for_inference(
                        cfg, p, block_n=block_n, block_k=block_k), params)
                shardings = Sh.param_shardings(packed_abs, mesh)
            self.params = model_zoo.pack_for_inference(
                cfg, params, block_n=block_n, block_k=block_k,
                shardings=shardings)
        else:
            self.params = params
            if mesh is not None:
                self.params = jax.device_put(
                    params, Sh.param_shardings(params, mesh))

        # use_backend wraps the BODY, so it is active while jit traces the
        # step and every gemm plan inside resolves to this engine's backend
        def _prefill(params, inputs):
            with gemm_api.use_backend(backend):
                return transformer.prefill(cfg, params, inputs,
                                           max_len=max_len,
                                           shard_fn=shard_fn)

        def _decode(params, cache, tokens):
            with gemm_api.use_backend(backend):
                return transformer.decode_step(cfg, params, cache, tokens,
                                               shard_fn=shard_fn)

        donate = (1,) if donate_cache else ()
        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=donate)

    # ------------------------------------------------------------- prefill
    def prefill(self, inputs):
        """inputs: [B, S] int32 (or [B, S, d] stub embeddings).
        Returns (last_logits [B, V], cache)."""
        return self._prefill(self.params, inputs)

    def decode(self, cache, tokens):
        return self._decode(self.params, cache, tokens)

    # ------------------------------------------------------------ generate
    def generate(self, prompts, max_new_tokens: int, *,
                 greedy: bool = True, seed: int = 0,
                 stats: GenStats | None = None):
        """Greedy/sampled continuation.  prompts: [B, S0] int32.
        Returns tokens [B, max_new_tokens]."""
        stats = stats if stats is not None else GenStats()
        b, s0 = prompts.shape[0], prompts.shape[1]
        t0 = time.perf_counter()
        logits, cache = self.prefill(prompts)
        logits.block_until_ready()
        stats.prefill_s += time.perf_counter() - t0
        stats.prefill_tokens += b * s0

        key = jax.random.key(seed)
        out = []
        tok = self._pick(logits, key, greedy)
        out.append(tok)
        t0 = time.perf_counter()
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self.decode(cache, tok[:, None])
            tok = self._pick(logits, sub, greedy)
            out.append(tok)
        jax.block_until_ready(tok)
        stats.decode_s += time.perf_counter() - t0
        stats.decode_tokens += b * max(max_new_tokens - 1, 0)
        return jnp.stack(out, axis=1), stats

    @staticmethod
    def _pick(logits, key, greedy):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits).astype(jnp.int32)

    # ------------------------------------------- continuous batching lite
    def serve(self, requests: list[np.ndarray], *, batch_slots: int,
              prompt_len: int, max_new_tokens: int):
        """Slot-pool serving: static shapes, finished rows refilled.

        requests: list of int32 prompt arrays (padded/truncated to
        ``prompt_len``).  Returns list of generated-token arrays, one per
        request, and GenStats.
        """
        stats = GenStats()
        results: dict[int, np.ndarray] = {}
        queue = list(enumerate(requests))
        while queue:
            chunk = queue[:batch_slots]
            queue = queue[batch_slots:]
            ids = [i for i, _ in chunk]
            toks = np.zeros((batch_slots, prompt_len), np.int32)
            for r, (_, p) in enumerate(chunk):
                p = np.asarray(p, np.int32)[:prompt_len]
                toks[r, :len(p)] = p
            gen, stats = self.generate(jnp.asarray(toks), max_new_tokens,
                                       stats=stats)
            gen = np.asarray(gen)
            for r, i in enumerate(ids):
                results[i] = gen[r]
        return [results[i] for i in range(len(requests))], stats
