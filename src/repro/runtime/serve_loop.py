"""Serving engine: the paper's deployment, generalized.

The paper's result is a *deployment* discipline: pack every constant
weight once at model load, then every prefill/decode call pays only the
compute loop.  ``Engine`` is that discipline as a class:

  * ``__init__`` — the untimed model-load phase: weights are packed
    (transpose/pad/layout, paper §3.2) and placed with their serving
    shardings; prefill and decode are jitted against the packed tree.
  * ``prefill`` / ``decode`` — per-call compute only; no pack, no
    resharding collective in the step HLO (asserted by the dry-run).
  * per-call mode (``packed=False``) keeps raw weights — the
    cblas/BNNSMatMul analogue the benchmarks compare against.

Batched requests run through ``serve`` — real continuous batching
(runtime/batching): a static-shape slot pool whose finished rows are
refilled *mid-generation*, a paged KV cache so refills reuse freed
blocks, and chunked prefill admission interleaved with decode steps.
The legacy phase-locked loop survives as ``serve_chunked`` — the
baseline the serving benchmark measures against.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import gemm as gemm_api
from repro.models import model_zoo, transformer
from repro.obs import recorder as _flight
from repro.obs import spans as _spans
from repro.obs.timing import FencedTimer
from repro.parallel import sharding as Sh


@dataclasses.dataclass
class GenStats:
    """Token accounting for ``generate``/``serve_chunked``.

    ``prefill_tokens`` counts prompt tokens *processed*; ``decode_tokens``
    counts tokens *emitted* — ``rows x max_new_tokens`` for ``generate``
    (the prefill-sampled first token included: generate emits
    ``max_new_tokens`` per row, not ``max_new_tokens - 1``).  Both count
    only live, non-pad tokens when accumulated by ``serve_chunked``.
    ``fused`` records whether the engine ran the horizontally fused
    QKV / gate-up GEMM path (None: raw-weight engine, fusion n/a);
    ``quant`` the engine's quantized weight format (None: fp32).

    GEMM-dispatch observability (the previously-invisible plan churn):
    ``plan_cache`` snapshots ``gemm.plan_cache_info()`` after the run —
    (hits, misses, maxsize, currsize) — and ``vmem_clamped_plans``
    counts cached plans whose blocks the policy shrank to fit the
    kernel VMEM budget.  ``plan_store`` snapshots the engine's
    persistent plan store counters (``gemm.StoreInfo``: store hits /
    misses / autotuned entries / total entries; None when the engine
    runs without a store) — warm-start observability: a second process
    booting from a populated store shows ``hits == plans needed`` and
    zero autotune/gate work.
    """
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    fused: bool | None = None
    quant: str | None = None
    # sparse-ternary pack observability: mean occupied-group fraction
    # across the engine's quantized packs (None: no quantized packs) and
    # how many crossed to the compressed zero-group layout
    quant_density: float | None = None
    quant_sparse_packs: int = 0
    plan_cache: tuple | None = None
    vmem_clamped_plans: int = 0
    plan_store: tuple | None = None

    @property
    def prefill_tps(self):
        return self.prefill_tokens / max(self.prefill_s, 1e-9)

    @property
    def decode_tps(self):
        return self.decode_tokens / max(self.decode_s, 1e-9)


class Engine:
    def __init__(self, cfg, params, *, mesh=None, max_len: int = 2048,
                 packed: bool = True, block_n: int | None = None,
                 block_k: int | None = None, donate_cache: bool = True,
                 backend: str | None = None, fuse: bool = True,
                 quant: str | None = None,
                 keep_fp32=("head", "embed"),
                 plan_store=None):
        """``backend`` pins this engine's GEMM backend (a registry name
        from ``repro.gemm.list_backends()``); None keeps the process
        default.  The choice is scoped to this engine's traces — two
        engines with different backends coexist in one process, which the
        old ``REPRO_GEMM_IMPL`` process global could not express.

        ``plan_store`` (a ``gemm.PlanStore`` or a path, loaded
        corruption-tolerantly) scopes a PERSISTENT plan store over this
        engine's pack, trace and warmup paths: every plan they resolve
        is looked up in the store first (a populated store makes a
        fresh process start hot — no analytic re-resolution, no
        bit-exactness gate re-runs, measured-autotuned winners adopted)
        and recorded back on a miss.  The caller persists with
        ``engine.plan_store.save()`` (``launch/serve --plan-store``
        does this at exit); ``launch/autotune`` pre-populates one.

        ``fuse`` (default on) packs same-input projection groups
        horizontally at load — Q/K/V and gate+up each become one fused
        GEMM with an in-kernel epilogue — cutting >= 2 GEMM dispatches
        (and as many re-reads of the activations) per transformer block.
        ``fuse=False`` is the A/B escape hatch; it only applies to the
        packed path (raw engines always run unfused).

        ``quant`` ("int8" | "ternary") serves the model on QUANTIZED
        packed weights (repro.quant): every projection quantizes at load
        except the ``keep_fp32`` roles (default: LM head + embeddings),
        GEMMs run the dequant-fused path, and the error ledger
        tolerance-gates each pack.  Requires ``packed=True``.
        """
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.packed = packed
        self.backend = backend
        self.fused = bool(packed and fuse)
        self.quant = quant
        self.plan_store = gemm_api.as_plan_store(plan_store)
        store = self.plan_store            # closed over by the step defs
        if backend is not None:
            gemm_api.get_backend(backend)       # fail fast on a typo
        if quant is not None and not packed:
            raise ValueError("quant= is a pack-time format; it requires "
                             "packed=True")

        shard_fn = Sh.activation_sharder(mesh) if mesh is not None else None
        if packed:
            # ---- model load: pack once (lever 2). Untimed by protocol.
            # The pack-time plan resolutions (pack_blocks per weight)
            # run under the engine's plan store, so a populated store
            # hands back its (possibly measured-autotuned) blocks.
            shardings = None
            with gemm_api.use_plan_store(store):
                if mesh is not None:
                    packed_abs = jax.eval_shape(
                        lambda p: model_zoo.pack_for_inference(
                            cfg, p, block_n=block_n, block_k=block_k,
                            fuse=fuse, quant=quant, keep_fp32=keep_fp32),
                        params)
                    shardings = Sh.param_shardings(packed_abs, mesh)
                self.params = model_zoo.pack_for_inference(
                    cfg, params, block_n=block_n, block_k=block_k,
                    shardings=shardings, fuse=fuse, quant=quant,
                    keep_fp32=keep_fp32)
        else:
            self.params = params
            if mesh is not None:
                self.params = jax.device_put(
                    params, Sh.param_shardings(params, mesh))

        # use_backend wraps the BODY, so it is active while jit traces the
        # step and every gemm plan inside resolves to this engine's backend.
        # Decode bodies additionally trace inside gemm.decode_lane(): every
        # plan they resolve takes the decode policy arm (skinny block_m,
        # forced prepack, split-K scored) and is plan-keyed apart from the
        # prefill plans of the same shapes.  Prefill traces never enter the
        # lane, so their plans and numerics are untouched.
        # obs.manifest_scope wraps each jitted body like use_backend
        # does: the body runs at TRACE time, so every gemm.execute the
        # step dispatches registers its plan under the step's manifest
        # key exactly once per compilation — the flight recorder's
        # answer to "which GEMMs does this step run", with zero
        # per-dispatch cost (docs/observability.md).
        def _prefill(params, inputs):
            with gemm_api.use_backend(backend), \
                    gemm_api.use_plan_store(store), \
                    _flight.manifest_scope(
                        f"prefill_m{inputs.shape[0] * inputs.shape[1]}"):
                return transformer.prefill(cfg, params, inputs,
                                           max_len=max_len,
                                           shard_fn=shard_fn)

        def _decode(params, cache, tokens):
            with gemm_api.use_backend(backend), gemm_api.decode_lane(), \
                    gemm_api.use_plan_store(store), \
                    _flight.manifest_scope("decode"):
                return transformer.decode_step(cfg, params, cache, tokens,
                                               shard_fn=shard_fn)

        donate = (1,) if donate_cache else ()
        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=donate)

        # ---- continuous-batching steps against the paged cache.  One
        # trace each (shapes are static: [1, C] chunks, [slots, 1]
        # decode), so the GEMM plans they resolve are resolved exactly
        # once — the "plans stay hot" property tests/test_serving.py
        # asserts via plan_cache_info().
        # Greedy selection runs INSIDE the jit (same argmax the host-side
        # _pick applies, so tokens stay bit-identical) — each scheduler
        # tick is then a single device dispatch, which is what lets the
        # pool's decode pipeline match generate's device-side loop.
        # The builder is parameterized on the GEMM backend so the
        # scheduler's degradation ladder can ask for a SECOND step set
        # traced against the ``xla`` reference backend (built lazily on
        # first fallback — see ``_paged_steps``); every registered
        # backend passes the same bit-exactness gate, so a fallback
        # dispatch is token-identical to the primary.
        def _build_paged_steps(step_backend):
            def _paged_prefill(params, pages, page_table, lens, tokens,
                               logit_index, *, page_size):
                with gemm_api.use_backend(step_backend), \
                        gemm_api.use_plan_store(store), \
                        _flight.manifest_scope(
                            f"prefill_chunk_m{tokens.shape[1]}"):
                    cache = {"layers": pages, "page_table": page_table,
                             "lens": lens}
                    logits, cache = transformer.prefill_chunk(
                        cfg, params, cache, tokens, page_size=page_size,
                        logit_index=logit_index, shard_fn=shard_fn)
                    tok = jnp.argmax(logits[0]).astype(jnp.int32)
                    return tok, cache["layers"]

            def _decode_tick(params, pages, page_table, lens, write_mask,
                             last_tokens, *, page_size):
                """One pool decode tick: the SINGLE definition both the
                per-tick step and the megastep body trace, so a megastep
                of depth D is bit-identical to D per-tick dispatches."""
                cache = {"layers": pages, "page_table": page_table,
                         "lens": lens, "write_mask": write_mask}
                logits, cache = transformer.paged_decode_step(
                    cfg, params, cache, last_tokens[:, None],
                    page_size=page_size, shard_fn=shard_fn)
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                # masked rows (idle / still prefilling) keep their token
                new_last = jnp.where(write_mask, toks, last_tokens)
                return new_last, cache["layers"]

            def _paged_decode(params, pages, page_table, lens, write_mask,
                              last_tokens, *, page_size):
                with gemm_api.use_backend(step_backend), \
                        gemm_api.decode_lane(), \
                        gemm_api.use_plan_store(store), \
                        _flight.manifest_scope("decode_step"):
                    return _decode_tick(params, pages, page_table, lens,
                                        write_mask, last_tokens,
                                        page_size=page_size)

            def _paged_megastep(params, pages, page_table, lens,
                                write_mask, last_tokens, n_ticks, *,
                                page_size, max_depth):
                """The fused decode megastep: up to ``max_depth`` decode
                ticks — greedy argmax, paged KV write and next-token
                embed each tick — inside ONE jitted ``lax.fori_loop``,
                so the host dispatches (and syncs) once per ``n_ticks``
                tokens per slot instead of once per token.  ``n_ticks``
                is a TRACED operand (the while-loop trip count), so one
                compilation serves every drain depth 1..max_depth.
                Per-slot lengths advance device-side
                (``lens + t * write_mask``); the scheduler pre-allocates
                the pages the D ticks will write.  Returns (last tokens,
                [max_depth, slots] token history — rows past ``n_ticks``
                are zeros the host never reads, pages).
                """
                with gemm_api.use_backend(step_backend), \
                        gemm_api.decode_lane(), \
                        gemm_api.use_plan_store(store), \
                        _flight.manifest_scope("decode_step"):
                    hist0 = jnp.zeros((max_depth, last_tokens.shape[0]),
                                      jnp.int32)
                    step = write_mask.astype(jnp.int32)

                    def body(t, carry):
                        last, pages, hist = carry
                        last, pages = _decode_tick(
                            params, pages, page_table, lens + t * step,
                            write_mask, last, page_size=page_size)
                        hist = jax.lax.dynamic_update_index_in_dim(
                            hist, last, t, 0)
                        return last, pages, hist

                    last, pages, hist = jax.lax.fori_loop(
                        0, n_ticks, body, (last_tokens, pages, hist0))
                    return last, hist, pages

            return {
                "prefill": jax.jit(_paged_prefill, donate_argnums=donate,
                                   static_argnames=("page_size",)),
                "decode": jax.jit(_paged_decode, donate_argnums=donate,
                                  static_argnames=("page_size",)),
                "megastep": jax.jit(
                    _paged_megastep, donate_argnums=donate,
                    static_argnames=("page_size", "max_depth")),
            }

        self._build_paged = _build_paged_steps
        self._paged = _build_paged_steps(backend)
        self._paged_fb = None           # lazy xla fallback step set

    # ------------------------------------------------------------- prefill
    def prefill(self, inputs):
        """inputs: [B, S] int32 (or [B, S, d] stub embeddings).
        Returns (last_logits [B, V], cache)."""
        return self._prefill(self.params, inputs)

    def decode(self, cache, tokens):
        return self._decode(self.params, cache, tokens)

    # ----------------------------------------- paged steps (slot pool)
    # The scheduler's dispatch degradation ladder (batching._guarded)
    # keys off this flag: after a retry on the primary backend fails, it
    # re-dispatches once with ``fallback=True``, which routes through a
    # step set traced against the ``xla`` reference backend.  Bit-exact
    # by the backend gate, so survivors of a backend fault keep
    # token-identical outputs.
    supports_fallback = True

    def _paged_steps(self, fallback: bool):
        if not fallback:
            return self._paged
        if self._paged_fb is None:
            # the primary set IS the xla set when this engine already
            # pins xla; otherwise trace a fresh set against it lazily
            # (first fallback dispatch pays the trace/compile, later
            # ones reuse it)
            self._paged_fb = (self._paged if self.backend == "xla"
                              else self._build_paged("xla"))
        return self._paged_fb

    def prefill_chunk(self, pages, page_table, lens, tokens, logit_index,
                      *, page_size: int, fallback: bool = False):
        """One chunked-prefill admission step: write ``tokens`` [1, C]
        into one slot's pages at its current length.  Returns
        (greedy token for chunk row ``logit_index`` — the prompt's last
        real row on the final chunk — as a device scalar, pages).
        ``fallback=True`` dispatches the xla-backend step set."""
        return self._paged_steps(fallback)["prefill"](
            self.params, pages, page_table, lens, tokens, logit_index,
            page_size=page_size)

    def decode_step(self, pages, page_table, lens, write_mask,
                    last_tokens, *, page_size: int,
                    fallback: bool = False):
        """One decode step for the whole pool: feeds ``last_tokens``
        [slots] back through the model at per-slot lengths, write-masked
        so idle / still-prefilling slots touch nothing.  Returns
        (next last_tokens [slots] — masked rows unchanged, pages)."""
        return self._paged_steps(fallback)["decode"](
            self.params, pages, page_table, lens, write_mask,
            last_tokens, page_size=page_size)

    def decode_megastep(self, pages, page_table, lens, write_mask,
                        last_tokens, n_ticks: int, *, page_size: int,
                        max_depth: int, fallback: bool = False):
        """``n_ticks`` decode ticks for the whole pool in ONE device
        dispatch (jitted ``lax.fori_loop`` — greedy argmax + paged KV
        write + next-token embed per tick).  The caller must have
        pre-allocated each live slot's pages for ``n_ticks`` more
        tokens; ``n_ticks`` is traced (one compile per ``max_depth``),
        and every tick is bit-identical to a ``decode_step`` dispatch.
        Returns (last tokens [slots], token history [max_depth, slots]
        — rows past ``n_ticks`` are zeros, pages)."""
        return self._paged_steps(fallback)["megastep"](
            self.params, pages, page_table, lens, write_mask,
            last_tokens, jnp.asarray(n_ticks, jnp.int32),
            page_size=page_size, max_depth=max_depth)

    # ------------------------------------------------------- plan warmup
    def warmup_plans(self, *, batch_slots: int, prefill_chunk: int = 32,
                     page_size: int = 16, num_pages: int | None = None,
                     megastep_depth: int = 1) -> dict:
        """Pre-populate the plan cache AND the jit executable cache for
        a serving configuration, so the first tick of the first request
        pays no trace/plan/gate/compile latency.

        Two layers of warmup: (1) the paged serving steps — the
        chunked-prefill step at the ``bucket_m(prefill_chunk)``
        admission width AND at every chunk-tail bucket below it (the
        widths the scheduler's bucketed final/divergent chunks emit —
        a prefix-cache hit starts prefill mid-prompt at arbitrary
        offsets, so every tail bucket is reachable), the ``[slots, 1]``
        decode step, and the megastep when ``megastep_depth > 1`` —
        each driven once, which
        resolves EVERY GEMM plan the configured serving geometry
        dispatches (epilogue-carrying plans included, since the real
        layers trace) and compiles the step executables: the first
        serving tick then pays no trace/plan/compile latency, and
        ``plan_cache_info().misses`` stays flat from the first request
        (asserted in tests/test_decode_lane.py).  (2) A best-effort
        decode-lane plan sweep over every packed weight at each
        ``gemm.DECODE_M_BUCKETS`` width, pre-resolving the PLAIN
        (epilogue-free) decode plans — fused-QKV and attention/output
        projections — for pools and ``generate`` batches of other
        bucketed widths <= 8.  Epilogue-carrying plans at those other
        widths (glu gate-up, fused-residual down-projection, softcap
        head) still resolve on their first dispatch there, as does each
        new shape's jit compile.  The pool geometry must match the
        later ``serve`` call (``num_pages=None`` = the dense-equivalent
        default).  Returns ``{step name: compile seconds}`` plus
        ``decode_bucket_plans`` (count pre-resolved) and a
        ``plan_cache`` snapshot.
        """
        if self.cfg.modality != "text":
            raise NotImplementedError("warmup covers the token-serving "
                                      "paged steps")
        from repro.runtime import kv_cache as KV
        chunk = gemm_api.bucket_m(prefill_chunk)
        n_pages = (num_pages if num_pages is not None
                   else batch_slots * (self.max_len // page_size))
        # dummy pool, driven through the REAL call path: AOT
        # lower().compile() does not seed the executables the call path
        # uses, so warmup dispatches each step once on zeros (page
        # tables all -1: every KV write drops, outputs are discarded;
        # the dummy pages are donated away step to step)
        pages = {
            name: jnp.zeros(
                (self.cfg.num_layers, n_pages, page_size, *feat), dtype)
            for name, (feat, dtype) in KV.leaf_specs_for(self.cfg).items()}
        pps = self.max_len // page_size
        i32 = jnp.int32
        timings = {}
        # admission width PLUS every chunk-tail bucket below it: the
        # scheduler dispatches a prompt's final chunk — and the whole
        # divergent remainder after a prefix-cache hit, which starts
        # mid-prompt at an arbitrary offset — at gemm.bucket_m(rem), so
        # the tail widths the pool can emit are exactly the bucket
        # ladder <= chunk.  Driving each once keeps chunk_plan_misses
        # at 0 with the prefix cache on (benchmarks/table10_prefix.py).
        widths = [b for b in gemm_api.PREFILL_M_BUCKETS if b < chunk]
        for w in widths + [chunk]:
            t0 = time.perf_counter()
            tok, pages = self.prefill_chunk(
                pages, jnp.full((1, pps), -1, i32), jnp.zeros((1,), i32),
                jnp.zeros((1, w), i32), jnp.asarray(0, i32),
                page_size=page_size)
            jax.block_until_ready(tok)
            key = ("prefill_chunk" if w == chunk
                   else f"prefill_chunk_m{w}")
            timings[key] = time.perf_counter() - t0
        table = jnp.full((batch_slots, pps), -1, i32)
        lens = jnp.zeros((batch_slots,), i32)
        mask = jnp.zeros((batch_slots,), bool)
        last = jnp.zeros((batch_slots,), i32)
        t0 = time.perf_counter()
        last, pages = self.decode_step(pages, table, lens, mask, last,
                                       page_size=page_size)
        jax.block_until_ready(last)
        timings["decode_step"] = time.perf_counter() - t0
        if megastep_depth > 1:
            t0 = time.perf_counter()
            last, _, pages = self.decode_megastep(
                pages, table, lens, mask, last, 1, page_size=page_size,
                max_depth=megastep_depth)
            jax.block_until_ready(last)
            timings["decode_megastep"] = time.perf_counter() - t0
        del pages
        # decode-bucket plan ladder: pre-resolve the decode-lane plan of
        # every packed weight at each bucket width
        from repro.core.packing import PackedWeight
        packs = [leaf for leaf in jax.tree.leaves(
            self.params,
            is_leaf=lambda x: isinstance(x, PackedWeight))
            if isinstance(leaf, PackedWeight)]
        n_plans = 0
        with gemm_api.use_backend(self.backend), \
                gemm_api.use_plan_store(self.plan_store):
            for bucket in gemm_api.DECODE_M_BUCKETS:
                for pw in packs:
                    gemm_api.plan_for_packed(bucket, pw, decode=True)
                    n_plans += 1
        timings["decode_bucket_plans"] = n_plans
        timings["plan_cache"] = gemm_api.plan_cache_info()
        if self.plan_store is not None:
            timings["plan_store"] = self.plan_store.info()
        return timings

    def _quant_pack_stats(self):
        """(mean occupied-group density, sparse pack count) over the
        engine's quantized packs — the ServeStats/GenStats quant area."""
        if not (self.packed and self.quant):
            return None, 0
        from repro.quant.formats import (QuantizedPackedWeight,
                                         SparseTernaryPackedWeight)
        packs = [leaf for leaf in jax.tree.leaves(
            self.params,
            is_leaf=lambda x: isinstance(x, QuantizedPackedWeight))
            if isinstance(leaf, QuantizedPackedWeight)]
        if not packs:
            return None, 0
        dens = [float(getattr(q, "density", 1.0)) for q in packs]
        sparse = sum(1 for q in packs
                     if isinstance(q, SparseTernaryPackedWeight))
        return sum(dens) / len(dens), sparse

    # ------------------------------------------------------------ generate
    def generate(self, prompts, max_new_tokens: int, *,
                 greedy: bool = True, seed: int = 0,
                 stats: GenStats | None = None):
        """Greedy/sampled continuation.  prompts: [B, S0] int32.
        Returns tokens [B, max_new_tokens]."""
        stats = stats if stats is not None else GenStats()
        stats.fused = self.fused if self.packed else None
        stats.quant = self.quant if self.packed else None
        stats.quant_density, stats.quant_sparse_packs = \
            self._quant_pack_stats()
        b, s0 = prompts.shape[0], prompts.shape[1]
        # phase timing through the obs fenced timer: both phases fence
        # (generate's numbers were always execution times — the fence
        # here is the same block_until_ready the bare pairs used to
        # wrap, now attributed explicitly; see docs/observability.md)
        with _spans.span("generate_prefill", step=f"prefill_m{b * s0}",
                         rows=b, tokens=b * s0), \
                FencedTimer(fence=True) as t:
            logits, cache = self.prefill(prompts)
            t.fence(logits)
        stats.prefill_s += t.elapsed_s
        stats.prefill_tokens += b * s0

        key = jax.random.key(seed)
        out = []
        tok = self._pick(logits, key, greedy)
        out.append(tok)
        with _spans.span("generate_decode", step="decode", rows=b,
                         ticks=max_new_tokens - 1), \
                FencedTimer(fence=True) as t:
            for i in range(max_new_tokens - 1):
                key, sub = jax.random.split(key)
                logits, cache = self.decode(cache, tok[:, None])
                tok = self._pick(logits, sub, greedy)
                out.append(tok)
            t.fence(tok)
        stats.decode_s += t.elapsed_s
        stats.decode_tokens += b * max_new_tokens      # emitted per row
        stats.plan_cache = gemm_api.plan_cache_info()
        stats.vmem_clamped_plans = gemm_api.vmem_clamped_count()
        if self.plan_store is not None:
            stats.plan_store = self.plan_store.info()
        return jnp.stack(out, axis=1), stats

    @staticmethod
    def _pick(logits, key, greedy):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits).astype(jnp.int32)

    # ------------------------------------------------ continuous batching
    def serve(self, requests: list[np.ndarray], *, batch_slots: int,
              max_new_tokens, prefill_chunk: int = 32,
              page_size: int = 16, num_pages: int | None = None,
              check_invariants: bool = False,
              sync_per_step: bool = False, megastep_depth: int = 1,
              prefix_cache: bool = False,
              watchdog_factor: float | None = None, shutdown=None,
              ttft_budget_s=None, total_budget_s=None):
        """Real continuous batching (greedy): slot refill mid-generation,
        paged KV cache, chunked prefill admission — runtime/batching.

        requests: list of int32 prompt arrays, served at their true
        lengths (no padding to a global prompt_len).  max_new_tokens:
        int or per-request sequence.  ``megastep_depth`` > 1 drains
        decode through the fused megastep (up to D device-side ticks
        per host dispatch).  ``prefix_cache=True`` turns on the
        cross-request prefix cache (runtime/prefix_cache): requests
        whose prompts share a cached prefix skip straight to the
        divergent token, reusing refcounted KV pages (COW-forked at
        the divergence page); ``ServeStats.prefix`` carries the
        hit/evict/COW counters.  Returns (list of generated-token
        arrays in request order — None for requests that ended in a
        non-DONE terminal state, whose ``RequestOutcome`` lives in
        ``stats.outcomes`` — and batching.ServeStats).  Outputs are
        bit-identical to per-request greedy ``generate`` at every
        megastep depth, with the cache on or off.

        Fault-isolation knobs (docs/serving.md "Failure model"):
        ``watchdog_factor`` arms the straggler watchdog over scheduler
        ticks; ``shutdown`` (a ``GracefulShutdown``) drains the run on
        SIGTERM; ``ttft_budget_s`` / ``total_budget_s`` set per-request
        deadlines (scalar or per-request sequence, enforced at tick
        boundaries — missed deadlines end TIMED_OUT, not raised).
        """
        from repro.runtime.batching import ContinuousBatchingScheduler
        sched = ContinuousBatchingScheduler(
            self, batch_slots=batch_slots, prefill_chunk=prefill_chunk,
            page_size=page_size, num_pages=num_pages,
            check_invariants=check_invariants,
            sync_per_step=sync_per_step, megastep_depth=megastep_depth,
            prefix_cache=prefix_cache, watchdog_factor=watchdog_factor,
            shutdown=shutdown)
        outs, stats = sched.run(requests, max_new_tokens,
                                ttft_budget_s=ttft_budget_s,
                                total_budget_s=total_budget_s)
        stats.fused = self.fused if self.packed else None
        stats.quant = self.quant if self.packed else None
        stats.quant_density, stats.quant_sparse_packs = \
            self._quant_pack_stats()
        stats.plan_cache = gemm_api.plan_cache_info()
        stats.vmem_clamped_plans = gemm_api.vmem_clamped_count()
        if self.plan_store is not None:
            stats.plan_store = self.plan_store.info()
        return outs, stats

    # -------------------------------------- legacy phase-locked baseline
    def serve_chunked(self, requests: list[np.ndarray], *,
                      batch_slots: int, prompt_len: int, max_new_tokens):
        """The old "continuous batching lite": sequential static batches
        where every slot waits for the chunk's slowest request.  Kept as
        the baseline benchmarks/serving_mixed_lengths.py measures the
        real scheduler against.

        requests are padded/truncated to ``prompt_len``; max_new_tokens
        may be per-request (each chunk then runs its max, and the extra
        tokens of early finishers are wasted occupancy — exactly the
        failure mode ``serve`` removes).  Stats count only live-slot,
        non-pad tokens.
        """
        n = len(requests)
        mn = ([int(max_new_tokens)] * n if np.isscalar(max_new_tokens)
              else [int(m) for m in max_new_tokens])
        stats = GenStats(fused=self.fused if self.packed else None,
                         quant=self.quant if self.packed else None)
        stats.quant_density, stats.quant_sparse_packs = \
            self._quant_pack_stats()
        results: dict[int, np.ndarray] = {}
        queue = list(enumerate(requests))
        while queue:
            chunk = queue[:batch_slots]
            queue = queue[batch_slots:]
            ids = [i for i, _ in chunk]
            step_new = max(mn[i] for i in ids)
            toks = np.zeros((batch_slots, prompt_len), np.int32)
            for r, (_, p) in enumerate(chunk):
                p = np.asarray(p, np.int32)[:prompt_len]
                toks[r, :len(p)] = p
            gen, s = self.generate(jnp.asarray(toks), step_new)
            stats.prefill_s += s.prefill_s
            stats.decode_s += s.decode_s
            # live-slot, non-pad accounting: dead rows (len(chunk) <
            # batch_slots), prompt padding, and over-generation past a
            # request's own max_new all count nothing
            stats.prefill_tokens += sum(
                min(len(np.asarray(requests[i])), prompt_len) for i in ids)
            stats.decode_tokens += sum(mn[i] for i in ids)
            gen = np.asarray(gen)
            for r, i in enumerate(ids):
                results[i] = gen[r, :mn[i]]
        stats.plan_cache = gemm_api.plan_cache_info()
        stats.vmem_clamped_plans = gemm_api.vmem_clamped_count()
        if self.plan_store is not None:
            stats.plan_store = self.plan_store.info()
        return [results[i] for i in range(len(requests))], stats
