"""Panel-granularity scheduling model — paper lever 1, TPU form.

The paper's Fig. 2: a single mis-tuned column-panel width (Nc = 512 vs 64)
costs ~2x because coarse panels (a) leave the second AMX block idle and
(b) blow the shared-L2 footprint.  The TPU analogues this model scores:

  * grid occupancy  — the Pallas grid over (M/bm, N/bn) output panels must
    expose enough parallel work per core; a tail of partially-filled cores
    is idle MXU time.  (v5e has one TensorCore per chip; across the mesh,
    the same arithmetic applies to N-shards per chip.)
  * VMEM footprint  — the (bm,bk)+(bk,bn) working set must fit VMEM with
    double buffering (the 128 KB L1 constraint of the paper, scaled).
  * HBM re-reads    — panel width sets operand reuse: x is re-read
    ceil(N/bn) times, w ceil(M/bm) times.  Coarse panels reduce re-reads
    but starve occupancy; the sweet spot is the sweep's job.

Pure napkin-math: every number here is derivable before lowering, and the
autotuner (core/autotune.py) uses the predicted time to rank candidates —
then gates on bit-exactness, exactly like the paper's offline sweep.
"""
from __future__ import annotations

import dataclasses
import math

from repro.kernels.panel_gemm import VMEM_BUDGET, vmem_bytes

# TPU v5e hardware constants (same as roofline/analysis.py).
PEAK_FLOPS = 197e12          # bf16; fp32 through the MXU is ~1/2, see below
PEAK_FLOPS_F32 = 98.5e12
HBM_BW = 819e9               # bytes/s
MXU_LANE = 128
GRID_STEP_OVERHEAD = 1e-8    # s per Pallas grid step (issue/semaphore)


@dataclasses.dataclass(frozen=True)
class PanelPlan:
    block_m: int
    block_n: int
    block_k: int
    grid: tuple[int, int, int]
    panels: int                 # parallel (i, j[, s]) output panels
    vmem: int
    vmem_ok: bool
    aligned: bool               # MXU 128-lane alignment
    hbm_bytes: float            # modeled HBM traffic incl. panel re-reads
    t_compute: float            # s
    t_memory: float             # s
    t_pred: float               # max(compute, memory) / occupancy
    occupancy: float            # parallel-panel tail utilization
    split_k: int = 1


def plan(m: int, n: int, k: int, *, block_m: int, block_n: int,
         block_k: int, dtype_bytes: int = 4, num_cores: int = 1,
         peak_flops: float = PEAK_FLOPS_F32, split_k: int = 1,
         weight_density: float = 1.0,
         sparse_index_bytes: float = 0.0) -> PanelPlan:
    """``split_k > 1`` scores the decode lane's reduction-side panels:
    the grid gains ``split_k`` parallel K slices per output panel
    (occupancy restored where a skinny M exposes almost none), paid for
    by the combine epilogue — ``split_k`` fp32 partials written and
    re-read plus ``split_k - 1`` panel adds.  The decode policy arm
    picks the candidate whose predicted time wins (paper Fig. 2's
    sweep, applied to the K dimension).

    ``weight_density`` scores the sparse-ternary arm: the kernel
    streams (and multiplies) only the occupied K-group fraction, so the
    weight-side HBM term, the compute term, and the K-grid depth scale
    by it; ``sparse_index_bytes`` adds the occupancy-bitmap +
    group-offset slab the sparse walk reads once per dispatch — the
    overhead side of ``gemm.policy.sparse_threshold``'s break-even."""
    gm, gn, gk = (math.ceil(m / block_m), math.ceil(n / block_n),
                  math.ceil(k / block_k))
    if weight_density < 1.0:
        gk = max(1, math.ceil(gk * weight_density))
    panels = gm * gn * split_k
    # tail utilization: last wave of panels may underfill the cores
    waves = math.ceil(panels / num_cores)
    occ = panels / (waves * num_cores)
    vm = vmem_bytes(block_m, block_n, block_k, split_k=split_k)
    # HBM traffic: x re-read per column panel, w re-read per row panel.
    hbm = dtype_bytes * (m * k * gn + weight_density * k * n * gm
                         + 2 * m * n) + sparse_index_bytes
    t_c = 2.0 * m * n * k * weight_density / (peak_flops * num_cores)
    if split_k > 1:
        # combine cost: the partials slab round-trips HBM once, and the
        # tree adds are extra (cheap) vector work
        hbm += 2.0 * 4 * split_k * m * n
        t_c += 2.0 * (split_k - 1) * m * n / (peak_flops * num_cores)
    t_m = hbm / (HBM_BW * num_cores)
    aligned = (block_m % 8 == 0 and block_n % MXU_LANE == 0
               and block_k % MXU_LANE == 0)
    # per-grid-step issue overhead: the paper's deeper-Kc preference
    # (fewer accumulator passes); small, mostly a tiebreak.
    t_o = GRID_STEP_OVERHEAD * gm * gn * gk / num_cores
    t = (max(t_c, t_m) + t_o) / max(occ, 1e-9)
    if not aligned:
        t *= 4.0        # unaligned tiles waste MXU lanes; heavy penalty
    if vm > VMEM_BUDGET:
        t = float("inf")
    return PanelPlan(block_m, block_n, block_k, (gm, gn, gk), panels, vm,
                     vm <= VMEM_BUDGET, aligned, hbm, t_c, t_m, t, occ,
                     split_k)


def mesh_panels(n: int, model_shards: int, block_n: int) -> dict:
    """Distributed form of lever 1: N-panels per model shard.

    The all-gather<->matmul overlap (parallel/collectives.py) decomposes the
    GEMM into `model_shards` panels; each must itself contain >= 1 kernel
    panel or the overlap serializes — the paper's 'coarse panel reaches only
    one block' failure, at mesh scale.
    """
    per_shard = n // model_shards
    return {
        "n_per_shard": per_shard,
        "kernel_panels_per_shard": per_shard // block_n,
        "overlap_feasible": per_shard >= block_n,
    }
