"""DEPRECATED — legacy GEMM entry points, now thin shims over
:mod:`repro.gemm` (plan/execute).  **Migration note.**

This module used to BE the GEMM surface: three unrelated functions
steered by a process-global ``REPRO_GEMM_IMPL`` env var, which meant no
caller could express the paper's shape-resolved lever choice.  That
surface moved to ``repro.gemm`` in the plan/execute redesign
(``docs/gemm_api.md``); the names below keep working for one release and
will then be removed:

  ==============================  =========================================
  legacy call                     replacement
  ==============================  =========================================
  ``gemm(x, pw)``                 ``p = gemm.plan_for_packed(m, pw)`` then
                                  ``gemm.execute(p, x, pw)``
  ``gemm_percall(x, w, ...)``     ``p = gemm.plan(m, n, k,
                                  pack=gemm.PACK_PERCALL, ...)`` then
                                  ``gemm.execute(p, x, w)``
  ``gemm_xla(x, w)``              ``p = gemm.plan(m, n, k, backend="xla",
                                  pack=gemm.PACK_NONE)`` then
                                  ``gemm.execute(p, x, w)``
  ``impl="..."`` keyword          ``backend="..."`` at plan time, or a
                                  ``gemm.use_backend("...")`` scope
  ``REPRO_GEMM_IMPL`` env var     honoured ONLY by these shims (the single
                                  remaining reader); the new surface takes
                                  backends explicitly / by scope
  ==============================  =========================================

Every shim resolves a plan through the same policy + LRU cache as native
callers, so results (including bit-exactness vs ``kernels/ref``) are
identical to the new API by construction.
"""
from __future__ import annotations

import os
import warnings

import jax

from repro import gemm as _G
from repro.core import packing
from repro.kernels import panel_gemm as _kernel


def _warn(old: str, new: str):
    warnings.warn(
        f"repro.core.panel_gemm.{old} is deprecated; use {new} "
        f"(see docs/gemm_api.md)", DeprecationWarning, stacklevel=3)


def _legacy_backend(impl: str | None) -> str | None:
    """impl kwarg, else the deprecated env var, else the new-API default.

    This is deliberately the ONLY place left that reads REPRO_GEMM_IMPL.
    """
    return impl or os.environ.get("REPRO_GEMM_IMPL") or None


def _lead_m(x: jax.Array) -> int:
    return _G.lead_m(x)     # resolved lazily: repro.gemm may still be
                            # mid-import when this module loads (cycle)


def gemm(x: jax.Array, pw: packing.PackedWeight, *,
         block_m: int = _kernel.DEFAULT_BLOCK_M,
         impl: str | None = None, out_dtype=None) -> jax.Array:
    """DEPRECATED: pre-packed GEMM.  Delegates to plan/execute."""
    _warn("gemm", "gemm.plan_for_packed + gemm.execute")
    p = _G.plan(_lead_m(x), pw.n, pw.k, dtype=x.dtype,
                backend=_legacy_backend(impl), block_m=block_m,
                block_n=pw.block_n, block_k=pw.block_k,
                pack=_G.PACK_PREPACKED)
    return _G.execute(p, x, pw, out_dtype=out_dtype)


def gemm_percall(x: jax.Array, w: jax.Array, *, transposed: bool = False,
                 block_m: int = _kernel.DEFAULT_BLOCK_M,
                 block_n: int = _kernel.DEFAULT_BLOCK_N,
                 block_k: int = _kernel.DEFAULT_BLOCK_K,
                 impl: str | None = None, out_dtype=None) -> jax.Array:
    """DEPRECATED: stateless pack-every-call GEMM.  Delegates to
    plan/execute with ``pack=PACK_PERCALL``."""
    _warn("gemm_percall", "gemm.plan(..., pack=PACK_PERCALL) + gemm.execute")
    n = w.shape[0] if transposed else w.shape[1]
    k = w.shape[1] if transposed else w.shape[0]
    p = _G.plan(_lead_m(x), n, k, dtype=x.dtype,
                backend=_legacy_backend(impl), block_m=block_m,
                block_n=block_n, block_k=block_k, pack=_G.PACK_PERCALL,
                transposed=transposed)
    return _G.execute(p, x, w, out_dtype=out_dtype)


def gemm_xla(x: jax.Array, w: jax.Array, *, transposed: bool = False):
    """DEPRECATED: raw shape-agnostic dot.  Delegates to plan/execute on
    the ``xla`` backend with ``pack=PACK_NONE``."""
    _warn("gemm_xla", 'gemm.plan(..., backend="xla", pack=PACK_NONE) '
          "+ gemm.execute")
    n = w.shape[0] if transposed else w.shape[1]
    k = w.shape[1] if transposed else w.shape[0]
    p = _G.plan(_lead_m(x), n, k, dtype=x.dtype, backend="xla",
                pack=_G.PACK_NONE, transposed=transposed)
    return _G.execute(p, x, w)
