"""REMOVED — the legacy GEMM entry points completed their deprecation
cycle (docs/gemm_api.md §Deprecation timeline).

``gemm`` / ``gemm_percall`` / ``gemm_xla`` shipped one release as
``DeprecationWarning`` shims over :mod:`repro.gemm`; this release they
are gone, and with them the last reader of the ``REPRO_GEMM_IMPL`` env
var.  Migration (the same table the shims carried):

  ==============================  =========================================
  legacy call                     replacement
  ==============================  =========================================
  ``gemm(x, pw)``                 ``p = gemm.plan_for_packed(m, pw)`` then
                                  ``gemm.execute(p, x, pw)``
  ``gemm_percall(x, w, ...)``     ``p = gemm.plan(m, n, k,
                                  pack=gemm.PACK_PERCALL, ...)`` then
                                  ``gemm.execute(p, x, w)``
  ``gemm_xla(x, w)``              ``p = gemm.plan(m, n, k, backend="xla",
                                  pack=gemm.PACK_NONE)`` then
                                  ``gemm.execute(p, x, w)``
  ``impl="..."`` keyword          ``backend="..."`` at plan time, or a
                                  ``gemm.use_backend(...)`` scope
  ``REPRO_GEMM_IMPL`` env var     removed — backends are explicit
                                  (``Engine(backend=)``, ``--backend``)
                                  or scoped (``use_backend``)
  ==============================  =========================================
"""
raise ImportError(
    "repro.core.panel_gemm was removed: the gemm/gemm_percall/gemm_xla "
    "shims completed their one-release deprecation cycle.  Use the "
    "plan/execute API in repro.gemm (gemm.plan / gemm.plan_for_packed + "
    "gemm.execute); the REPRO_GEMM_IMPL env var is gone too — pass "
    "backend= at plan time or scope gemm.use_backend(...).  Migration "
    "table: docs/gemm_api.md §Deprecation timeline.")
