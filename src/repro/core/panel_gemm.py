"""Public GEMM API: pre-packed, per-call, and XLA paths.

This is the surface the model code uses.  Three paths mirror the paper's
backends:

  gemm(x, pw)          — pre-packed kernel (the paper's proposed path):
                         per call pays ONLY the compute loop (+ M padding).
  gemm_percall(x, W)   — stateless baseline: transpose+pad the weight
                         inside the call, every call (cblas/BNNSMatMul
                         analogue).
  gemm_xla(x, W)       — raw XLA dot (the "Accelerate dispatch" analogue
                         and the CPU-runtime fallback).

Backend selection: impl ∈ {"xla", "pallas", "interpret"}.  On this CPU
container the model runtime defaults to "xla" (Pallas lowers for TPU;
interpret mode is for kernel validation, not throughput).  On TPU the
deployed default is "pallas".
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels import panel_gemm as _kernel
from repro.kernels import ref as _ref

# Global default backend; overridable per-call.  "xla" keeps CPU smoke tests
# and dry-runs fast; set REPRO_GEMM_IMPL=pallas on TPU.
_DEFAULT_IMPL = os.environ.get("REPRO_GEMM_IMPL", "xla")


def _pad_m(x: jax.Array, block_m: int) -> tuple[jax.Array, int]:
    m = x.shape[0]
    pad = (-m) % block_m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def _run(x_p, w_p, *, block_m, block_n, block_k, impl, out_dtype):
    if impl == "xla":
        return jnp.dot(x_p, w_p, preferred_element_type=jnp.float32).astype(
            out_dtype or x_p.dtype)
    return _kernel.panel_gemm(
        x_p, w_p, block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=(impl == "interpret"))


def gemm(x: jax.Array, pw: packing.PackedWeight, *,
         block_m: int = _kernel.DEFAULT_BLOCK_M,
         impl: str | None = None, out_dtype=None) -> jax.Array:
    """y[M, N] = x[M, K] @ pw  — pre-packed path (compute loop only)."""
    impl = impl or _DEFAULT_IMPL
    assert x.shape[-1] == pw.k, (x.shape, pw.shape)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, pw.k)
    if pw.data.shape[0] != pw.k:                   # pack padded K: pad x too
        x2 = jnp.pad(x2, ((0, 0), (0, pw.data.shape[0] - pw.k)))
    x2, m = _pad_m(x2, block_m)
    y = _run(x2, pw.data, block_m=block_m, block_n=pw.block_n,
             block_k=pw.block_k, impl=impl, out_dtype=out_dtype)
    return y[:m, :pw.n].reshape(*lead, pw.n)


def gemm_percall(x: jax.Array, w: jax.Array, *, transposed: bool = False,
                 block_m: int = _kernel.DEFAULT_BLOCK_M,
                 block_n: int = _kernel.DEFAULT_BLOCK_N,
                 block_k: int = _kernel.DEFAULT_BLOCK_K,
                 impl: str | None = None, out_dtype=None) -> jax.Array:
    """Stateless baseline: packs w inside the call, every call."""
    impl = impl or _DEFAULT_IMPL
    w_p = packing.pack_percall(w, transposed=transposed, block_n=block_n,
                               block_k=block_k)
    n = w.shape[0] if transposed else w.shape[1]
    k = w.shape[1] if transposed else w.shape[0]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if w_p.shape[0] != k:
        x2 = jnp.pad(x2, ((0, 0), (0, w_p.shape[0] - k)))
    x2, m = _pad_m(x2, block_m)
    y = _run(x2, w_p, block_m=block_m, block_n=block_n, block_k=block_k,
             impl=impl, out_dtype=out_dtype)
    return y[:m, :n].reshape(*lead, n)


def gemm_xla(x: jax.Array, w: jax.Array, *, transposed: bool = False):
    """The 'Accelerate' analogue: a single shape-agnostic XLA dot."""
    if transposed:
        w = w.T
    return _ref.gemm_xla(x.reshape(-1, w.shape[0]), w).reshape(
        *x.shape[:-1], w.shape[1])
