"""Bit-exactness harness — the paper's §4.1 verification discipline.

The paper samples the full output C at a large coprime stride (every 997th
or 1,023rd element, sweeping all rows and columns) and requires
max-abs-diff = 0e+00 for every configuration it ships.  Same here: the
Pallas kernel must be bit-identical to its blocked oracle at every swept
(block_n, block_k) pair, and the autotuner rejects non-bit-exact
candidates.  Differences vs the XLA dot path (different fp32 summation
order) are measured and REPORTED, not hidden — the paper does exactly this
for BNNS Graph's reduced-precision outputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

COPRIME_STRIDES = (997, 1023)


def sampled(x, stride: int = 997) -> np.ndarray:
    flat = np.asarray(x).reshape(-1)
    if flat.size <= stride:
        return flat
    return flat[::stride]


def max_abs_diff_sampled(a, b, stride: int = 997) -> float:
    return float(np.max(np.abs(sampled(a, stride).astype(np.float64)
                               - sampled(b, stride).astype(np.float64))))


def bit_identical(a, b) -> bool:
    """Bitwise equality over the FULL output (stronger than the paper)."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.shape != b.shape or a.dtype != b.dtype:
        return False
    return bool(np.array_equal(a.view(np.uint8), b.view(np.uint8)))


def assert_bit_identical(a, b, what: str = ""):
    if not bit_identical(a, b):
        diff = max_abs_diff_sampled(a, b, 1)
        raise AssertionError(
            f"not bit-identical{' (' + what + ')' if what else ''}: "
            f"max|diff| = {diff:.3e}")


def report(a, ref) -> dict:
    """Paper-style row: bit-exact? + coprime-stride max-abs-diff."""
    return {
        "bit_exact": bit_identical(a, ref),
        "max_abs_diff_997": max_abs_diff_sampled(a, ref, 997),
        "max_abs_diff_1023": max_abs_diff_sampled(a, ref, 1023),
    }
