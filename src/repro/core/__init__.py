"""Core: packing, scheduling model, autotune, bit-exactness.

The GEMM *dispatch* surface lives in :mod:`repro.gemm` (plan/execute).
The legacy ``core/panel_gemm`` shims (``gemm`` / ``gemm_percall`` /
``gemm_xla`` and the ``REPRO_GEMM_IMPL`` env var) completed their
deprecation cycle and are removed — importing ``repro.core.panel_gemm``
raises with the migration table (see docs/gemm_api.md).
"""
from repro.core import autotune, bitexact, packing, scheduler
from repro.core.packing import PackedWeight, pack, pack_fused

__all__ = [
    "autotune", "bitexact", "packing", "scheduler",
    "PackedWeight", "pack", "pack_fused",
]
