"""Core: packing, scheduling model, autotune, bit-exactness.

The GEMM *dispatch* surface moved to :mod:`repro.gemm` (plan/execute);
``gemm``/``gemm_percall``/``gemm_xla`` below are the deprecated shims
from ``core/panel_gemm.py`` — kept importable for one release (see
``docs/gemm_api.md``).
"""
from repro.core import autotune, bitexact, packing, panel_gemm, scheduler
from repro.core.packing import PackedWeight, pack
from repro.core.panel_gemm import gemm, gemm_percall, gemm_xla

__all__ = [
    "autotune", "bitexact", "packing", "panel_gemm", "scheduler",
    "PackedWeight", "pack", "gemm", "gemm_percall", "gemm_xla",
]
