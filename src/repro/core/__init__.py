"""Core: the paper's contribution — pre-packed, panel-scheduled GEMM."""
from repro.core import autotune, bitexact, packing, panel_gemm, scheduler
from repro.core.packing import PackedWeight, pack
from repro.core.panel_gemm import gemm, gemm_percall, gemm_xla

__all__ = [
    "autotune", "bitexact", "packing", "panel_gemm", "scheduler",
    "PackedWeight", "pack", "gemm", "gemm_percall", "gemm_xla",
]
