"""Offline (block_n, block_k) sweep — the paper's §3.3 panel-sizing sweep.

The paper sweeps Nc in {64..512} x Kc in {256..2048}, REJECTS any candidate
that is not bit-identical to Accelerate, and deploys the single pair that
wins all twelve shapes.  Same protocol here:

  1. candidates ranked by the napkin-math model in core/scheduler.plan()
     (predicted max(compute, memory) time / occupancy, VMEM-gated);
  2. each surviving candidate is executed in interpret mode on a reduced
     shape and must be BIT-IDENTICAL to the blocked oracle at its own
     block_k (kernels/ref.gemm_blocked) — any accumulator-carry bug is an
     instant reject;
  3. one (block_n, block_k) pair is deployed uniformly across shapes
     (the paper: "it is not tuned against any one comparison").

Run via benchmarks/table5_panel_sweep.py; the deployed defaults in
kernels/panel_gemm.py record the result.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitexact, scheduler
from repro.obs import spans as _spans

BLOCK_N_CANDIDATES = (128, 256, 512, 1024)
BLOCK_K_CANDIDATES = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass
class SweepResult:
    block_n: int
    block_k: int
    t_pred: float
    vmem: int
    bit_exact: bool


def sweep(shapes, *, block_m: int = 128, num_cores: int = 1,
          validate: bool = True, reduced: int = 256) -> list[SweepResult]:
    """Rank (block_n, block_k) pairs over a set of (M, N, K) shapes.

    ``shapes``: iterable of (m, n, k).  Returns candidates sorted by total
    predicted time across all shapes (the all-twelve-shapes criterion),
    with non-bit-exact candidates removed when ``validate``.
    """
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.panel_gemm import panel_gemm

    rng = np.random.default_rng(0)
    out = []
    for bn in BLOCK_N_CANDIDATES:
        for bk in BLOCK_K_CANDIDATES:
            t = 0.0
            ok = True
            for (m, n, k) in shapes:
                p = scheduler.plan(m, n, k, block_m=block_m, block_n=bn,
                                   block_k=bk, num_cores=num_cores)
                if not p.vmem_ok:
                    ok = False
                    break
                t += p.t_pred
            if not ok:
                continue
            exact = True
            if validate:
                m_r = block_m
                k_r, n_r = 2 * bk, bn   # smallest shape with a real K-carry
                x = jnp.asarray(rng.standard_normal((m_r, k_r)),
                                dtype=jnp.float32)
                w = jnp.asarray(rng.standard_normal((k_r, n_r)),
                                dtype=jnp.float32)
                y = panel_gemm(x, w, block_m=block_m, block_n=bn, block_k=bk,
                               interpret=True)
                exact = bitexact.bit_identical(
                    np.asarray(y), np.asarray(ref.gemm_blocked(x, w, bk)))
            out.append(SweepResult(bn, bk, t, scheduler.vmem_bytes(
                block_m, bn, bk), exact))
    out = [r for r in out if r.bit_exact]
    out.sort(key=lambda r: r.t_pred)
    return out


def deployed_pair(shapes, **kw) -> tuple[int, int]:
    """The single uniform pair the sweep deploys (paper: Nc=64, Kc=2048)."""
    best = sweep(shapes, **kw)[0]
    return best.block_n, best.block_k


# ====================================================================
# Measured autotune — the per-shape, per-format sweep the persistent
# plan store is populated with.
#
# The paper's sharpest deployment finding is that mis-tuning the single
# column-panel width costs ~2x — an argument for MEASURING candidate
# plans rather than trusting the analytic model above.  Protocol (the
# benchmark suite's §4.1 discipline, see benchmarks/common.py):
#
#   1. the analytic ``scheduler.plan`` prediction PRUNES the candidate
#      block triples (and decode split-K counts) to a short list that
#      always includes the analytic winner;
#   2. each candidate is executed for real — jitted, block_until_ready,
#      INTERLEAVED reps so machine drift cancels across candidates,
#      per-candidate median;
#   3. the measured winner must beat the analytic plan by more than the
#      noise tolerance or it is re-measured with more reps
#      (retry-on-noise: re-measure, never fudge), and after the retries
#      the ANALYTIC plan is kept — the mis-tune guard: a plan is never
#      deployed on a measurement that is not above noise;
#   4. the winner must pass the existing bit-exactness gate
#      (``gemm.validate_plan``) before it is committed; a gate-failing
#      candidate is discarded and the next-best stands.
#
# The committed winner lands in the ACTIVE plan store under the
# policy-position key (no block overrides), so a later ``gemm.plan(m,
# n, k, ...)`` — in this process or any warm-started one — adopts it.
# ====================================================================

# A measured advantage below this fraction of the analytic plan's time
# is treated as timer noise: re-measure, and ultimately keep analytic.
NOISE_RTOL = 0.05


@dataclasses.dataclass
class MeasuredPlan:
    """Result of one :func:`measured_autotune` call."""
    plan: "object"               # the deployed GemmPlan (gate-passed)
    t_analytic: float            # measured seconds/call, analytic plan
    t_measured: float            # measured seconds/call, deployed plan
    analytic: bool               # deployed == the analytic choice
    retries: int                 # noise re-measure rounds taken
    candidates: int              # candidates actually timed
    rejected: int                # candidates the bit-exact gate refused
    committed: bool              # landed in the active plan store

    @property
    def speedup(self) -> float:
        """Measured throughput ratio of deployed over analytic (>= 1.0
        by the mis-tune guard, == 1.0 when analytic is kept)."""
        return self.t_analytic / max(self.t_measured, 1e-12)

    def row(self) -> dict:
        p = self.plan
        return {
            "blocks": f"{p.block_m}x{p.block_n}x{p.block_k}",
            "split_k": p.split_k,
            "t_analytic_ms": round(self.t_analytic * 1e3, 5),
            "t_measured_ms": round(self.t_measured * 1e3, 5),
            "tuned_vs_analytic": round(self.speedup, 4),
            "analytic_kept": self.analytic,
            "retries": self.retries,
            "candidates": self.candidates,
            "gate_rejected": self.rejected,
            "committed": self.committed,
        }


def _candidate_plans(p0, m, n, k, *, dtype, backend, num_cores,
                     epilogue, weight_format, decode, max_candidates,
                     density_bucket=-1):
    """Analytic pruning: score block-triple (x decode split-K)
    candidates with the scheduler model, keep the ``max_candidates``
    best plus the analytic winner itself.  Every candidate resolves
    through ``gemm.plan`` with explicit blocks, so the VMEM fit and
    split validation run exactly as they would at dispatch."""
    from repro import gemm
    from repro.core import packing
    from repro.gemm.policy import DECODE_SPLIT_K_CANDIDATES

    bns = sorted({packing.fit_block(n, c) for c in BLOCK_N_CANDIDATES})
    bks = sorted({packing.fit_block(k, c) for c in BLOCK_K_CANDIDATES})
    if density_bucket >= 0:
        # sparse arm: the group-granular walk ignores block_k, and one
        # block_k keeps every candidate's pack (and padded K, hence the
        # synthetic weight's group structure) identical — the sweep's
        # real lever is the column-panel width
        bks = [p0.block_k]
    splits = (DECODE_SPLIT_K_CANDIDATES if (decode and p0.split_k > 1)
              else (p0.split_k,))
    scored = []
    for bn in bns:
        for bk in bks:
            k_pad = max(bk, -(-k // bk) * bk)
            for s in splits:
                if s > 1 and (k_pad % s or (k_pad // s) % bk):
                    continue       # split does not cut this padded K
                p = scheduler.plan(m, n, k, block_m=p0.block_m,
                                   block_n=bn, block_k=bk,
                                   num_cores=num_cores, split_k=s)
                if not p.vmem_ok:
                    continue
                scored.append((p.t_pred, bn, bk, s))
    scored.sort()
    plans, seen = [], set()
    triples = [(p0.block_n, p0.block_k, p0.split_k)]   # analytic first
    triples += [(bn, bk, s) for _, bn, bk, s in scored[:max_candidates]]
    for bn, bk, s in triples:
        try:
            p = gemm.plan(m, n, k, dtype=dtype, backend=backend,
                          num_cores=num_cores, block_m=p0.block_m,
                          block_n=bn, block_k=bk, pack=p0.pack,
                          epilogue=epilogue, weight_format=weight_format,
                          decode=decode, split_k=s,
                          density_bucket=density_bucket)
        except ValueError:
            continue          # split does not cut this K; not a candidate
        tr = (p.block_m, p.block_n, p.block_k, p.split_k)
        if tr in seen:
            continue
        seen.add(tr)
        plans.append(p)
    return plans


def _time_interleaved(runs, *, trials: int, warmup: int) -> list[float]:
    """Median seconds/call per run, interleaved reps (drift cancels)."""
    import time

    import jax

    for fn in runs:
        for _ in range(max(warmup, 1)):
            jax.block_until_ready(fn())
    ts: list[list[float]] = [[] for _ in runs]
    for _ in range(trials):
        for i, fn in enumerate(runs):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[i].append(time.perf_counter() - t0)
    return [float(np.median(v)) for v in ts]


def measured_autotune(m: int, n: int, k: int, *, dtype=None,
                      backend: str | None = None,
                      weight_format: str = "fp32", epilogue=None,
                      decode: bool = False, num_cores: int | None = None,
                      trials: int = 5, warmup: int = 2,
                      max_retries: int = 3, noise_rtol: float = NOISE_RTOL,
                      max_candidates: int = 4, commit: bool = True,
                      seed: int = 0,
                      density_bucket: int = -1) -> MeasuredPlan:
    """Measure candidate plans for one ``[m,k] @ [k,n]`` dispatch and
    deploy the winner (module docstring has the full protocol).

    The candidate resolutions run under ``gemm.no_plan_store()`` so the
    sweep never reads the store it is populating; with ``commit=True``
    and a store active, the gate-passed winner is committed under the
    policy-position store key (and adopted by this process's in-memory
    plan cache), with its measured time as provenance.

    ``density_bucket >= 0`` sweeps the SPARSE-ternary arm
    (``weight_format='ternary'`` only): the synthetic weight zeroes
    whole GROUP_K K-groups to land in exactly that bucket, packs through
    the compressed layout, and the winner commits under the
    bucket-keyed store position a later ``plan_for_packed`` on a
    same-bucket pack will ask.
    """
    import jax
    import jax.numpy as jnp

    from repro import gemm
    from repro.core import packing
    from repro.gemm import plan_store as _ps
    from repro.gemm import policy as _pol

    dtype = jnp.float32 if dtype is None else dtype
    num_cores = _pol.DEFAULT_NUM_CORES if num_cores is None else num_cores
    with _ps.no_plan_store():
        p0 = gemm.plan(m, n, k, dtype=dtype, backend=backend,
                       num_cores=num_cores, epilogue=epilogue,
                       weight_format=weight_format, decode=decode,
                       density_bucket=density_bucket)
        cands = _candidate_plans(
            p0, m, n, k, dtype=dtype, backend=backend,
            num_cores=num_cores, epilogue=epilogue,
            weight_format=weight_format, decode=decode,
            max_candidates=max_candidates, density_bucket=density_bucket)

    rng = np.random.default_rng(seed)
    quant = weight_format != "fp32"
    sparse = density_bucket >= 0
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w_np = (rng.standard_normal((k, n)) * 0.02).astype(np.float32)
    if sparse:
        # land the synthetic weight in EXACTLY the requested bucket:
        # zero whole GROUP_K K-groups of the candidate-shared padded K
        # (sparse candidates pin one block_k, so one weight serves all)
        from repro.quant.formats import GROUP_K
        k_pad = -(-k // p0.block_k) * p0.block_k
        kg = k_pad // GROUP_K
        pad_zero = kg - (-(-k // GROUP_K))      # all-pad tail groups
        z = max(0, -(-density_bucket * kg // 10) - pad_zero)
        for g in range(z):
            lo, hi = g * GROUP_K, min((g + 1) * GROUP_K, k)
            w_np[lo:hi] = 0.0
        got = int((z + pad_zero) / kg * 10)     # density_bucket_of's math
        if got != density_bucket:
            raise ValueError(
                f"density_bucket={density_bucket} is unreachable for "
                f"K={k} at block_k={p0.block_k} ({kg} groups, {pad_zero} "
                f"already zero from padding -> bucket {got})")
    w = jnp.asarray(w_np)

    def make_run(p):
        # measure the plan's own deployment: a prepack plan pays its
        # pack OUTSIDE the timed region (model-load protocol), a
        # percall plan pays the in-call re-layout it actually costs
        if p.prepack:
            pw = packing.pack(w, block_n=p.block_n, block_k=p.block_k,
                              quant=weight_format if quant else None,
                              sparse=True if sparse else None)
        else:
            pw = w
        if sparse and getattr(pw, "density_bucket", -1) != p.density_bucket:
            raise RuntimeError(
                f"synthetic sparse pack landed in bucket "
                f"{getattr(pw, 'density_bucket', -1)}, plan expects "
                f"{p.density_bucket}")
        run = jax.jit(lambda x, pw: gemm.execute(p, x, pw))
        return lambda: run(x, pw)

    runs = [make_run(p) for p in cands]

    retries = 0
    while True:
        with _spans.span("autotune_measure", m=m, n=n, k=k,
                         candidates=len(runs), round=retries,
                         trials=trials + 2 * retries):
            meds = _time_interleaved(runs, trials=trials + 2 * retries,
                                     warmup=warmup)
        t_analytic = meds[0]                  # analytic plan is cands[0]
        order = sorted(range(len(cands)), key=lambda i: meds[i])
        best = order[0]
        if best == 0:
            break                             # analytic measured best
        adv = (t_analytic - meds[best]) / max(t_analytic, 1e-12)
        if adv >= noise_rtol:
            break                             # a real, above-noise win
        if retries >= max_retries:
            # mis-tune guard: the advantage never cleared the noise
            # floor — keep the analytic plan, never deploy on noise
            order = [0] + [i for i in order if i != 0]
            break
        retries += 1

    # the deployed plan must pass the existing bit-exactness gate;
    # gate-failing candidates are discarded, next-best stands (the
    # analytic plan gates too — an all-reject sweep is an error)
    rejected = 0
    winner = None
    for i in order:
        if gemm.validate_plan(cands[i]):
            winner, t_meas = cands[i], meds[i]
            break
        rejected += 1
    if winner is None:
        raise RuntimeError(
            f"measured autotune: every candidate for {m}x{n}x{k} "
            f"({weight_format}, decode={decode}) failed the "
            f"bit-exactness gate")
    final = dataclasses.replace(winner, validated=True)

    committed = False
    store = _ps.active_plan_store()
    if commit and store is not None:
        skey = _pol.store_key(m, n, k, dtype=dtype, backend=backend,
                              num_cores=num_cores, epilogue=epilogue,
                              weight_format=weight_format, decode=decode,
                              density_bucket=density_bucket)
        store.put(skey, final, t_meas=t_meas, autotuned=True)
        # adopt in-process too: the policy-position cache entry (if the
        # analytic resolution above seeded it) must agree with the store
        ck = _pol._plan_key(m, n, k, dtype=dtype, backend=backend,
                            num_cores=num_cores, epilogue=epilogue,
                            weight_format=weight_format, decode=decode,
                            density_bucket=density_bucket)
        _pol._cache_insert(ck, final)
        committed = True

    return MeasuredPlan(plan=final, t_analytic=meds[0], t_measured=t_meas,
                        analytic=(final.block_n, final.block_k,
                                  final.split_k) == (p0.block_n,
                                                     p0.block_k,
                                                     p0.split_k),
                        retries=retries, candidates=len(cands),
                        rejected=rejected, committed=committed)
