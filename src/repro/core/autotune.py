"""Offline (block_n, block_k) sweep — the paper's §3.3 panel-sizing sweep.

The paper sweeps Nc in {64..512} x Kc in {256..2048}, REJECTS any candidate
that is not bit-identical to Accelerate, and deploys the single pair that
wins all twelve shapes.  Same protocol here:

  1. candidates ranked by the napkin-math model in core/scheduler.plan()
     (predicted max(compute, memory) time / occupancy, VMEM-gated);
  2. each surviving candidate is executed in interpret mode on a reduced
     shape and must be BIT-IDENTICAL to the blocked oracle at its own
     block_k (kernels/ref.gemm_blocked) — any accumulator-carry bug is an
     instant reject;
  3. one (block_n, block_k) pair is deployed uniformly across shapes
     (the paper: "it is not tuned against any one comparison").

Run via benchmarks/table5_panel_sweep.py; the deployed defaults in
kernels/panel_gemm.py record the result.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitexact, scheduler

BLOCK_N_CANDIDATES = (128, 256, 512, 1024)
BLOCK_K_CANDIDATES = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass
class SweepResult:
    block_n: int
    block_k: int
    t_pred: float
    vmem: int
    bit_exact: bool


def sweep(shapes, *, block_m: int = 128, num_cores: int = 1,
          validate: bool = True, reduced: int = 256) -> list[SweepResult]:
    """Rank (block_n, block_k) pairs over a set of (M, N, K) shapes.

    ``shapes``: iterable of (m, n, k).  Returns candidates sorted by total
    predicted time across all shapes (the all-twelve-shapes criterion),
    with non-bit-exact candidates removed when ``validate``.
    """
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.panel_gemm import panel_gemm

    rng = np.random.default_rng(0)
    out = []
    for bn in BLOCK_N_CANDIDATES:
        for bk in BLOCK_K_CANDIDATES:
            t = 0.0
            ok = True
            for (m, n, k) in shapes:
                p = scheduler.plan(m, n, k, block_m=block_m, block_n=bn,
                                   block_k=bk, num_cores=num_cores)
                if not p.vmem_ok:
                    ok = False
                    break
                t += p.t_pred
            if not ok:
                continue
            exact = True
            if validate:
                m_r = block_m
                k_r, n_r = 2 * bk, bn   # smallest shape with a real K-carry
                x = jnp.asarray(rng.standard_normal((m_r, k_r)),
                                dtype=jnp.float32)
                w = jnp.asarray(rng.standard_normal((k_r, n_r)),
                                dtype=jnp.float32)
                y = panel_gemm(x, w, block_m=block_m, block_n=bn, block_k=bk,
                               interpret=True)
                exact = bitexact.bit_identical(
                    np.asarray(y), np.asarray(ref.gemm_blocked(x, w, bk)))
            out.append(SweepResult(bn, bk, t, scheduler.vmem_bytes(
                block_m, bn, bk), exact))
    out = [r for r in out if r.bit_exact]
    out.sort(key=lambda r: r.t_pred)
    return out


def deployed_pair(shapes, **kw) -> tuple[int, int]:
    """The single uniform pair the sweep deploys (paper: Nc=64, Kc=2048)."""
    best = sweep(shapes, **kw)[0]
    return best.block_n, best.block_k
