"""Weight pre-packing — paper lever 2, as a first-class feature.

On the M1 the pack is a physical re-layout of B into [Kc, Nc] panels, paid
once at model load and amortized to zero across every prefill/decode call.
On TPU the per-call costs a stateless GEMM pays are the analogues we remove:

  * transpose        — engines store W as [N, K] (llama.cpp convention);
                       the kernel wants [K, N].  Done once here.
  * block padding    — pad (K, N) up to (block_k, block_n) multiples so the
                       kernel's BlockSpec grid divides exactly.  Once.
  * dtype cast       — e.g. fp32 master → bf16 compute copy.  Once.
  * device layout /  — place the packed array with the exact NamedSharding
    resharding         the GEMM consumes, so no relayout or resharding
                       collective appears in the per-step HLO.  Once.

``PackedWeight`` is a pytree, so it flows through jit/pjit/scan/checkpoint
like any array.  The stateless baseline (pack-every-call) lives in
core/panel_gemm.gemm_percall and is benchmarked against this path
(benchmarks/table3_prefill_gemms.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import panel_gemm as _kernel


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedWeight:
    """A weight packed once at load for the panel GEMM.

    data: [K_pad, N_pad] row-major, zero-padded to block multiples.
    n, k: logical (unpadded) dims.  block_n/block_k: the pack granularity.
    """
    data: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    block_n: int = dataclasses.field(metadata=dict(static=True))
    block_k: int = dataclasses.field(metadata=dict(static=True))

    @property
    def shape(self):  # logical shape
        return (self.k, self.n)

    @property
    def dtype(self):
        return self.data.dtype


def _pad_to(x: jax.Array, mults: tuple[int, int]) -> jax.Array:
    pk = (-x.shape[0]) % mults[0]
    pn = (-x.shape[1]) % mults[1]
    if pk or pn:
        x = jnp.pad(x, ((0, pk), (0, pn)))
    return x


def fit_block(dim: int, want: int, lane: int = 128) -> int:
    """Largest block <= ``want`` that divides dim rounded up to a lane
    multiple — keeps pack padding minimal on odd dims (hymba's 1600-wide
    projections would otherwise pad 28% to honor the deep default K
    block; the deep block only pays off when it divides anyway)."""
    padded = max(lane, ((dim + lane - 1) // lane) * lane)
    b = min(want, padded)
    while b > lane and padded % b:
        b //= 2
    return b if padded % b == 0 else lane


def pack(
    w: jax.Array,
    *,
    transposed: bool = False,          # True: w given as [N, K] (llama.cpp)
    block_n: int = _kernel.DEFAULT_BLOCK_N,
    block_k: int = _kernel.DEFAULT_BLOCK_K,
    dtype: Any = None,
    sharding: jax.sharding.Sharding | None = None,
) -> PackedWeight:
    """Pack a weight once at model load (see module docstring)."""
    if transposed:
        n, k = w.shape
        w = w.T
    else:
        k, n = w.shape
    if dtype is not None:
        w = w.astype(dtype)
    block_k = fit_block(k, block_k)
    block_n = fit_block(n, block_n)
    w = _pad_to(w, (block_k, block_n))
    if sharding is not None:
        w = jax.device_put(w, sharding)
    return PackedWeight(data=w, n=n, k=k, block_n=block_n, block_k=block_k)


def pack_percall(w: jax.Array, *, transposed: bool, block_n: int,
                 block_k: int, dtype: Any = None) -> jax.Array:
    """The stateless pack, traced INSIDE the per-call GEMM (the honest
    cblas_sgemm/BNNSMatMul analogue: transpose + pad paid on every call)."""
    if transposed:
        w = w.T
    if dtype is not None:
        w = w.astype(dtype)
    return _pad_to(w, (block_k, block_n))
