"""Weight pre-packing — paper lever 2, as a first-class feature.

On the M1 the pack is a physical re-layout of B into [Kc, Nc] panels, paid
once at model load and amortized to zero across every prefill/decode call.
On TPU the per-call costs a stateless GEMM pays are the analogues we remove:

  * transpose        — engines store W as [N, K] (llama.cpp convention);
                       the kernel wants [K, N].  Done once here.
  * block padding    — pad (K, N) up to (block_k, block_n) multiples so the
                       kernel's BlockSpec grid divides exactly.  Once.
  * dtype cast       — e.g. fp32 master → bf16 compute copy.  Once.
  * device layout /  — place the packed array with the exact NamedSharding
    resharding         the GEMM consumes, so no relayout or resharding
                       collective appears in the per-step HLO.  Once.

``PackedWeight`` is a pytree, so it flows through jit/pjit/scan/checkpoint
like any array.  The stateless baseline (pack-every-call) is a plan
decision (``gemm.plan(..., pack=PACK_PERCALL)``) and is benchmarked
against this path (benchmarks/table3_prefill_gemms.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import panel_gemm as _kernel
from repro.obs import spans as _spans


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedWeight:
    """A weight packed once at load for the panel GEMM.

    data: [K_pad, N_pad] row-major, zero-padded to block multiples.
    n, k: logical (unpadded) dims.  block_n/block_k: the pack granularity.

    A *fused* pack (``pack_fused``) concatenates several same-K weights
    along N, each part individually padded to a ``block_n`` multiple so no
    kernel column tile straddles two parts.  ``n_splits`` is the static
    split map — the parts' LOGICAL widths, in order; for a fused pack
    ``n`` is the kernel-visible concatenated width (interior zero padding
    included).  ``n_splits == ()`` marks an ordinary single-weight pack.
    """
    data: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    block_n: int = dataclasses.field(metadata=dict(static=True))
    block_k: int = dataclasses.field(metadata=dict(static=True))
    n_splits: tuple = dataclasses.field(default=(),
                                        metadata=dict(static=True))

    @property
    def shape(self):  # logical shape (fused: padded-concat width)
        return (self.k, self.n)

    @property
    def dtype(self):
        return self.data.dtype


def _pad_to(x: jax.Array, mults: tuple[int, int]) -> jax.Array:
    pk = (-x.shape[0]) % mults[0]
    pn = (-x.shape[1]) % mults[1]
    if pk or pn:
        x = jnp.pad(x, ((0, pk), (0, pn)))
    return x


def fit_block(dim: int, want: int, lane: int = 128) -> int:
    """Largest block <= ``want`` that divides dim rounded up to a lane
    multiple — keeps pack padding minimal on odd dims (hymba's 1600-wide
    projections would otherwise pad 28% to honor the deep default K
    block; the deep block only pays off when it divides anyway)."""
    padded = max(lane, ((dim + lane - 1) // lane) * lane)
    b = min(want, padded)
    while b > lane and padded % b:
        b //= 2
    return b if padded % b == 0 else lane


def pack(
    w: jax.Array,
    *,
    transposed: bool = False,          # True: w given as [N, K] (llama.cpp)
    block_n: int = _kernel.DEFAULT_BLOCK_N,
    block_k: int = _kernel.DEFAULT_BLOCK_K,
    dtype: Any = None,
    sharding: jax.sharding.Sharding | None = None,
    quant: str | None = None,
    sparse: bool | None = None,
) -> PackedWeight:
    """Pack a weight once at model load (see module docstring).

    ``quant`` ("int8" | "ternary") additionally QUANTIZES at pack time —
    the pre-pack lever extended below fp32 (repro.quant): the returned
    :class:`~repro.quant.QuantizedPackedWeight` carries codes + scales,
    the plan carries the format, and execute() streams 4x/16x fewer
    weight bytes per tile through the dequant-fused kernel.  The error
    ledger measures and tolerance-gates every concrete quantized pack
    (docs/quantization.md).  ``sparse`` (ternary only) controls the
    compressed zero-group layout: ``None`` auto-compresses when the
    pack's zero-group fraction clears ``quant.SPARSE_DENSITY_THRESHOLD``,
    ``True`` forces it, ``False`` keeps the dense layout."""
    with _spans.span("pack", n=int(w.shape[-1] if not transposed
                                   else w.shape[-2]),
                     k=int(w.shape[-2] if not transposed
                           else w.shape[-1]),
                     quant=quant or "fp32") as sp:
        if quant is not None:
            from repro.quant.formats import quantize_pack
            if dtype is not None:
                raise ValueError("dtype casts do not compose with quant= "
                                 "(codes have a fixed storage type)")
            return quantize_pack(w, quant, transposed=transposed,
                                 block_n=block_n, block_k=block_k,
                                 sharding=sharding, sparse=sparse)
        if sparse:
            raise ValueError("sparse= is a ternary pack-time lever; it "
                             "requires quant='ternary'")
        if transposed:
            n, k = w.shape
            w = w.T
        else:
            k, n = w.shape
        if dtype is not None:
            w = w.astype(dtype)
        block_k = fit_block(k, block_k)
        block_n = fit_block(n, block_n)
        sp.set(block_n=block_n, block_k=block_k)
        w = _pad_to(w, (block_k, block_n))
        if sharding is not None:
            w = jax.device_put(w, sharding)
        return PackedWeight(data=w, n=n, k=k, block_n=block_n,
                            block_k=block_k)


def pack_fused(
    parts,                             # sequence of [K, Ni] (or [Ni, K])
    *,
    transposed: bool = False,
    block_n: int = _kernel.DEFAULT_BLOCK_N,
    block_k: int = _kernel.DEFAULT_BLOCK_K,
    dtype: Any = None,
    sharding: jax.sharding.Sharding | None = None,
    quant: str | None = None,
    sparse: bool | None = None,
) -> PackedWeight:
    """Horizontally fuse same-input weights into ONE pack (paper lever 2
    applied across projections): concatenate along N at load, so one
    kernel pass streams the shared activations once and produces every
    part (QKV; gate+up for the glu epilogue).

    Each part is padded to a ``block_n`` multiple before the concat —
    column tiles never straddle parts, which is what lets (a) the output
    split map stay static (``gemm.split_fused``) and (b) the glu kernel
    address gate/up halves by tile offset.  Parts may also be stacked
    ``[L, K, Ni]`` (scan-over-layers weights); the leading dim rides
    through untouched.  ``quant`` quantizes every part at pack time
    (per-part per-column scales — see ``pack(quant=)``).
    """
    if quant is not None:
        from repro.quant.formats import quantize_pack_fused
        if dtype is not None:
            raise ValueError("dtype casts do not compose with quant=")
        with _spans.span("pack_fused", parts=len(parts),
                         quant=quant):
            return quantize_pack_fused(parts, quant,
                                       transposed=transposed,
                                       block_n=block_n, block_k=block_k,
                                       sharding=sharding, sparse=sparse)
    if sparse:
        raise ValueError("sparse= is a ternary pack-time lever; it "
                         "requires quant='ternary'")
    with _spans.span("pack_fused", parts=len(parts), quant="fp32"):
        return _pack_fused_fp32(parts, transposed=transposed,
                                block_n=block_n, block_k=block_k,
                                dtype=dtype, sharding=sharding)


def _pack_fused_fp32(parts, *, transposed, block_n, block_k, dtype,
                     sharding) -> PackedWeight:
    ws = [jnp.swapaxes(w, -1, -2) if transposed else w for w in parts]
    if len(ws) < 2:
        raise ValueError("pack_fused needs at least two weights; "
                         "use pack() for one")
    k = ws[0].shape[-2]
    if any(w.shape[-2] != k or w.ndim != ws[0].ndim for w in ws):
        raise ValueError(
            f"fused parts must share K and rank; got "
            f"{[tuple(w.shape) for w in ws]}")
    if dtype is not None:
        ws = [w.astype(dtype) for w in ws]
    block_k = fit_block(k, block_k)
    bn = min(fit_block(w.shape[-1], block_n) for w in ws)
    n_splits = tuple(int(w.shape[-1]) for w in ws)
    pk = (-k) % block_k

    def pad(w):
        pn = (-w.shape[-1]) % bn
        cfg = [(0, 0)] * (w.ndim - 2) + [(0, pk), (0, pn)]
        return jnp.pad(w, cfg) if pk or pn else w

    data = jnp.concatenate([pad(w) for w in ws], axis=-1)
    if sharding is not None:
        data = jax.device_put(data, sharding)
    return PackedWeight(data=data, n=int(data.shape[-1]), k=k,
                        block_n=bn, block_k=block_k, n_splits=n_splits)


def pack_percall(w: jax.Array, *, transposed: bool, block_n: int,
                 block_k: int, dtype: Any = None) -> jax.Array:
    """The stateless pack, traced INSIDE the per-call GEMM (the honest
    cblas_sgemm/BNNSMatMul analogue: transpose + pad paid on every call)."""
    if transposed:
        w = w.T
    if dtype is not None:
        w = w.astype(dtype)
    return _pad_to(w, (block_k, block_n))
