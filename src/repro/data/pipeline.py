"""Tokenized data pipeline.

Two sources behind one iterator protocol:

* ``SyntheticLM`` — deterministic, seeded Zipf-ish token stream with local
  n-gram structure (so the loss actually decreases and training-curve
  sanity checks mean something).  Restart-safe: batch(step) is a pure
  function of (seed, step), so resuming from a checkpoint replays the
  exact stream — no iterator state to checkpoint.
* ``TokenFileDataset`` — memory-mapped uint16/uint32 token file (the
  production path).  Sequential sequence windows, host-sharded by
  (process_index, process_count): each host reads only its stripe, the
  multi-host layout jax.distributed assumes.

Both yield {"inputs": (B, S) int32, "labels": (B, S) int32} with labels =
inputs shifted left (next-token prediction).  For stub-frontend archs
(audio/vlm) ``make_batches(..., embed_dim=d)`` yields float embeddings
instead of token ids — matching model_zoo.input_specs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int                 # per-host batch
    seed: int = 0
    zipf_a: float = 1.3

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s, v = self.batch_size, self.seq_len, self.vocab_size
        # Zipf marginal over a shuffled alphabet + deterministic bigram
        # successor table: x[t+1] = succ[x[t]] with prob .5 else zipf draw.
        ranks = rng.permutation(v)
        draws = np.minimum(rng.zipf(self.zipf_a, size=(b, s + 1)), v) - 1
        toks = ranks[draws]
        succ = (np.arange(v) * 31 + 7) % v
        follow = rng.random((b, s + 1)) < 0.5
        for t in range(1, s + 1):
            toks[:, t] = np.where(follow[:, t], succ[toks[:, t - 1]],
                                  toks[:, t])
        return {"inputs": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class TokenFileDataset:
    """Memory-mapped flat token file, host-sharded sequence windows."""
    path: str
    seq_len: int
    batch_size: int                 # per-host batch
    dtype: str = "uint16"
    process_index: int = 0
    process_count: int = 1
    seed: int = 0

    def __post_init__(self):
        self._tokens = np.memmap(self.path, dtype=self.dtype, mode="r")
        n_seq = (len(self._tokens) - 1) // self.seq_len
        # host stripe: contiguous block of sequence windows
        per = n_seq // self.process_count
        self._lo = self.process_index * per
        self._n = per
        if self._n < self.batch_size:
            raise ValueError(
                f"host stripe has {self._n} sequences < batch "
                f"{self.batch_size}; token file too small")

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.process_index, step]))
        idx = self._lo + rng.integers(0, self._n, self.batch_size)
        s = self.seq_len
        rows = np.stack([self._tokens[i * s: i * s + s + 1] for i in idx])
        rows = rows.astype(np.int32)
        return {"inputs": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def write_token_file(path: str, tokens: np.ndarray, dtype: str = "uint16"):
    np.asarray(tokens, dtype=dtype).tofile(path)


def make_batches(source, *, embed_dim: int | None = None,
                 embed_dtype=np.float32, start_step: int = 0):
    """Iterator of batches from ``source`` starting at ``start_step``
    (checkpoint resume).  embed_dim: stub-frontend mode — replace token
    inputs with deterministic pseudo-embeddings [B, S, d]."""
    step = start_step
    while True:
        b = source.batch(step)
        if embed_dim is not None:
            rng = np.random.default_rng(
                np.random.SeedSequence([source.seed, 999, step]))
            bsz, s = b["inputs"].shape
            b = dict(b)
            b["inputs"] = rng.standard_normal(
                (bsz, s, embed_dim)).astype(embed_dtype)
        yield step, b
        step += 1
