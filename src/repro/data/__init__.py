"""Data pipeline: synthetic LM stream + binary token-file loader."""
from repro.data.pipeline import (
    SyntheticLM, TokenFileDataset, make_batches, write_token_file,
)

__all__ = ["SyntheticLM", "TokenFileDataset", "make_batches",
           "write_token_file"]
