"""Table 9 — the decode fast lane, measured per decode GEMM shape.

The PR 2 serving loop dispatched decode ``[slots, 1]`` GEMMs through the
prefill-tuned panel policy.  For the K >= N shape class that policy's
lever tolerates the PER-CALL pack (paper §3.2: transpose + pad, and for
a quantized checkpoint the per-call dequant on top) because 128 prefill
rows amortize it — the policy-default dispatch every prior table
measures as its baseline (table3, table8).  Decode rows amortize
nothing: the re-layout dwarfs the skinny dot.  The decode arm forces
prepack, pins the skinny ``block_m = 8`` row panel, and scores split-K
for reduction-side occupancy.

Modes per (shape, slots, format), all jitted on the xla backend, all
at the EXACT decode M (serving dispatched decode at exact M before
this PR too — no bucket padding anywhere in the timed modes):

  prefill_policy    — the baseline the acceptance gate measures
                      against: the prefill-tuned policy's default
                      dispatch for the shape class.  For K >= N that
                      is the per-call pack — the weight rides
                      checkpoint-style [N, K] (quant: codes + per-row
                      scales, dequantized per call, table8's
                      §3.2-extended protocol) and re-lays-out inside
                      every call.  For the N > K context row the
                      prefill policy already prepacks, so there this
                      mode IS the prepacked dispatch (reported, not
                      gated — the acceptance names the K >= N shapes;
                      note serving's packed engines always paid the
                      prepacked column below, not this one).
  prefill_prepacked — context: the prefill-arm plan against the
                      prepacked weight at the same exact M — what
                      PR 2/4 packed serving actually dispatched.
                      ``lane_vs_prepacked`` therefore isolates the
                      decode arm's residual delta (split-K restructure
                      + plan metadata): ~1.0 at split_k = 1 by
                      construction, and the split-K rows show the
                      restructure alone (TPU-occupancy-targeted;
                      ~neutral on this CPU host's xla backend).
  decode_lane       — the decode arm as the policy resolves it for the
                      xla backend: prepacked (quantize-packed) weight,
                      one execute() call.  The policy keeps
                      ``split_k = 1`` on xla — the split lever scores
                      KERNEL-GRID occupancy, which a shape-agnostic
                      backend does not have, and the restructure
                      measured a wash-to-loss on this CPU host.
  decode_lane_splitk — context, only where the kernel arm engages: the
                      same dispatch forced to the split the policy
                      scores for the panel-grid (pallas) backends
                      (``kernel_split_k`` column), executed on xla.
                      Shows the split restructure's CPU cost honestly
                      and keeps the split dispatch + combine parity
                      exercised in the committed table; the occupancy
                      win it buys is a TPU-grid property the roofline
                      model predicts, not a CPU measurement.

Parity before timing: the lane is asserted BITWISE against the
prefill_policy baseline itself — same M, same values: the prepack
lever deleted the re-layout without touching a bit.  The split lane is
asserted BITWISE against a pure-jnp reference computing its plan's
exact split-K semantics (slice dots + the shared
``gemm.splitk_combine`` tree over the same (dequantized) values) and
allclose against the unsplit lane (a split plan reorders the fp32
reduction by design — the bitwise contract there is carried by the
split-K oracle gates: ``gemm.validate_plan``,
tests/test_decode_lane.py).

The committed acceptance ratio: ``decode_lane`` >= 1.15x over
``prefill_policy`` on every K >= N row at slots <= 4, all three
formats.  The lane does strictly less per-call work on those rows, so
a sub-threshold median is timer noise — re-measure, never fudge
(table8's retry discipline).

Emits ``benchmarks/out/table9_decode.json`` (transient) and the
version-tracked ``benchmarks/BENCH_decode.json`` baseline.  ``--dry-run``
(CI serving-smoke job) runs one tiny shape per format with every parity
gate, so the lane's dispatch contract runs on every PR.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.table8_quant import _pack_nk, _unpack_nk
from repro import gemm as G
from repro.core import bitexact, packing
from repro.quant import formats as F

FORMATS = ("fp32", "int8", "ternary")
SLOTS = (1, 2, 4, 8)

# Decode GEMM shapes (op, n, k): the deep-K (K >= 4N) decode class the
# motivation names — kv / down projections, where weight bytes dominate
# the skinny dot (clean 128-multiples so no K/N pad clouds the
# comparison).  K >= N rows are the gated set; gate_up is the N > K
# context row.  Square K == N shapes are deliberately NOT in the gated
# set: at M = 1 this host's XLA dot-kernel choice is bimodal on wide-N
# GEMVs, and the per-call re-layout of a square weight is too small to
# dominate that noise — the gate would measure the quirk, not the lane.
DECODE_GEMM_SHAPES = (
    ("kv_proj", 256, 8192),       # GQA kv head block: narrow N, deep K
    ("ffn_down", 1024, 4096),     # down-proj: the deep-K decode GEMM
    ("ffn_down_3b", 2048, 8192),  # 3B-class down-proj
    ("gate_up", 4096, 1024),      # N > K: prefill policy prepacks too
)

ACCEPT_RATIO = 1.15


def _timer(reps):
    def time_modes(modes: dict) -> dict:
        ts = {name: [] for name in modes}
        for _ in range(reps):
            # interleaved reps: machine drift cancels across modes
            for name, fn in modes.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                ts[name].append(time.perf_counter() - t0)
        return {name: float(np.median(v)) for name, v in ts.items()}
    return time_modes


def _lane_reference(x, pw, plan):
    """Pure-jnp reference for the lane's exact dispatch semantics:
    slice dots over the (dequantized) packed values + the shared
    fixed-order combine tree.  What execute() returns must match this
    BITWISE — the dispatch layer adds nothing numerically."""
    w = (F.dequantize_padded(pw.data, pw.scales, pw.fmt)
         if plan.quantized else pw.data)
    s = plan.split_k
    if s == 1:
        return jnp.dot(x, w, preferred_element_type=jnp.float32)
    ks = x.shape[1] // s
    parts = [jnp.dot(x[:, i * ks:(i + 1) * ks], w[i * ks:(i + 1) * ks],
                     preferred_element_type=jnp.float32)
             for i in range(s)]
    return G.splitk_combine(parts)


def _row(op, n, k, fmt, slots, rng, reps):
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.standard_normal((slots, k)), jnp.float32)

    quant = fmt != "fp32"
    # pack with the policy-resolved serving blocks (what
    # model_zoo.pack_for_inference pays at load)
    bn, bk = G.pack_blocks(n, k, weight_format=fmt if quant else "fp32")
    pw = packing.pack(w, block_n=bn, block_k=bk,
                      quant=fmt if quant else None)
    lane_plan = G.plan_for_packed(slots, pw, backend="xla", decode=True)
    assert lane_plan.decode and lane_plan.pack == G.PACK_PREPACKED
    assert lane_plan.split_k == 1        # xla: no kernel grid to occupy
    # the split the policy scores for the panel-grid backends, executed
    # on xla as the context mode
    kernel_split = G.plan_for_packed(slots, pw, backend="interpret",
                                     decode=True).split_k
    splitk_plan = (dataclasses.replace(lane_plan, split_k=kernel_split)
                   if kernel_split > 1 else None)

    percall_is_policy = k >= n       # fine-panel lever: percall default
    percall_plan = G.plan(slots, n, k, backend="xla", transposed=True,
                          pack=G.PACK_PERCALL, block_n=pw.block_n,
                          block_k=pw.block_k)
    pre_plan = G.plan_for_packed(slots, pw, backend="xla", decode=False)
    assert not pre_plan.decode and pre_plan.split_k == 1

    @jax.jit
    def run_lane(x, pw):
        return G.execute(lane_plan, x, pw)

    @jax.jit
    def run_splitk(x, pw):
        return G.execute(splitk_plan, x, pw)

    @jax.jit
    def run_prepacked(x, pw):
        return G.execute(pre_plan, x, pw)

    if quant:
        # checkpoint-layout quant percall: [N, K] codes + per-(row,
        # K-group) scales, dequant AND transpose+pad inside every call
        codes_l, scales_l = F.quantize(w, fmt)
        codes_nk = (_pack_nk(codes_l.T) if fmt == "ternary"
                    else codes_l.T)
        scales_nk = scales_l.T

        @jax.jit
        def run_percall(x, codes_nk, scales_nk):
            c = _unpack_nk(codes_nk) if fmt == "ternary" \
                else codes_nk.astype(jnp.float32)
            s = jnp.repeat(scales_nk, F.GROUP_K,
                           axis=-1)[:, :c.shape[-1]]
            w_nk = jax.lax.optimization_barrier(c * s)
            return G.execute(percall_plan, x, w_nk)

        def percall():
            return run_percall(x, codes_nk, scales_nk)
    else:
        w_nk = jnp.asarray(np.asarray(w).T.copy())   # checkpoint [N, K]

        @jax.jit
        def run_percall(x, w_nk):
            return G.execute(percall_plan, x, w_nk)

        def percall():
            return run_percall(x, w_nk)

    # the prefill-policy baseline: percall where the prefill lever says
    # percall (K >= N), prepacked where it prepacks (N > K)
    base = percall if percall_is_policy else (lambda: run_prepacked(x,
                                                                    pw))

    # ---- parity gates, BEFORE timing
    y_lane = run_lane(x, pw)
    y_base = np.asarray(base())
    bitexact.assert_bit_identical(
        np.asarray(y_lane), y_base,
        f"{op} {fmt} slots={slots}: lane vs prefill-policy baseline")
    if splitk_plan is not None:
        y_split = run_splitk(x, pw)
        y_ref = jax.jit(lambda x, pw: _lane_reference(x, pw, splitk_plan)
                        .astype(y_split.dtype))(x, pw)
        bitexact.assert_bit_identical(
            np.asarray(y_split), np.asarray(y_ref),
            f"{op} {fmt} slots={slots}: split lane vs split-K jnp "
            f"reference")
        assert np.allclose(np.asarray(y_split), np.asarray(y_lane),
                           rtol=2e-4, atol=1e-5), (
            f"{op} {fmt} slots={slots}: split_k={kernel_split} lane "
            f"diverged beyond reduction-reorder tolerance")
    jax.block_until_ready(run_prepacked(x, pw))      # warm all modes

    modes = {"prefill_policy": base,
             "prefill_prepacked": lambda: run_prepacked(x, pw),
             "decode_lane": lambda: run_lane(x, pw)}
    if splitk_plan is not None:
        modes["decode_lane_splitk"] = lambda: run_splitk(x, pw)
    t = _timer(reps)(modes)
    return {
        "op": op, "N": n, "K": k, "format": fmt, "slots": slots,
        "k_ge_n": k >= n, "lever": lane_plan.lever,
        "kernel_split_k": kernel_split,
        "baseline_percall": percall_is_policy,
        "prefill_policy_ms": round(t["prefill_policy"] * 1e3, 4),
        "prefill_prepacked_ms": round(t["prefill_prepacked"] * 1e3, 4),
        "decode_lane_ms": round(t["decode_lane"] * 1e3, 4),
        "lane_splitk_ms": (round(t["decode_lane_splitk"] * 1e3, 4)
                           if splitk_plan is not None else None),
        "lane_vs_prefill_policy": round(
            t["prefill_policy"] / t["decode_lane"], 3),
        "lane_vs_prepacked": round(
            t["prefill_prepacked"] / t["decode_lane"], 3),
        "bit_exact_vs_reference": True,
    }


def _gated(rows):
    """The committed-acceptance subset: K >= N decode shapes, slots <= 4."""
    return [r for r in rows if r["k_ge_n"] and r["slots"] <= 4]


def run(reps: int = 13, dry_run: bool = False,
        max_retries: int = 4) -> list[dict]:
    rng = np.random.default_rng(9)
    rows = []
    if dry_run:
        # (256, 1024): deep enough that the kernel arm engages split-K,
        # so the dry run exercises the split dispatch + combine parity
        for fmt in FORMATS:
            rows.append(_row("dry", 256, 1024, fmt, 2, rng, 1))
        return rows
    for op, n, k in DECODE_GEMM_SHAPES:
        for fmt in FORMATS:
            for slots in SLOTS:
                # the lane does strictly less per-call work than the
                # gated rows' per-call baseline — a sub-threshold
                # median is timer noise (common.retry_on_noise)
                r, _ = common.retry_on_noise(
                    lambda extra: _row(op, n, k, fmt, slots, rng,
                                       reps + extra),
                    lambda r: not (r["k_ge_n"] and r["slots"] <= 4)
                    or r["lane_vs_prefill_policy"] >= ACCEPT_RATIO,
                    max_retries=max_retries)
                rows.append(r)
    return rows


def _serving_meta():
    """Megastep serving stats for the report meta (the ServeStats
    per-phase breakdown satellite, exercised end-to-end on a reduced
    model at D in {1, 4})."""
    from repro.models import model_zoo
    from repro.runtime.serve_loop import Engine
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    eng = Engine(cfg, model_zoo.build(cfg), max_len=64, packed=True)
    eng.warmup_plans(batch_slots=2, prefill_chunk=8, page_size=8,
                     megastep_depth=4)   # steady-state tick percentiles
    rng = np.random.default_rng(1)
    reqs = [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in (5, 17, 9, 23)]
    out = {}
    ref = None
    for depth in (1, 4):
        outs, st = eng.serve(reqs, batch_slots=2, max_new_tokens=8,
                             prefill_chunk=8, page_size=8,
                             megastep_depth=depth, sync_per_step=True)
        toks = [o.tolist() for o in outs]
        if ref is None:
            ref = toks
        assert toks == ref, "megastep depth changed served tokens"
        out[f"D={depth}"] = {
            "decode_ticks": st.decode_ticks,
            "decode_dispatches": st.decode_dispatches,
            "host_syncs": st.host_syncs,
            "prefill_tick_ms_p50": round(
                st.phase_percentile("prefill", 50), 3),
            "prefill_tick_ms_p99": round(
                st.phase_percentile("prefill", 99), 3),
            "decode_tick_ms_p50": round(
                st.phase_percentile("decode", 50), 3),
            "decode_tick_ms_p99": round(
                st.phase_percentile("decode", 99), 3),
        }
    return out


def main(argv=()):
    dry = "--dry-run" in argv
    rows = run(dry_run=dry)
    common.print_csv("table9_decode", rows)
    if dry:
        print("dry-run OK: decode lane bit-identical to the "
              "prefill-policy baseline, split lane bit-identical to "
              "its split-K reference, for every format")
        return rows
    gated = _gated(rows)
    bad = [r for r in gated if r["lane_vs_prefill_policy"] < ACCEPT_RATIO]
    assert not bad, (
        f"decode lane under {ACCEPT_RATIO}x vs the prefill-policy "
        f"baseline after retries: {bad}")
    meta = {
        "note": "decode fast lane per decode GEMM shape, every mode at "
                "the EXACT decode M: decode-arm plan (prepacked, "
                "skinny block_m, policy split-K) vs the prefill-tuned "
                "policy's default dispatch for the shape class (K>=N "
                "rows pay the lever's per-call transpose+pad, quant "
                "rows the per-call dequant on top; N>K context rows "
                "were already prepacked).  Gate: lane >= 1.15x on "
                "K>=N rows at slots <= 4, all formats.",
        "protocol": "jitted, interleaved reps, median; xla backend; "
                    "bitwise parity asserted before timing (split_k>1 "
                    "rows gate bitwise against the split-K reference, "
                    "allclose vs the reordered baseline)",
        "context_caveat": "prefill_prepacked is what PR 2/4 packed "
                          "serving actually dispatched (serving never "
                          "paid the percall baseline, which is the "
                          "policy's default for raw/checkpoint "
                          "weights — the table3/table8 protocol), so "
                          "lane_vs_prepacked ~ 1.0 is expected: on "
                          "the xla backend the lane's win is the "
                          "deleted per-call pack plus plan hygiene, "
                          "and the policy deliberately keeps split_k=1 "
                          "(no kernel grid to occupy; lane_splitk_ms "
                          "shows the restructure's CPU cost where the "
                          "panel-grid arm would split)",
        "plan_cache": tuple(G.plan_cache_info()),
        "vmem_clamped_plans": G.vmem_clamped_count(),
        "plan_store": (tuple(G.plan_store_info())
                       if G.plan_store_info() is not None else None),
        "serving_megastep": _serving_meta(),
    }
    common.write_table("table9_decode", rows, meta=meta)
    summary = {
        "all_gated_ge_ratio": all(
            r["lane_vs_prefill_policy"] >= ACCEPT_RATIO for r in gated),
        "min_lane_vs_prefill_policy_kgeN_slots_le4": min(
            r["lane_vs_prefill_policy"] for r in gated),
        "min_lane_vs_prepacked_all": min(
            r["lane_vs_prepacked"] for r in rows),
        "kernel_split_k_engaged_rows": sum(
            1 for r in rows if r["kernel_split_k"] > 1),
        "rows": rows,
    }
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "BENCH_decode.json")
    with open(path, "w") as f:
        json.dump({"meta": {"baseline_of": "table9_decode",
                            "tracked_since": "decode fast lane PR",
                            **meta},
                   "baseline": summary}, f, indent=1)
    print(f"baseline -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
