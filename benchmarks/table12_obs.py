"""Table 12 — the observability layer's no-overhead claims, gated.

An observability layer that slows the thing it observes corrupts its
own numbers, so the obs PR carries its cost budget as a committed
baseline:

1. INACTIVE IS FREE: with no recorder/tracer/metrics installed, the
   hook in ``gemm.execute`` is one module-level int check — measured
   ``execute`` vs the bare ``_execute_impl`` must agree within
   ``GATE_RTOL`` (3%) on every gated shape.
2. RECORDING IS CHEAP: with an (unfenced) flight recorder active, the
   per-dispatch record — plan fields, ring insert, seen-set probe —
   must stay within ``GATE_RTOL`` of the bare path on the gated shapes
   (dispatches big enough that the paper's serving traffic looks like
   them; the tiny-shape rows are reported but not gated, since a
   microsecond of bookkeeping is a visible fraction of a 10us GEMM and
   no serving dispatch is that small — jitted serving dispatches pay
   ZERO per-dispatch recorder cost by construction, manifests are
   registered at trace time).
3. TRACED SERVING (report-only): end-to-end ``generate`` under the
   full obs stack (tracer + recorder + metrics) vs bare, on a reduced
   engine — context for the per-dispatch gates, not gated itself
   (seconds-scale end-to-end runs drift more than 3% from machine
   noise alone).

Below-threshold measurements re-measure with more reps
(``common.retry_on_noise``) — never fudged, and a persistent failure
fails the run.  Emits ``benchmarks/out/table12_obs.json`` and the
version-tracked ``benchmarks/BENCH_obs.json``.  ``--dry-run`` gates
one shape (the CI smoke).
"""
from __future__ import annotations

import importlib
import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import gemm as G
from repro import obs

_exec = importlib.import_module("repro.gemm.execute")

GATE_RTOL = 0.03
# (m, n, k, gated): tiny shapes are context, serving-scale shapes gate
SHAPES = [(8, 64, 64, False),
          (32, 256, 256, True),
          (128, 512, 512, True),
          (256, 1024, 1024, True)]


def _measure_shape(m, n, k, *, trials):
    rng = np.random.default_rng(m + n + k)
    p = G.plan(m, n, k)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    # interleave the three paths' trials via common.time_fn's own
    # warmup; bare first compiles the kernel both variants share
    t_bare = common.time_fn(_exec._execute_impl, p, x, w, trials=trials)
    t_inact = common.time_fn(G.execute, p, x, w, trials=trials)
    rec = obs.FlightRecorder(capacity=65536)      # unfenced
    with obs.use_recorder(rec):
        t_rec = common.time_fn(G.execute, p, x, w, trials=trials)
    assert rec.total >= trials
    return {"M": m, "N": n, "K": k,
            "t_bare_us": t_bare * 1e6,
            "t_inactive_us": t_inact * 1e6,
            "t_recorder_us": t_rec * 1e6,
            "inactive_vs_bare": t_inact / t_bare,
            "recorder_vs_bare": t_rec / t_bare,
            "gflops_bare": common.gflops(m, n, k, t_bare)}


def _traced_serve_overhead(trials: int = 3):
    """End-to-end generate with the full obs stack vs bare (report-only)."""
    from repro.models import model_zoo
    from repro.runtime.serve_loop import Engine
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    eng = Engine(cfg, model_zoo.build(cfg), max_len=64, packed=True)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 24)),
                          jnp.int32)
    eng.generate(prompts, 8)                      # compile once

    def bare():
        return eng.generate(prompts, 8)[0]

    def instrumented():
        tracer, rec, reg = (obs.Tracer(), obs.FlightRecorder(),
                            obs.MetricsRegistry())
        with obs.use_tracer(tracer), obs.use_recorder(rec), \
                obs.use_metrics(reg):
            return eng.generate(prompts, 8)[0]

    t_bare = common.time_fn(bare, trials=trials, warmup=1)
    t_obs = common.time_fn(instrumented, trials=trials, warmup=1)
    return {"t_bare_s": t_bare, "t_obs_s": t_obs,
            "obs_vs_bare": t_obs / t_bare}


def run(dry_run: bool = False, trials: int = 30, noise_retries: int = 4):
    shapes = [(64, 256, 256, True)] if dry_run else SHAPES
    rows = []
    for m, n, k, gated in shapes:
        def accept(r):
            if not gated:
                return True
            return (r["inactive_vs_bare"] <= 1.0 + GATE_RTOL
                    and r["recorder_vs_bare"] <= 1.0 + GATE_RTOL)
        r, tries = common.retry_on_noise(
            lambda extra: _measure_shape(m, n, k,
                                         trials=trials + 10 * extra),
            accept, max_retries=noise_retries)
        r["gated"] = gated
        r["noise_retries"] = tries
        rows.append(r)
    serve = None if dry_run else _traced_serve_overhead()
    return rows, serve


def main(argv=()):
    dry = "--dry-run" in argv
    rows, serve = run(dry_run=dry, trials=10 if dry else 30)
    common.print_csv("table12_obs", rows)
    bad = [r for r in rows if r["gated"] and
           (r["inactive_vs_bare"] > 1.0 + GATE_RTOL
            or r["recorder_vs_bare"] > 1.0 + GATE_RTOL)]
    assert not bad, \
        f"obs overhead gate failed ({GATE_RTOL:.0%} budget): {bad}"
    if serve is not None:
        print(f"# traced serve (report-only): obs_vs_bare "
              f"{serve['obs_vs_bare']:.3f}")
    if dry:
        print("dry-run OK: inactive hook and active recorder both "
              f"within {GATE_RTOL:.0%} of the bare GEMM path")
        return rows
    meta = {
        "note": "obs overhead gates: inactive execute-hook and active "
                "(unfenced) flight recorder vs the bare GEMM path, "
                f"<= {GATE_RTOL:.0%} on gated (serving-scale) shapes; "
                "tiny shapes reported for context, not gated; traced "
                "end-to-end generate reported, not gated",
        "protocol": "median over >=30 blocked trials per path; "
                    "retry_on_noise with +10 reps per retry",
        "gate_rtol": GATE_RTOL,
        "schema": G.SCHEMA_VERSION,
        "host": G.host_fingerprint(),
        "traced_serve": serve,
    }
    common.write_table("table12_obs", rows, meta=meta)
    summary = {
        "max_inactive_vs_bare_gated": max(r["inactive_vs_bare"]
                                          for r in rows if r["gated"]),
        "max_recorder_vs_bare_gated": max(r["recorder_vs_bare"]
                                          for r in rows if r["gated"]),
        "gate_rtol": GATE_RTOL,
        "traced_serve_obs_vs_bare": serve["obs_vs_bare"],
        "rows": rows,
    }
    path = os.path.join(os.path.dirname(__file__), "BENCH_obs.json")
    with open(path, "w") as f:
        json.dump({"meta": {"baseline_of": "table12_obs",
                            "tracked_since": "observability layer PR",
                            **meta},
                   "baseline": summary}, f, indent=1)
    print(f"baseline -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
