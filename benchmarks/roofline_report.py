"""Aggregate dry-run cells into the §Roofline table (markdown + CSV).

Reads experiments/dryrun/*.json written by repro.launch.dryrun and emits
the per-(arch × shape × mesh) three-term roofline with the dominant
bottleneck, useful-FLOP ratio, and a one-line "what would move the
dominant term" note derived from the cell's own breakdown.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common

DRYRUN_DIR = "experiments/dryrun"


def _advice(rec: dict) -> str:
    r = rec["roofline"]
    dom = r["dominant"]
    coll = rec.get("collectives", {})
    if dom == "collective":
        kinds = coll.get("by_kind_s", {})
        worst = max(kinds, key=kinds.get) if kinds else "?"
        if worst == "all-reduce" and rec["step"] == "train_step":
            return ("grad sync dominates: reduce-scatter into sharded "
                    "accumulators (+bf16 wire) instead of per-microbatch "
                    "all-reduce")
        if worst == "all-gather":
            return ("weight all-gathers dominate: hoist out of the "
                    "microbatch loop / overlap with matmul panels")
        return f"dominant collective: {worst}; overlap or reshard"
    if dom == "memory":
        if rec["step"] == "train_step":
            return ("attention residuals dominate HBM: flash custom-VJP "
                    "(recompute scores per chunk) instead of scan-saved "
                    "residuals")
        return ("cache traffic dominates: avoid chunk-restack copies; "
                "read KV in place (Pallas flash path on TPU)")
    return "compute-bound: at the MXU roofline; only useful-ratio helps"


def load_cells(pattern: str = "*.json") -> list[dict]:
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern)))
    if not files:
        raise FileNotFoundError(f"no dry-run cells under {DRYRUN_DIR}")
    return [json.load(open(f)) for f in files]


def rows(cells) -> list[dict]:
    out = []
    for rec in cells:
        if not rec.get("ok"):
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "step": "-", "chips": "-",
                        "compute_ms": "-", "memory_ms": "-",
                        "collective_ms": "-", "dominant": "FAILED",
                        "useful_ratio": "-", "mfu_bound": "-",
                        "fits_hbm": "-",
                        "note": rec.get("error", "")[:80]})
            continue
        r = rec["roofline"]
        out.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "step": rec["step"], "chips": rec["chips"],
            "compute_ms": round(r["compute_s"] * 1e3, 3),
            "memory_ms": round(r["memory_s"] * 1e3, 3),
            "memory_adj_ms": round(
                r.get("memory_adjusted_s", r["memory_s"]) * 1e3, 3),
            "collective_ms": round(r["collective_s"] * 1e3, 3),
            "dominant": r["dominant"],
            "dominant_adj": r.get("dominant_adjusted", r["dominant"]),
            "useful_ratio": round(r["useful_ratio"], 3),
            "mfu_bound": round(r["mfu_upper_bound"], 4),
            "fits_hbm": rec["fits_hbm"],
            "note": _advice(rec),
        })
    return out


def main(pattern: str = "*.json"):
    rs = rows(load_cells(pattern))
    common.print_csv("roofline (from dry-run cells)", rs)
    common.write_table("roofline_report", rs)
    n_fail = sum(1 for r in rs if r["dominant"] == "FAILED")
    print(f"{len(rs)} cells, {n_fail} failed")
    return rs


if __name__ == "__main__":
    main()
