"""Paper Table 4 — bit-exactness grid.

The paper's discipline: the proposed kernel is bit-identical to the
reference at every shape (max-abs-diff = 0e+00, coprime-stride sampled),
while BNNS Graph silently computes nine of twelve shapes at reduced
precision.  Here the roles are:

  proposed (Pallas panel_gemm, interpret) vs blocked oracle — must be
      BITWISE identical (the kernel's accumulation order is its spec);
  proposed vs XLA dot (the "other backend") — fp32 summation-order diff
      measured at the paper's coprime strides and REPORTED, not hidden.

Shapes are the paper's twelve at 1/8 scale (interpret mode executes the
kernel body in Python — correctness is scale-invariant, wall-clock is
not).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks import common
from repro.core import bitexact
from repro.kernels import ref
from repro.kernels.panel_gemm import panel_gemm
from repro.models.model_zoo import PAPER_GEMM_SHAPES, PAPER_M


def run(scale: int = 8) -> list[dict]:
    rng = np.random.default_rng(1)
    rows = []
    for model, op, n_full, k_full in PAPER_GEMM_SHAPES:
        m = PAPER_M
        # kernel-divisible reductions of the paper shapes (the pack pads
        # in deployment; here the kernel is called directly)
        n = max(512, n_full // scale // 512 * 512)
        k = max(512, k_full // scale // 512 * 512)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        bk = min(512, k)
        y = panel_gemm(x, w, block_m=128, block_n=min(512, n), block_k=bk,
                       interpret=True)
        oracle = ref.gemm_blocked(x, w, bk)
        xla = ref.gemm_xla(x, w)
        rep = bitexact.report(np.asarray(y), np.asarray(oracle))
        rows.append({
            "model": model, "op": op, "N": n, "K": k,
            "bit_exact_vs_oracle": rep["bit_exact"],
            "maxdiff_oracle_997": rep["max_abs_diff_997"],
            "maxdiff_xla_997": bitexact.max_abs_diff_sampled(
                np.asarray(y), np.asarray(xla), 997),
            "maxdiff_xla_1023": bitexact.max_abs_diff_sampled(
                np.asarray(y), np.asarray(xla), 1023),
        })
    return rows


def main():
    rs = run()
    common.print_csv("table4_bitexact", rs)
    assert all(r["bit_exact_vs_oracle"] for r in rs), \
        "kernel not bit-identical to its oracle"
    assert all(r["maxdiff_oracle_997"] == 0.0 for r in rs)
    common.write_table("table4_bitexact", rs, meta={
        "note": "proposed kernel bit-identical to blocked oracle at all "
                "twelve shapes; diff vs XLA dot is fp32 reorder only "
                "(reported like the paper's BNNS-Graph diff column)"})
    return rs


if __name__ == "__main__":
    main()
