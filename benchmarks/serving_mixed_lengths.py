"""Mixed-length serving sweep: phase-locked chunked loop vs continuous
batching.

The scenario the old ``serve_chunked`` loop cannot express efficiently:
requests arrive with mixed prompt lengths AND a heavy-tailed mix of
generation budgets (mostly short chats, a fraction of long
generations — serving's classic traffic shape).  The chunked loop pads
every prompt to the global ``prompt_len`` and runs every chunk for its
slowest request's ``max_new``, so nearly every chunk is held hostage by
one long request while the short requests' slots burn steps producing
tokens nobody asked for.  The continuous pool (runtime/batching)
prefills true lengths in admission chunks and refills a slot the step
its request finishes.

Reported metric: *useful* generated tokens per wall second (tokens a
request actually asked for; the chunked loop's over-generation counts
nothing).  Both loops share one packed Engine — same weights, same jit
caches — so the ratio isolates the scheduling discipline, in the spirit
of the paper's within-invocation ratios.  ``parity_ok`` spot-checks the
continuous outputs against per-request greedy ``generate`` (the chunked
loop's own outputs are garbage for padded prompts — that bug is part of
what this table documents).

``--dry-run`` shrinks everything to seconds and skips nothing
structurally — CI runs it so the harness can't rot.
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import model_zoo
from repro.runtime.serve_loop import Engine


def make_workload(rng, *, requests: int, prompt_len: int, max_new: int,
                  vocab: int, tail_frac: float = 0.3,
                  share_ratio: float = 0.0):
    """Mixed prompt lengths + heavy-tailed generation budgets.

    ``share_ratio > 0`` draws the prompts from the shared-prefix trace
    generator (``common.shared_prefix_trace``, the table10 workload) so
    this sweep can be run against prefix-cache-friendly traffic too."""
    if share_ratio > 0:
        reqs, _ = common.shared_prefix_trace(
            rng, requests=requests, prompt_len=prompt_len, vocab=vocab,
            share_ratio=share_ratio)
    else:
        reqs = [rng.integers(1, vocab,
                             rng.integers(4, prompt_len + 1))
                .astype(np.int32) for _ in range(requests)]
    short_hi = max(3, min(6, max_new))
    mns = [int(rng.integers(max(1, (3 * max_new) // 4), max_new + 1))
           if rng.random() < tail_frac
           else int(rng.integers(2, short_hi))
           for _ in range(requests)]
    return reqs, mns


def run(*, arch: str, requests: int, prompt_len: int, max_new: int,
        batch_slots_sweep, prefill_chunk: int, page_size: int,
        seed: int = 0, reps: int = 5,
        share_ratio: float = 0.0) -> list[dict]:
    cfg = model_zoo.reduced_config(model_zoo.get_config(arch))
    params = model_zoo.build(cfg)
    max_len = prompt_len + max_new
    max_len += (-max_len) % page_size
    eng = Engine(cfg, params, max_len=max_len, packed=True)

    rng = np.random.default_rng(seed)
    reqs, mns = make_workload(rng, requests=requests,
                              prompt_len=prompt_len, max_new=max_new,
                              vocab=cfg.vocab_size,
                              share_ratio=share_ratio)
    useful = sum(mns)

    # parity spot check: shortest and longest prompt vs per-request greedy
    spots = [int(np.argmin([len(r) for r in reqs])),
             int(np.argmax([len(r) for r in reqs]))]
    refs = {i: np.asarray(eng.generate(jnp.asarray(reqs[i])[None],
                                       mns[i])[0][0]) for i in spots}

    rows = []
    for slots in batch_slots_sweep:
        # common.py's protocol, adapted: interleave the two loops (so
        # machine drift cancels within the ratio), warm both traces
        # untimed, then take the median over reps
        eng.serve_chunked(reqs, batch_slots=slots, prompt_len=prompt_len,
                          max_new_tokens=mns)
        out_new, _ = eng.serve(reqs, batch_slots=slots, max_new_tokens=mns,
                               prefill_chunk=prefill_chunk,
                               page_size=page_size)
        ts_old, ts_new = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            eng.serve_chunked(reqs, batch_slots=slots,
                              prompt_len=prompt_len, max_new_tokens=mns)
            ts_old.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            out_new, _ = eng.serve(reqs, batch_slots=slots,
                                   max_new_tokens=mns,
                                   prefill_chunk=prefill_chunk,
                                   page_size=page_size)
            ts_new.append(time.perf_counter() - t0)
        t_old = float(np.median(ts_old))
        t_new = float(np.median(ts_new))

        # latency columns come from a separate per-step-synced run: under
        # the async dispatch used for the throughput reps, TTFT would
        # measure host dispatch, not token availability
        _, sstats = eng.serve(reqs, batch_slots=slots, max_new_tokens=mns,
                              prefill_chunk=prefill_chunk,
                              page_size=page_size, sync_per_step=True)

        parity = all(np.array_equal(out_new[i], refs[i]) for i in spots)
        rows.append({
            "batch_slots": slots, "requests": requests,
            "useful_tokens": useful,
            "chunked_tps": round(useful / t_old, 1),
            "continuous_tps": round(useful / t_new, 1),
            "speedup": round(t_old / t_new, 3),
            "ttft_p95_ms": round(sstats.percentile("ttft_s", 95) * 1e3, 1),
            "parity_ok": parity,
        })
    return rows


def main(dry_run: bool = False):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=model_zoo.list_archs())
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--batch-slots", default="1,2,4")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--share-ratio", type=float, default=0.0,
                    help="fraction of requests opening with a shared "
                         "preamble (common.shared_prefix_trace; 0 = the "
                         "classic fully-unique mixed-length trace)")
    ap.add_argument("--dry-run", action="store_true",
                    help="smallest structurally-complete run (CI smoke)")
    args = ap.parse_args()
    if dry_run:
        args.dry_run = True

    kw = dict(arch=args.arch, requests=args.requests,
              prompt_len=args.prompt_len, max_new=args.max_new,
              batch_slots_sweep=[int(s) for s in
                                 args.batch_slots.split(",")],
              prefill_chunk=args.prefill_chunk, page_size=args.page_size,
              share_ratio=args.share_ratio)
    if args.dry_run:
        kw.update(requests=4, prompt_len=16, max_new=4,
                  batch_slots_sweep=[2], prefill_chunk=8, page_size=8)

    rows = run(**kw)
    common.print_csv("serving_mixed_lengths", rows)
    if args.dry_run:
        print("(dry-run: structural smoke only — timings at this scale "
              "are scheduler overhead, not a measurement)")
    if not args.dry_run:
        common.write_table("serving_mixed_lengths", rows, meta={
            "note": "mixed prompt+generation lengths; useful tok/s = "
                    "requested tokens / wall. Continuous batching must "
                    "strictly beat the chunked loop at batch_slots >= 2 "
                    "(ISSUE 2 acceptance gate; asserted by "
                    "tests/test_serving.py)",
            **{k: v for k, v in kw.items() if k != "batch_slots_sweep"}})
    return rows


if __name__ == "__main__":
    main()
