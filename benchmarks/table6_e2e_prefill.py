"""Paper Table 6 — end-to-end prefill GEMM sequence, measured.

Runs each model's full prefill GEMM sequence in layer order at S = 128
(per block: Q, K, V, attention-out, FFN-gate, FFN-up, FFN-down; once at
the end: LM head), with the weight handling each backend implies:

  xla      — raw dot per GEMM ("Accelerate")
  percall  — transpose+pad W[N,K] inside every call (cblas/BNNSMatMul)
  packed   — all weights packed once BEFORE the timed region (untimed,
             exactly the paper's model-load protocol); timed region pays
             compute only.
  fused    — the packed path with horizontal fusion + fused epilogues:
             Q/K/V ride ONE fused pack (split map), gate+up ride one
             glu-epilogue pack (``silu(gate) * up`` combined in the
             store step).  7 GEMM dispatches per block become 4 — the
             activations stream from HBM once per fused group and the
             [M, 2F] gate-up intermediate never materializes.
  chunked  — the packed path at continuous-batching admission shapes:
             the S = 128 panel arrives as S_CHUNK-row prefill chunks
             (runtime/batching's chunked admission), each chunk hitting
             the SAME pre-resolved plan — the table records the plan
             cache staying cold-miss-free across the whole chunked
             sequence (plans stay hot under continuous batching,
             docs/serving.md).

Like the paper's §4.7 the activation handling stays inside the timed
region, so the comparison is conservative for the packed path.  Shapes
default to 1/4 scale per dim (CPU budget); --full for exact.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import gemm as G
from repro.core import packing

# (model, H, F, V, L) — paper Table 6
MODELS = [
    ("tinyllama-1.1b", 2048, 5632, 32000, 22),
    ("llama-7b", 4096, 11008, 32000, 32),
]
S = 128
S_CHUNK = G.bucket_m(32)      # serving admission width (plan bucket)

# per-block GEMM sequences (op names index the weight dict)
UNFUSED_BLOCK = ["q", "k", "v", "attn_out", "ffn_gate", "ffn_up",
                 "ffn_down"]
FUSED_BLOCK = ["qkv", "attn_out", "gate_up", "ffn_down"]


def _block_shapes(h, f, v, scale):
    h, f, v = h // scale, f // scale, v // scale
    per_block = [("q", h, h), ("k", h, h), ("v", h, h), ("attn_out", h, h),
                 ("ffn_gate", f, h), ("ffn_up", f, h), ("ffn_down", h, f)]
    return per_block, ("lm_head", v, h)


def run(scale: int = 4, reps: int = 7) -> list[dict]:
    rng = np.random.default_rng(2)
    rows = []
    glu = G.EpilogueSpec(glu="silu")
    for name, h, f, v, layers in MODELS:
        per_block, head = _block_shapes(h, f, v, scale)
        # weights stored [N, K] (llama.cpp convention)
        weights = {op: jnp.asarray(rng.standard_normal((n, k)) * 0.02,
                                   jnp.float32)
                   for op, n, k in per_block + [head]}
        xs = {op: jnp.asarray(rng.standard_normal((S, k)), jnp.float32)
              for op, n, k in per_block + [head]}
        seq = UNFUSED_BLOCK * layers + [head[0]]

        def seq_once(block_fn, head_fn, layers=layers):
            """One timed pass over the whole prefill sequence: ``layers``
            transformer blocks + the LM head.  Each mode's block_fn runs
            the SAME per-block computation (q/k/v, attn-out, silu(gate)
            * up, down) so fused vs unfused is apples-to-apples — the
            unfused modes pay their combine as separate XLA ops, the
            fused mode inside the GEMM epilogue."""
            t0 = time.perf_counter()
            outs = []
            for _ in range(layers):
                outs.extend(block_fn())
            outs.append(head_fn())
            jax.block_until_ready(outs)
            return time.perf_counter() - t0

        def time_modes(modes: dict) -> dict:
            """Interleave the modes within each rep (the paper's
            within-invocation ratio discipline — machine drift cancels
            across modes instead of biasing whichever ran last)."""
            ts = {name: [] for name in modes}
            for _ in range(reps):
                for name, (bf, hf) in modes.items():
                    ts[name].append(seq_once(bf, hf))
            return {name: float(np.median(v)) for name, v in ts.items()}

        # plan resolution + packed model load (untimed, paper protocol);
        # plans are hoisted so the timed region pays dispatch only
        packed = {op: packing.pack(w, transposed=True, block_n=512,
                                   block_k=512)
                  for op, w in weights.items()}
        plans = {}
        for op, n, k in per_block + [head]:
            plans[op] = {
                "xla": G.plan(S, n, k, backend="xla", pack=G.PACK_NONE,
                              transposed=True),
                "percall": G.plan(S, n, k, backend="xla",
                                  pack=G.PACK_PERCALL, block_n=512,
                                  block_k=512, transposed=True),
                "packed": G.plan_for_packed(S, packed[op], backend="xla"),
                "chunked": G.plan_for_packed(S_CHUNK, packed[op],
                                             backend="xla"),
            }
        # ---- fused model load: QKV one pack (split map), gate+up one
        # glu pack (blocks budget the two-accumulator store phase)
        hh = h // scale
        fused = {
            "qkv": packing.pack_fused(
                [weights["q"], weights["k"], weights["v"]],
                transposed=True, block_n=512, block_k=512),
            "attn_out": packed["attn_out"],
            "ffn_down": packed["ffn_down"],
        }
        bn_gu, bk_gu = G.pack_blocks(2 * (f // scale), hh, epilogue=glu,
                                     block_n=512, block_k=512)
        fused["gate_up"] = packing.pack_fused(
            [weights["ffn_gate"], weights["ffn_up"]], transposed=True,
            block_n=bn_gu, block_k=bk_gu)
        fused_plans = {
            "qkv": G.plan_for_packed(S, fused["qkv"], backend="xla"),
            "attn_out": plans["attn_out"]["packed"],
            "gate_up": G.plan_for_packed(S, fused["gate_up"],
                                         backend="xla", epilogue=glu),
            "ffn_down": plans["ffn_down"]["packed"],
            "lm_head": plans["lm_head"]["packed"],
        }
        fused_xs = {"qkv": xs["q"], "attn_out": xs["attn_out"],
                    "gate_up": xs["ffn_gate"], "ffn_down": xs["ffn_down"]}
        fused_w = fused

        # every mode's per-block step is jitted, exactly like the serving
        # engine's steps — the timed region dispatches compiled
        # computations; compile (like the pack) is model-load work
        def unfused_block(mode, wsrc):
            @jax.jit
            def block(xs, ws):
                outs = [G.execute(plans[op][mode], xs[op], ws[op])
                        for op in ("q", "k", "v", "attn_out")]
                g = G.execute(plans["ffn_gate"][mode], xs["ffn_gate"],
                              ws["ffn_gate"])
                u = G.execute(plans["ffn_up"][mode], xs["ffn_up"],
                              ws["ffn_up"])
                outs.append(jax.nn.silu(g) * u)     # the model's combine
                outs.append(G.execute(plans["ffn_down"][mode],
                                      xs["ffn_down"], ws["ffn_down"]))
                return outs
            return lambda: block(xs, wsrc)

        @jax.jit
        def _fused_block(fxs, fws):
            y = G.execute(fused_plans["qkv"], fxs["qkv"], fws["qkv"])
            outs = list(G.split_fused(fused_plans["qkv"], y))
            outs.append(G.execute(fused_plans["attn_out"],
                                  fxs["attn_out"], fws["attn_out"]))
            outs.append(G.execute(fused_plans["gate_up"], fxs["gate_up"],
                                  fws["gate_up"]))   # combine inside
            outs.append(G.execute(fused_plans["ffn_down"],
                                  fxs["ffn_down"], fws["ffn_down"]))
            return outs

        def fused_block():
            return _fused_block(fused_xs, fused_w)

        def head_call(mode, wsrc):
            return G.execute(plans["lm_head"][mode], xs["lm_head"],
                             wsrc["lm_head"])

        # ONE closure per mode, compiled at warmup and reused in the
        # timed region (a fresh @jax.jit closure per phase would push
        # the unfused modes' compile into their first timed rep)
        modes = {
            "xla": (unfused_block("xla", weights),
                    lambda: head_call("xla", weights)),
            "percall": (unfused_block("percall", weights),
                        lambda: head_call("percall", weights)),
            "packed": (unfused_block("packed", packed),
                       lambda: head_call("packed", packed)),
            "fused": (fused_block, lambda: head_call("packed", packed)),
        }
        for bf, hf in modes.values():              # warmup / compile
            jax.block_until_ready(bf())
            jax.block_until_ready(hf())

        timed = time_modes(modes)
        t_xla, t_percall = timed["xla"], timed["percall"]
        t_packed, t_fused = timed["packed"], timed["fused"]

        # chunked admission: the same 128-row panel, S_CHUNK rows at a
        # time.  Plans are re-RESOLVED per chunk (the serving hot path:
        # plan_for_packed -> cache lookup) so the miss counter genuinely
        # verifies key stability — if the chunk shapes stopped hitting
        # one key, misses would move inside the timed region.
        for op in set(seq):
            G.execute(plans[op]["chunked"], xs[op][:S_CHUNK], packed[op])
        miss0 = G.plan_cache_info().misses

        def chunked_block():
            outs = []
            for op in UNFUSED_BLOCK:
                for i in range(0, S, S_CHUNK):
                    outs.append(G.execute(
                        G.plan_for_packed(S_CHUNK, packed[op],
                                          backend="xla"),
                        xs[op][i:i + S_CHUNK], packed[op]))
            return outs

        t_chunked = time_modes({
            "chunked": (chunked_block,
                        lambda: head_call("packed", packed))})["chunked"]
        chunk_misses = G.plan_cache_info().misses - miss0

        rows.append({
            "model": name, "H": h // scale, "F": f // scale,
            "V": v // scale, "L": layers,
            "xla_ms": round(t_xla * 1e3, 1),
            "percall_ms": round(t_percall * 1e3, 1),
            "packed_ms": round(t_packed * 1e3, 1),
            "fused_ms": round(t_fused * 1e3, 1),
            "chunked_ms": round(t_chunked * 1e3, 1),
            "packed_vs_percall": round(t_percall / t_packed, 3),
            "packed_vs_xla": round(t_xla / t_packed, 3),
            "fused_vs_packed": round(t_packed / t_fused, 3),
            "gemms_block_unfused": len(UNFUSED_BLOCK),
            "gemms_block_fused": len(FUSED_BLOCK),
            "dispatches_saved_per_block": (len(UNFUSED_BLOCK)
                                           - len(FUSED_BLOCK)),
            "chunk_overhead": round(t_chunked / t_packed, 3),
            "chunk_plan_misses": chunk_misses,
        })
    return rows


def _ps_meta():
    info = G.plan_store_info()
    return tuple(info) if info is not None else None


def main(full: bool = False):
    rs = run(scale=1 if full else 4)
    common.print_csv("table6_e2e_prefill", rs)
    info = G.plan_cache_info()
    clamped = G.vmem_clamped_count()
    print(f"# plan cache: {info.hits} hits / {info.misses} misses "
          f"({info.currsize} cached, {clamped} vmem-clamped)")
    common.write_table("table6_e2e_prefill", rs, meta={
        "note": "paper T6: packed weights win the full prefill GEMM "
                "sequence (paper: 1.42x/1.50x vs BNNSMatMul, 1.80x/2.67x "
                "vs cblas); fused = horizontal QKV + glu gate-up fusion "
                "on the packed path (7 -> 4 GEMM dispatches per block, "
                "fused_vs_packed >= 1.0 expected); chunked = same "
                "sequence at the serving pool's admission width, "
                "chunk_plan_misses must be 0 (plans stay hot under "
                "continuous batching)",
        "s_chunk": S_CHUNK, "scale": 1 if full else 4,
        # dispatch observability (previously invisible in reports):
        # plan churn + how many plans the VMEM budget clamped
        "plan_cache": tuple(info), "vmem_clamped_plans": clamped,
        # persistent plan store (None unless the run scoped one):
        # store hits/misses/autotuned/entries — warm-run observability
        "plan_store": _ps_meta()})
    return rs


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
