"""Paper Table 6 — end-to-end prefill GEMM sequence, measured.

Runs each model's full prefill GEMM sequence in layer order at S = 128
(per block: Q, K, V, attention-out, FFN-up, FFN-down; once at the end:
LM head), with the weight handling each backend implies:

  xla      — raw dot per GEMM ("Accelerate")
  percall  — transpose+pad W[N,K] inside every call (cblas/BNNSMatMul)
  packed   — all weights packed once BEFORE the timed region (untimed,
             exactly the paper's model-load protocol); timed region pays
             compute only.
  chunked  — the packed path at continuous-batching admission shapes:
             the S = 128 panel arrives as S_CHUNK-row prefill chunks
             (runtime/batching's chunked admission), each chunk hitting
             the SAME pre-resolved plan — the table records the plan
             cache staying cold-miss-free across the whole chunked
             sequence (plans stay hot under continuous batching,
             docs/serving.md).

Like the paper's §4.7 the activation handling stays inside the timed
region, so the comparison is conservative for the packed path.  Shapes
default to 1/4 scale per dim (CPU budget); --full for exact.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import gemm as G
from repro.core import packing

# (model, H, F, V, L) — paper Table 6
MODELS = [
    ("tinyllama-1.1b", 2048, 5632, 32000, 22),
    ("llama-7b", 4096, 11008, 32000, 32),
]
S = 128
S_CHUNK = G.bucket_m(32)      # serving admission width (plan bucket)


def _block_shapes(h, f, v, scale):
    h, f, v = h // scale, f // scale, v // scale
    per_block = [("q", h, h), ("k", h, h), ("v", h, h), ("attn_out", h, h),
                 ("ffn_up", f, h), ("ffn_down", h, f)]
    return per_block, ("lm_head", v, h)


def run(scale: int = 4, reps: int = 3) -> list[dict]:
    rng = np.random.default_rng(2)
    rows = []
    for name, h, f, v, layers in MODELS:
        per_block, head = _block_shapes(h, f, v, scale)
        # weights stored [N, K] (llama.cpp convention)
        weights = {op: jnp.asarray(rng.standard_normal((n, k)) * 0.02,
                                   jnp.float32)
                   for op, n, k in per_block + [head]}
        xs = {op: jnp.asarray(rng.standard_normal((S, k)), jnp.float32)
              for op, n, k in per_block + [head]}
        seq = [op for op, _, _ in per_block] * layers + [head[0]]

        def time_seq(call):
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                outs = [call(op) for op in seq]
                jax.block_until_ready(outs)
                ts.append(time.perf_counter() - t0)
            return float(np.median(ts))

        # plan resolution + packed model load (untimed, paper protocol);
        # plans are hoisted so the timed region pays dispatch only
        packed = {op: packing.pack(w, transposed=True, block_n=512,
                                   block_k=512)
                  for op, w in weights.items()}
        plans = {}
        for op, n, k in per_block + [head]:
            plans[op] = {
                "xla": G.plan(S, n, k, backend="xla", pack=G.PACK_NONE,
                              transposed=True),
                "percall": G.plan(S, n, k, backend="xla",
                                  pack=G.PACK_PERCALL, block_n=512,
                                  block_k=512, transposed=True),
                "packed": G.plan_for_packed(S, packed[op], backend="xla"),
                "chunked": G.plan_for_packed(S_CHUNK, packed[op],
                                             backend="xla"),
            }
        for op in set(seq):        # warmup
            G.execute(plans[op]["xla"], xs[op], weights[op])
            G.execute(plans[op]["percall"], xs[op], weights[op])
            G.execute(plans[op]["packed"], xs[op], packed[op])
            G.execute(plans[op]["chunked"], xs[op][:S_CHUNK], packed[op])

        t_xla = time_seq(lambda op: G.execute(plans[op]["xla"], xs[op],
                                              weights[op]))
        t_percall = time_seq(lambda op: G.execute(plans[op]["percall"],
                                                  xs[op], weights[op]))
        t_packed = time_seq(lambda op: G.execute(plans[op]["packed"],
                                                 xs[op], packed[op]))

        # chunked admission: the same 128-row panel, S_CHUNK rows at a
        # time.  Plans are re-RESOLVED per chunk (the serving hot path:
        # plan_for_packed -> cache lookup) so the miss counter genuinely
        # verifies key stability — if the chunk shapes stopped hitting
        # one key, misses would move inside the timed region.
        miss0 = G.plan_cache_info().misses
        t_chunked = time_seq(lambda op: [
            G.execute(G.plan_for_packed(S_CHUNK, packed[op],
                                        backend="xla"),
                      xs[op][i:i + S_CHUNK], packed[op])
            for i in range(0, S, S_CHUNK)])
        chunk_misses = G.plan_cache_info().misses - miss0

        rows.append({
            "model": name, "H": h // scale, "F": f // scale,
            "V": v // scale, "L": layers,
            "xla_ms": round(t_xla * 1e3, 1),
            "percall_ms": round(t_percall * 1e3, 1),
            "packed_ms": round(t_packed * 1e3, 1),
            "chunked_ms": round(t_chunked * 1e3, 1),
            "packed_vs_percall": round(t_percall / t_packed, 3),
            "packed_vs_xla": round(t_xla / t_packed, 3),
            "chunk_overhead": round(t_chunked / t_packed, 3),
            "chunk_plan_misses": chunk_misses,
        })
    return rows


def main(full: bool = False):
    rs = run(scale=1 if full else 4)
    common.print_csv("table6_e2e_prefill", rs)
    common.write_table("table6_e2e_prefill", rs, meta={
        "note": "paper T6: packed weights win the full prefill GEMM "
                "sequence (paper: 1.42x/1.50x vs BNNSMatMul, 1.80x/2.67x "
                "vs cblas); chunked = same sequence at the serving "
                "pool's admission width, chunk_plan_misses must be 0 "
                "(plans stay hot under continuous batching)",
        "s_chunk": S_CHUNK, "scale": 1 if full else 4})
    return rs


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
