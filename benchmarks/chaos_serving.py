"""Chaos serving — the survivor-parity gate as a benchmark row.

The serving stack's robustness claim (request-level fault isolation) is
a *numerics* claim, so it gates like one: for every injected fault mix,
requests that complete must return tokens BIT-IDENTICAL to a fault-free
serve of the same trace, every failed request must end in a structured
``RequestOutcome`` (state + reason, partial tokens salvaged), and the
page pool must audit clean (``assert_all_free`` runs at every run
teardown — a completed row IS the zero-leak certificate).

Fault mixes (runtime/faults, seeded — the same seed fires the same
faults at the same occurrences):

  clean             no injection; parity vs per-request ``generate``
  transient_retry   first prefill + decode dispatch fail once; the
                    retry absorbs both, zero failed requests
  backend_fallback  both primary decode attempts fail; the xla
                    fallback step set serves, still bit-exact
  poison_prefill    one request's prefill fails through the whole
                    ladder; it alone is quarantined
  poison_decode     one request's decode fails mid-generation; single-
                    victim eviction, partial tokens salvaged
  alloc_oom         injected OutOfPagesError on page-pool takes;
                    victims fail structurally, survivors keep parity
  deadline          one request enters with an expired total budget;
                    it times out, the rest serve normally
  prefix_error      prefix-cache lookups/admits fail randomly with the
                    cache ON; every request still completes (cold
                    degradation) with full parity
  slow_tick         injected straggler ticks; the watchdog flags them
                    (reported), nothing fails
  combined          several of the above at once

Reports per-mix completion/failure/retry/degradation counters and the
survivor-parity verdict.  All gates are asserts: a violated guarantee
exits non-zero.  Emits ``benchmarks/out/chaos_serving.json`` (transient)
and the version-tracked ``benchmarks/BENCH_chaos.json`` baseline;
``--dry-run`` (CI serving-smoke job) shrinks the trace but runs every
mix and every gate.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import model_zoo
from repro.runtime import faults as F
from repro.runtime import kv_cache as KV
from repro.runtime.batching import RequestState
from repro.runtime.serve_loop import Engine


def _refs(eng, reqs, mns):
    return [np.asarray(eng.generate(jnp.asarray(r)[None], m)[0][0])
            for r, m in zip(reqs, mns)]


def _gate_mix(label, outs, refs, stats, *, expect_failed=None,
              expect_clean=False):
    """The headline gate: DONE == bitwise fault-free; non-DONE ==
    structured outcome with salvaged-partial parity."""
    failed = set()
    for i, (o, r) in enumerate(zip(outs, refs)):
        oc = stats.outcomes[i]
        if oc.state == RequestState.DONE:
            assert o is not None and np.array_equal(o, r), (
                f"{label}: survivor {i} diverged from fault-free run")
        else:
            failed.add(i)
            assert o is None, f"{label}: failed request {i} returned tokens"
            assert oc.error is not None, (
                f"{label}: request {i} failed without a reason")
            if oc.state == RequestState.FAILED:
                assert oc.error_type is not None
            if oc.tokens is not None:
                assert np.array_equal(oc.tokens, r[:len(oc.tokens)]), (
                    f"{label}: request {i}'s salvaged partial diverged")
    if expect_clean:
        assert not failed, f"{label}: unexpected failures {sorted(failed)}"
    if expect_failed is not None:
        assert failed == set(expect_failed), (
            f"{label}: failure set {sorted(failed)} != expected "
            f"{sorted(expect_failed)}")
    return failed


def _row(label, plan, eng, reqs, mns, refs, *, serve_kw=None,
         expect_failed=None, expect_clean=False, budgets=None) -> dict:
    kw = dict(batch_slots=3, prefill_chunk=8, page_size=8)
    kw.update(serve_kw or {})
    ctx = F.use_faults(plan) if plan is not None else None
    try:
        if ctx is not None:
            ctx.__enter__()
        outs, stats = eng.serve(reqs, max_new_tokens=mns,
                                total_budget_s=budgets, **kw)
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
    failed = _gate_mix(label, outs, refs, stats,
                       expect_failed=expect_failed,
                       expect_clean=expect_clean)
    return {
        "mix": label,
        "requests": len(reqs),
        "completed": stats.completed,
        "failed": len(failed),
        "failed_states": sorted({stats.outcomes[i].state.value
                                 for i in failed}),
        "dispatch_retries": stats.dispatch_retries,
        "backend_fallbacks": stats.backend_fallbacks,
        "degraded": sum(stats.degraded.values()),
        "stragglers": len(stats.stragglers),
        "injected_fires": sum(plan.fired.values()) if plan else 0,
        "survivor_parity_ok": True,     # asserted above
        "leaked_pages": 0,              # assert_all_free() teardown
    }


def run(*, arch: str = "stablelm-3b", requests: int = 8,
        max_new: int = 8, seed: int = 0,
        dry_run: bool = False) -> list[dict]:
    if dry_run:
        requests, max_new = 6, 6
    requests = max(requests, 4)     # targeted mixes poison rids 1 and 2

    cfg = model_zoo.reduced_config(model_zoo.get_config(arch))
    eng = Engine(cfg, model_zoo.build(cfg), max_len=48, packed=False)
    rng = np.random.default_rng(seed)
    lens = [int(l) for l in rng.integers(3, 24, requests)]
    reqs = [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in lens]
    mns = [int(m) for m in rng.integers(2, max_new + 1, requests)]
    mns[1] = max(mns[1], 4)         # poison_decode needs decode ticks
    refs = _refs(eng, reqs, mns)

    oom = lambda: KV.OutOfPagesError("injected pool exhaustion")
    rows = [
        _row("clean", None, eng, reqs, mns, refs, expect_clean=True),
        _row("transient_retry",
             F.FaultPlan(F.FaultSpec("prefill_dispatch", at=(0,)),
                         F.FaultSpec("decode_dispatch", at=(0,)),
                         seed=seed),
             eng, reqs, mns, refs, expect_clean=True),
        _row("backend_fallback",
             F.FaultPlan(F.FaultSpec("decode_dispatch", at=(0, 1)),
                         seed=seed),
             eng, reqs, mns, refs, expect_clean=True),
        _row("poison_prefill",
             F.FaultPlan(F.FaultSpec("prefill_dispatch", at=(0, 1, 2),
                                     target_rid=2), seed=seed),
             eng, reqs, mns, refs, expect_failed={2}),
        _row("poison_decode",
             F.FaultPlan(F.FaultSpec("decode_dispatch", at=(1, 2, 3),
                                     target_rid=1), seed=seed),
             eng, reqs, mns, refs, expect_failed={1}),
        _row("alloc_oom",
             F.FaultPlan(F.FaultSpec("alloc_oom", at=(5,), error=oom),
                         seed=seed),
             eng, reqs, mns, refs),
        _row("deadline", None, eng, reqs, mns, refs,
             expect_failed={1},
             budgets=[0.0 if i == 1 else None
                      for i in range(len(reqs))]),
        _row("prefix_error",
             F.FaultPlan(F.FaultSpec("prefix_cache", p=0.5), seed=seed),
             eng, reqs, mns, refs, expect_clean=True,
             serve_kw=dict(prefix_cache=True)),
        _row("slow_tick",
             F.FaultPlan(F.FaultSpec("slow_tick", at=(10,),
                                     delay_s=0.25), seed=seed),
             eng, reqs, mns, refs, expect_clean=True,
             serve_kw=dict(watchdog_factor=8.0)),
        _row("combined",
             F.FaultPlan(F.FaultSpec("prefill_dispatch", at=(0,)),
                         F.FaultSpec("decode_dispatch", at=(4, 5, 6),
                                     target_rid=1),
                         F.FaultSpec("alloc_oom", at=(9,), error=oom),
                         F.FaultSpec("slow_tick", at=(6,),
                                     delay_s=0.05),
                         seed=seed),
             eng, reqs, mns, refs),
    ]

    # cross-mix invariants the per-row gates can't see
    by = {r["mix"]: r for r in rows}
    assert by["clean"]["injected_fires"] == 0
    assert by["transient_retry"]["dispatch_retries"] >= 2
    assert by["transient_retry"]["backend_fallbacks"] == 0
    assert by["backend_fallback"]["backend_fallbacks"] >= 1
    assert by["alloc_oom"]["failed"] >= 1
    assert by["deadline"]["failed_states"] == ["TIMED_OUT"]
    assert by["prefix_error"]["degraded"] >= 1
    assert by["slow_tick"]["stragglers"] >= 1
    return rows


def main(argv=()):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=model_zoo.list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds the trace AND every fault plan — the "
                         "same seed reproduces the same fires")
    ap.add_argument("--dry-run", action="store_true",
                    help="smallest structurally-complete run (CI smoke): "
                         "every mix, every gate, no file writes")
    args = ap.parse_args(argv)

    rows = run(arch=args.arch, requests=args.requests,
               max_new=args.max_new, seed=args.seed,
               dry_run=args.dry_run)
    common.print_csv("chaos_serving", rows)
    if args.dry_run:
        print("dry-run OK: survivor parity held under every fault mix, "
              "all failures carried structured outcomes, zero leaked "
              "pages")
        return rows
    meta = {
        "note": "request-level fault isolation gate: under every "
                "injected fault mix, completed requests are token-"
                "identical to a fault-free serve, failed requests end "
                "in structured RequestOutcomes (partials salvaged and "
                "prefix-matching), and the page pool audits clean at "
                "every teardown.",
        "protocol": "seeded deterministic injection (runtime/faults); "
                    "fault-free refs from per-request generate; every "
                    "gate is an assert — a violated guarantee exits "
                    "non-zero",
        "trace": {"requests": args.requests, "max_new": args.max_new,
                  "seed": args.seed},
    }
    common.write_table("chaos_serving", rows, meta=meta)
    summary = {
        "mixes": len(rows),
        "survivor_parity_ok": all(r["survivor_parity_ok"] for r in rows),
        "total_injected_fires": sum(r["injected_fires"] for r in rows),
        "total_failed": sum(r["failed"] for r in rows),
        "total_retries": sum(r["dispatch_retries"] for r in rows),
        "total_fallbacks": sum(r["backend_fallbacks"] for r in rows),
        "rows": rows,
    }
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "BENCH_chaos.json")
    with open(path, "w") as f:
        json.dump({"meta": {"baseline_of": "chaos_serving",
                            "tracked_since": "fault isolation PR",
                            **meta},
                   "baseline": summary}, f, indent=1)
    print(f"baseline -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
