"""Table 8 — quantized pre-pack, measured per paper shape with its
error ledger.

For each of the paper's twelve prefill GEMMs (M = S = 128) and each
quantized format (int8 per-channel symmetric, 2-bit ternary), three
jitted modes on the SAME weight:

  fp32           — the packed fp32 baseline (paper lever 2 as shipped).
  dequant        — dequant-THEN-sgemm: the same quantized values stored
                   the way quantized checkpoints ship ([N, K] llama.cpp
                   convention, codes + per-row scales, no pack-time
                   integration); every call dequantizes AND pays the
                   transpose+pad re-layout inside the GEMM — the
                   paper's §3.2 per-call baseline, extended to quant.
  dequant_packed — generous variant (reported, not gated): the
                   baseline's dequant lands straight in the pre-packed
                   panel layout, so only the fp32 materialization
                   round-trip separates it from fused.
  fused          — the dequant-fused path: execute() on the quantized
                   plan; codes + scales stream through one dispatch and
                   dequantize on the way to the accumulator.

``fused == dequant`` is asserted BITWISE before timing (all modes
compute the same dot over the same dequantized values), and
``fused_vs_dequant >= 1.0`` is the committed acceptance ratio: the
fused path deleted the baseline's per-call dequant + re-layout at pack
time.  ``quant_vs_fp32`` is reported as context (on this CPU host the
dequant arithmetic is paid in compute; on the load-issue-bound TPU/AMX
target the 4x/16x tile-byte reduction is the point — see
docs/quantization.md).

Every row carries its ERROR LEDGER columns (max-abs / max-rel vs the
fp32 oracle, the format tolerance, within_tol) — the Table-4 discipline
applied to our own reduced precision.  The benchmark REFUSES to write a
baseline whose ledger has any entry out of tolerance.

Emits ``benchmarks/out/table8_quant.json`` (transient) and the
version-tracked ``benchmarks/BENCH_quant.json`` baseline.  ``--dry-run``
(CI serving-smoke job) runs one tiny shape per format with the parity
and ledger gates, so the tolerance contract runs on every PR.

DENSITY SWEEP (the sparse-ternary lane's Table 8 arm): per paper shape,
a synthetic group-sparse ternary weight — whole ``GROUP_K`` K-groups
zeroed at zero-group fractions 0.1 .. 0.9 — is packed BOTH ways (dense
ternary vs the compressed zero-group layout) and the two planned
execute paths race on the same activations.  Parity is asserted before
any timing: the planned sparse interpret kernel is BITWISE against
``sparse_ref`` (the blocked oracle over the layout round-trip), and the
timed xla lane is allclose against the dense ternary plan (the sparse
dot reduces over the compacted K', so fp summation order differs —
bitwise xla-vs-xla is not a claim the lane makes).  The committed gates:
``sparse_vs_dense >= 1.0`` wherever the achieved zero-group fraction
clears ``SPARSE_DENSITY_THRESHOLD`` (the policy's crossover — below it
the policy would not pick the sparse arm, so those rows are context),
and ``>= 1.3`` at zero-group fraction 0.7 on deep-K (K >= N) shapes.
Real-TWN context rides on the plain ternary rows: gaussian weights
threshold to ~45% zero CODES but ~0 zero GROUPS, so their
``density_bucket`` column stays -1 — the auto arm leaves them dense.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import gemm as G
from repro.core import bitexact, packing
from repro.models.model_zoo import PAPER_GEMM_SHAPES
from repro.quant import formats as F
from repro.quant import ledger

S = 128
FORMATS = ("int8", "ternary")
# zero-group fractions the sparse sweep targets (deciles; 0.7 is the
# ISSUE's deep-K acceptance point)
DENSITIES = tuple(round(0.1 * i, 1) for i in range(1, 10))


def _timer(reps):
    def time_modes(modes: dict) -> dict:
        ts = {name: [] for name in modes}
        for _ in range(reps):
            # interleaved reps: machine drift cancels across modes
            for name, fn in modes.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                ts[name].append(time.perf_counter() - t0)
        return {name: float(np.median(v)) for name, v in ts.items()}
    return time_modes


def _unpack_nk(packed):
    """Baseline-side 2-bit unpack for checkpoint-layout ternary codes
    ``[N, K // 4]`` -> fp32 codes ``[N, K]`` (codes 2-bit along K, the
    axis a [N, K] checkpoint packs)."""
    parts = [((packed >> (2 * i)) & 3).astype(jnp.float32) - 1.0
             for i in range(4)]
    return jnp.stack(parts, axis=-1).reshape(packed.shape[0], -1)


def _pack_nk(t):
    c = (t.astype(jnp.int32) + 1).astype(jnp.uint8)
    c4 = c.reshape(t.shape[0], -1, 4)
    out = c4[..., 0]
    for i in range(1, 4):
        out = out | (c4[..., i] << (2 * i))
    return out


def _row(model, op, n, k, fmt, rng, reps):
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.standard_normal((S, k)), jnp.float32)

    # quantize-pack (ledger measures + tolerance-gates here), and an
    # fp32 pack on the SAME blocks so every mode tiles identically
    qpw = packing.pack(w, quant=fmt)
    pw = packing.pack(w, block_n=qpw.block_n, block_k=qpw.block_k)
    ent = ledger.lookup(qpw.n, qpw.k, fmt)
    qplan = G.plan_for_packed(S, qpw, backend="xla")
    fplan = G.plan_for_packed(S, pw, backend="xla")

    # dequant-then-sgemm baseline: the SAME quantized values stored the
    # way quantized checkpoints ship them — [N, K] (llama.cpp / GGUF
    # convention), codes + per-(row, K-group) scales, no pack-time
    # integration.  Each call dequantizes AND pays the transpose+pad
    # re-layout inside the GEMM (the paper's §3.2 per-call baseline,
    # extended to quant); the fused path paid all of that at pack time.
    codes_logical, scales_logical = F.quantize(w, fmt)
    codes_nk = (_pack_nk(codes_logical.T) if fmt == "ternary"
                else codes_logical.T)
    scales_nk = scales_logical.T                    # [N, Kg]
    bplan = G.plan(S, n, k, backend="xla", pack=G.PACK_PERCALL,
                   block_n=qpw.block_n, block_k=qpw.block_k,
                   transposed=True)

    @jax.jit
    def run_fp32(x, pw):
        return G.execute(fplan, x, pw)

    @jax.jit
    def run_fused(x, qpw):
        return G.execute(qplan, x, qpw)

    @jax.jit
    def run_dequant(x, codes_nk, scales_nk):
        c = _unpack_nk(codes_nk) if fmt == "ternary" \
            else codes_nk.astype(jnp.float32)
        s = jnp.repeat(scales_nk, F.GROUP_K, axis=-1)[:, :c.shape[-1]]
        w_nk = jax.lax.optimization_barrier(c * s)
        return G.execute(bplan, x, w_nk)            # transpose+pad inside

    # generous variant (reported, not gated): the baseline's dequant
    # lands straight in the pre-packed panel layout — only the fp32
    # materialization round-trip separates it from the fused path
    dq = jax.jit(functools.partial(F.dequantize_padded, fmt=fmt))

    @jax.jit
    def mm(x, data):
        return G.execute(fplan, x, dataclasses.replace(pw, data=data))

    def run_dequant_packed():
        return mm(x, dq(qpw.data, qpw.scales))

    y_fused = run_fused(x, qpw)
    bitexact.assert_bit_identical(
        np.asarray(y_fused), np.asarray(run_dequant(x, codes_nk,
                                                    scales_nk)),
        f"{model}/{op} {fmt}: fused vs dequant-then-sgemm")
    bitexact.assert_bit_identical(
        np.asarray(y_fused), np.asarray(run_dequant_packed()),
        f"{model}/{op} {fmt}: fused vs packed-layout dequant")
    jax.block_until_ready(run_fp32(x, pw))     # warm all modes

    t = _timer(reps)({"fp32": lambda: run_fp32(x, pw),
                      "dequant": lambda: run_dequant(x, codes_nk,
                                                     scales_nk),
                      "dequant_packed": run_dequant_packed,
                      "fused": lambda: run_fused(x, qpw)})
    row = {
        "model": model, "op": op, "M": S, "N": n, "K": k, "format": fmt,
        "lever": qplan.lever,
        "fp32_ms": round(t["fp32"] * 1e3, 3),
        "dequant_ms": round(t["dequant"] * 1e3, 3),
        "dequant_packed_ms": round(t["dequant_packed"] * 1e3, 3),
        "fused_ms": round(t["fused"] * 1e3, 3),
        "fused_vs_dequant": round(t["dequant"] / t["fused"], 3),
        "quant_vs_fp32": round(t["fp32"] / t["fused"], 3),
        "weight_bytes_fp32": int(pw.data.size * 4),
        "weight_bytes_quant": int(qpw.data.size
                                  * qpw.data.dtype.itemsize
                                  + qpw.scales.size * 4),
        "bit_exact_vs_dequant": True,
    }
    if fmt == "ternary":
        row["sparsity"] = round(qpw.sparsity, 4)
        # real-TWN context for the density sweep: gaussian weights have
        # ~45% zero codes but ~0 zero GROUPS — the auto arm stays dense
        row["density_bucket"] = int(getattr(qpw, "density_bucket", -1))
    row.update({k2: (round(v, 8) if isinstance(v, float) else v)
                for k2, v in ent.row().items()
                if k2 not in ("N", "K", "format")})
    return row


def _density_row(model, op, n, k, gs, rng, reps):
    """One density-sweep row: the SAME group-sparse weight packed dense
    vs compressed, parity asserted (interpret bitwise vs ``sparse_ref``,
    timed xla lane allclose vs dense), then raced interleaved."""
    w_np = (rng.standard_normal((k, n)) * 0.02).astype(np.float32)
    kg_full = k // F.GROUP_K                # whole groups we may zero
    kg_pad = -(-k // F.GROUP_K)
    z = min(kg_full, round(gs * kg_pad))
    if z:
        for g in rng.choice(kg_full, size=z, replace=False):
            w_np[g * F.GROUP_K:(g + 1) * F.GROUP_K] = 0.0
    w = jnp.asarray(w_np)
    x = jnp.asarray(rng.standard_normal((S, k)), jnp.float32)

    qpw = packing.pack(w, quant="ternary", sparse=False)
    spw = packing.pack(w, block_n=qpw.block_n, block_k=qpw.block_k,
                       quant="ternary", sparse=True)
    achieved = round(1.0 - spw.density, 4)
    dplan = G.plan_for_packed(S, qpw, backend="xla")
    splan = G.plan_for_packed(S, spw, backend="xla")

    @jax.jit
    def run_dense(x, qpw):
        return G.execute(dplan, x, qpw)

    @jax.jit
    def run_sparse(x, spw):
        return G.execute(splan, x, spw)

    # parity BEFORE timing.  (1) the planned sparse kernel (interpret
    # backend, same plan blocks) is bitwise against the blocked oracle
    # over the decompressed layout; (2) the timed xla sparse lane is
    # allclose against the dense ternary plan — its dot reduces over the
    # compacted K', so fp summation order legitimately differs.
    from repro.quant import kernels as QK
    iplan = G.plan_for_packed(S, spw, backend="interpret")
    x_pad = jnp.pad(x, ((0, 0), (0, spw.k_pad - k)))  # oracle wants K_pad
    bitexact.assert_bit_identical(
        np.asarray(G.execute(iplan, x, spw)),
        np.asarray(QK.sparse_ref(x_pad, spw))[:, :spw.n],
        f"{model}/{op} gs={gs}: sparse kernel vs sparse_ref")
    y_d = np.asarray(run_dense(x, qpw))
    y_s = np.asarray(run_sparse(x, spw))
    np.testing.assert_allclose(
        y_s, y_d, rtol=2e-5, atol=2e-5 * max(1.0, np.abs(y_d).max()),
        err_msg=f"{model}/{op} gs={gs}: sparse xla vs dense xla")

    t = _timer(reps)({"dense": lambda: run_dense(x, qpw),
                      "sparse": lambda: run_sparse(x, spw)})
    return {
        "model": model, "op": op, "M": S, "N": n, "K": k,
        "target_gs": gs, "achieved_gs": achieved,
        "density_bucket": int(spw.density_bucket),
        "deep_k": k >= n, "lever": splan.lever,
        "dense_ms": round(t["dense"] * 1e3, 3),
        "sparse_ms": round(t["sparse"] * 1e3, 3),
        "sparse_vs_dense": round(t["dense"] / t["sparse"], 3),
        "weight_bytes_dense": int(qpw.data.size + qpw.scales.size * 4),
        "weight_bytes_sparse": int(spw.data.size + spw.scales.size * 4
                                   + spw.index_bytes),
        "bit_exact_vs_ref": True,
    }


def _density_ok(r) -> bool:
    """Accept predicate for retry_on_noise: rows below the policy
    crossover are context (no speedup claim); above it the sparse walk
    does strictly less work, so a miss is noise — re-measure."""
    if r["achieved_gs"] < F.SPARSE_DENSITY_THRESHOLD:
        return True
    if r["sparse_vs_dense"] < 1.0:
        return False
    if r["deep_k"] and r["target_gs"] == 0.7 and r["sparse_vs_dense"] < 1.3:
        return False
    return True


def run(scale: int = 4, reps: int = 9, dry_run: bool = False,
        max_retries: int = 4):
    rng = np.random.default_rng(8)
    rows, sweep = [], []
    if dry_run:
        for fmt in FORMATS:
            r = _row("dry", fmt, 256, 256, fmt, rng, 1)
            assert r["within_tol"], f"dry-run ledger gate failed: {r}"
            rows.append(r)
        # density-sweep parity gates on one tiny shape (K = 4 groups):
        # sparse kernel bitwise vs oracle, sparse xla allclose vs dense
        for gs in (0.25, 0.5):
            sweep.append(_density_row("dry", "sweep", 256, 512, gs,
                                      rng, 1))
        return rows, sweep
    for model, op, n, k in PAPER_GEMM_SHAPES:
        for fmt in FORMATS:
            # the committed acceptance ratio is fused >= dequant-then-
            # sgemm; the fused mode does strictly less memory work, so a
            # sub-1.0 median is timer noise (common.retry_on_noise)
            r, _ = common.retry_on_noise(
                lambda extra: _row(model, op, n // scale, k // scale,
                                   fmt, rng, reps + extra),
                lambda r: r["fused_vs_dequant"] >= 1.0,
                max_retries=max_retries)
            rows.append(r)
        for gs in DENSITIES:
            r, _ = common.retry_on_noise(
                lambda extra: _density_row(model, op, n // scale,
                                           k // scale, gs, rng,
                                           reps + extra),
                _density_ok, max_retries=max_retries)
            sweep.append(r)
    return rows, sweep


def main(argv=()):
    dry = "--dry-run" in argv
    full = "--full" in argv
    rows, sweep = run(scale=1 if full else 4, dry_run=dry)
    common.print_csv("table8_quant", rows)
    common.print_csv("table8_density_sweep", sweep)
    bad_tol = [r for r in rows if not r["within_tol"]]
    assert not bad_tol, f"ledger out of tolerance: {bad_tol}"
    if dry:
        print("dry-run OK: fused == dequant-then-sgemm bitwise, ledger "
              "within tolerance for every format; sparse lane bitwise "
              "vs sparse_ref and allclose vs dense across the sweep")
        return rows + sweep
    meta = {
        "note": "quantized pre-pack per paper shape: dequant-fused vs "
                "dequant-then-sgemm (fused_vs_dequant >= 1.0 expected) "
                "vs fp32 packed; ledger columns are max err vs the fp32 "
                "oracle, tolerance-gated at pack time",
        "protocol": "jitted, interleaved reps, median; xla backend; "
                    f"scale={1 if full else 4}; probe_m={ledger.PROBE_M}",
        "tolerances": dict(ledger.TOLERANCES),
        "density_sweep_gs": list(DENSITIES),
        "sparse_threshold": F.SPARSE_DENSITY_THRESHOLD,
        "plan_cache": tuple(G.plan_cache_info()),
        "vmem_clamped_plans": G.vmem_clamped_count(),
    }
    common.write_table("table8_quant", rows + sweep, meta=meta)
    bad_perf = [r for r in rows if r["fused_vs_dequant"] < 1.0]
    assert not bad_perf, (
        f"fused lost to dequant-then-sgemm after retries: {bad_perf}")
    # density-sweep gates: the sparse arm must pay off wherever the
    # policy would actually pick it, and pay off HARD on deep-K at 0.7
    above = [r for r in sweep
             if r["achieved_gs"] >= F.SPARSE_DENSITY_THRESHOLD]
    bad_sparse = [r for r in above if r["sparse_vs_dense"] < 1.0]
    assert not bad_sparse, (
        f"sparse lost to dense above the policy threshold: {bad_sparse}")
    deep07 = [r for r in sweep if r["deep_k"] and r["target_gs"] == 0.7]
    bad_deep = [r for r in deep07 if r["sparse_vs_dense"] < 1.3]
    assert not bad_deep, (
        f"deep-K shapes below 1.3x at zero-group fraction 0.7: "
        f"{bad_deep}")
    summary = {
        "all_within_tol": all(r["within_tol"] for r in rows),
        "all_fused_ge_dequant": all(r["fused_vs_dequant"] >= 1.0
                                    for r in rows),
        "worst_max_rel": {
            fmt: max(r["max_rel_err"] for r in rows if r["format"] == fmt)
            for fmt in FORMATS},
        "min_fused_vs_dequant": min(r["fused_vs_dequant"] for r in rows),
        "rows": rows,
        "density_sweep": {
            "threshold": F.SPARSE_DENSITY_THRESHOLD,
            "min_sparse_vs_dense_above_threshold": min(
                (r["sparse_vs_dense"] for r in above), default=None),
            "min_deepk_speedup_at_0.7": min(
                (r["sparse_vs_dense"] for r in deep07), default=None),
            "rows": sweep,
        },
    }
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "BENCH_quant.json")
    with open(path, "w") as f:
        json.dump({"meta": {"baseline_of": "table8_quant",
                            "tracked_since": "quantized pre-pack "
                                             "subsystem PR",
                            **meta},
                   "baseline": summary}, f, indent=1)
    print(f"baseline -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
