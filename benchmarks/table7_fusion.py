"""Table 7 — horizontal fusion + fused epilogues, measured per shape.

The fused-GEMM subsystem's two levers, isolated:

  * **horizontal fusion** — Q/K/V (three same-input projections) as ONE
    ``pack_fused`` GEMM with a static split map, and gate+up as one
    glu-epilogue GEMM (``silu(gate) * up`` combined in the store step):
    the shared activations stream from HBM once instead of 2-3 times and
    the [M, 2F] gate-up intermediate never materializes.
  * **fused epilogues** — bias / activation / softcap / residual applied
    on the fp32 accumulator inside the store step instead of a separate
    XLA op re-reading the GEMM output from HBM.

Per shape the table times the fused path against the unfused
``execute -> XLA op`` sequence computing the SAME function (both jitted,
interleaved reps so machine drift cancels), asserts bitwise equality for
fp32 operands first, and reports the per-block dispatch reduction.
Emits ``benchmarks/out/table7_fusion.json`` (transient, gitignored) and
the machine-readable ``benchmarks/BENCH_fusion.json`` baseline —
version-tracked, so the perf trajectory is diffable from this PR on.

``--dry-run`` (wired into the CI serving-smoke job) runs one tiny shape
per mode with parity asserts and a single rep — the harness can't rot.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import gemm as G
from repro.core import bitexact, packing

S = 128

# (model, H, F) — the paper's table-3 models, FFN from their configs
SHAPES = [
    ("tinyllama-1.1b", 2048, 5632),
    ("llama-7b", 4096, 11008),
]

EPILOGUES = [
    ("bias", G.EpilogueSpec(bias=True)),
    ("silu", G.EpilogueSpec(act="silu")),
    ("softcap", G.EpilogueSpec(softcap=30.0)),
    ("residual", G.EpilogueSpec(residual=True)),
    ("bias+gelu+residual",
     G.EpilogueSpec(bias=True, act="gelu", residual=True)),
]


def _timer(reps):
    def time_modes(modes: dict) -> dict:
        ts = {name: [] for name in modes}
        for _ in range(reps):
            for name, fn in modes.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                ts[name].append(time.perf_counter() - t0)
        return {name: float(np.median(v)) for name, v in ts.items()}
    return time_modes


def _qkv_row(name, h, rng, reps, hkv_ratio=1):
    """Q/K/V horizontal fusion: 3 GEMMs -> 1 (split map)."""
    nk = h // hkv_ratio
    ws = [jnp.asarray(rng.standard_normal((h, n)) * 0.02, jnp.float32)
          for n in (h, nk, nk)]
    x = jnp.asarray(rng.standard_normal((S, h)), jnp.float32)
    pws = [packing.pack(w) for w in ws]
    plans = [G.plan_for_packed(S, pw, backend="xla") for pw in pws]
    fpw = packing.pack_fused(ws)
    fplan = G.plan_for_packed(S, fpw, backend="xla")

    @jax.jit
    def unfused(x, pws):
        return [G.execute(p, x, pw) for p, pw in zip(plans, pws)]

    @jax.jit
    def fused(x, fpw):
        return list(G.split_fused(fplan, G.execute(fplan, x, fpw)))

    a, b = unfused(x, pws), fused(x, fpw)
    for ya, yb in zip(a, b):
        bitexact.assert_bit_identical(np.asarray(ya), np.asarray(yb),
                                      "fused qkv vs separate")
    t = _timer(reps)({"unfused": lambda: unfused(x, pws),
                      "fused": lambda: fused(x, fpw)})
    return {
        "model": name, "op": "qkv", "M": S, "K": h,
        "N": "+".join(str(w.shape[1]) for w in ws),
        "gemms_unfused": 3, "gemms_fused": 1,
        "unfused_ms": round(t["unfused"] * 1e3, 3),
        "fused_ms": round(t["fused"] * 1e3, 3),
        "speedup": round(t["unfused"] / t["fused"], 3),
        "bit_exact": True,
    }


def _glu_row(name, h, f, rng, reps):
    """gate+up glu fusion: 2 GEMMs + 2 XLA ops -> 1 GEMM."""
    wg = jnp.asarray(rng.standard_normal((h, f)) * 0.02, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((h, f)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.standard_normal((S, h)), jnp.float32)
    glu = G.EpilogueSpec(glu="silu")
    bn, bk = G.pack_blocks(2 * f, h, epilogue=glu)
    fpw = packing.pack_fused([wg, wu], block_n=bn, block_k=bk)
    fplan = G.plan_for_packed(S, fpw, backend="xla", epilogue=glu)
    pg, pu = packing.pack(wg), packing.pack(wu)
    plg = G.plan_for_packed(S, pg, backend="xla")
    plu = G.plan_for_packed(S, pu, backend="xla")

    @jax.jit
    def unfused(x, pg, pu):
        g = G.execute(plg, x, pg)
        u = G.execute(plu, x, pu)
        return jax.nn.silu(g) * u

    @jax.jit
    def fused(x, fpw):
        return G.execute(fplan, x, fpw)

    bitexact.assert_bit_identical(np.asarray(unfused(x, pg, pu)),
                                  np.asarray(fused(x, fpw)),
                                  "fused glu vs 2 GEMMs + ops")
    t = _timer(reps)({"unfused": lambda: unfused(x, pg, pu),
                      "fused": lambda: fused(x, fpw)})
    return {
        "model": name, "op": "gate_up", "M": S, "K": h, "N": f"2x{f}",
        "gemms_unfused": 2, "gemms_fused": 1,
        "unfused_ms": round(t["unfused"] * 1e3, 3),
        "fused_ms": round(t["fused"] * 1e3, 3),
        "speedup": round(t["unfused"] / t["fused"], 3),
        "bit_exact": True,
    }


def _epilogue_row(label, spec, rng, reps, n=2048, k=2048):
    """One epilogue spec: fused-in-execute vs execute -> XLA op."""
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.standard_normal((S, k)), jnp.float32)
    pw = packing.pack(w)
    base = G.plan_for_packed(S, pw, backend="xla")
    fplan = G.plan_for_packed(S, pw, backend="xla", epilogue=spec)
    bias = (jnp.asarray(rng.standard_normal((n,)), jnp.float32)
            if spec.bias else None)
    res = (jnp.asarray(rng.standard_normal((S, n)), jnp.float32)
           if spec.residual else None)

    @jax.jit
    def unfused(x, pw):
        acc = G.execute(base, x, pw, out_dtype=jnp.float32)
        return G.apply_epilogue(acc, spec, bias=bias,
                                residual=res).astype(x.dtype)

    @jax.jit
    def fused(x, pw):
        return G.execute(fplan, x, pw, bias=bias, residual=res)

    bitexact.assert_bit_identical(np.asarray(unfused(x, pw)),
                                  np.asarray(fused(x, pw)),
                                  f"epilogue {label}")
    t = _timer(reps)({"unfused": lambda: unfused(x, pw),
                      "fused": lambda: fused(x, pw)})
    return {
        "model": "epilogue", "op": label, "M": S, "K": k, "N": n,
        "gemms_unfused": 1, "gemms_fused": 1,
        "unfused_ms": round(t["unfused"] * 1e3, 3),
        "fused_ms": round(t["fused"] * 1e3, 3),
        "speedup": round(t["unfused"] / t["fused"], 3),
        "bit_exact": True,
    }


def run(scale: int = 4, reps: int = 7, dry_run: bool = False):
    rng = np.random.default_rng(7)
    rows = []
    if dry_run:
        rows.append(_qkv_row("dry", 256, rng, 1))
        rows.append(_glu_row("dry", 256, 384, rng, 1))
        rows.append(_epilogue_row("bias+gelu+residual", EPILOGUES[-1][1],
                                  rng, 1, n=256, k=256))
        return rows
    for name, h, f in SHAPES:
        rows.append(_qkv_row(name, h // scale, rng, reps))
        rows.append(_glu_row(name, h // scale, f // scale, rng, reps))
    for label, spec in EPILOGUES:
        rows.append(_epilogue_row(label, spec, rng, reps,
                                  n=2048 // scale * 2, k=2048 // scale * 2))
    return rows


def main(argv=()):
    dry = "--dry-run" in argv
    full = "--full" in argv
    rows = run(scale=1 if full else 4, dry_run=dry)
    common.print_csv("table7_fusion", rows)
    if dry:
        print("dry-run OK: fused == unfused bitwise on every mode")
        return rows
    common.write_table("table7_fusion", rows, meta={
        "note": "horizontal QKV/gate-up fusion + fused epilogues vs the "
                "unfused execute -> XLA op sequence; bit_exact asserted "
                "for fp32 before timing; jitted, interleaved reps",
        "scale": 1 if full else 4, "reps": 7})
    # machine-readable perf baseline: the numbers later PRs diff
    # against.  Written NEXT TO the benchmarks (benchmarks/out/ is
    # gitignored; the baseline is version-tracked from this PR on).
    summary = {
        "per_block_gemms": {"unfused": 7, "fused": 4, "saved": 3},
        "speedups": {f"{r['model']}/{r['op']}": r["speedup"]
                     for r in rows},
        "rows": rows,
        "all_bit_exact": all(r["bit_exact"] for r in rows),
    }
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "BENCH_fusion.json")
    with open(path, "w") as f:
        json.dump({"meta": {"baseline_of": "table7_fusion",
                            "tracked_since": "fused-epilogue panel GEMM "
                                             "PR",
                            "protocol": "jitted, interleaved reps, "
                                        "median; scale=4 unless --full"},
                   "baseline": summary}, f, indent=1)
    print(f"baseline -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
