"""Benchmark orchestrator: one table per paper table (T2–T6) + the
roofline report over whatever dry-run cells exist.

``PYTHONPATH=src python -m benchmarks.run [--full]``
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    full = "--full" in sys.argv
    t0 = time.perf_counter()
    from benchmarks import (
        table2_issue_bound, table3_prefill_gemms, table4_bitexact,
        table5_panel_sweep, table6_e2e_prefill,
    )
    print("=" * 72)
    table2_issue_bound.main()
    print("=" * 72)
    table3_prefill_gemms.main(full=full)
    print("=" * 72)
    table4_bitexact.main()
    print("=" * 72)
    table5_panel_sweep.main()
    print("=" * 72)
    table6_e2e_prefill.main(full=full)
    print("=" * 72)
    try:
        from benchmarks import roofline_report
        roofline_report.main()
    except FileNotFoundError as e:
        print(f"(roofline report skipped: {e})")
    print(f"total bench time: {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
