"""Table 10 — the cross-request prefix cache on a shared-prefix trace.

Production prompts open with shared preambles (system prompt, few-shot
header) and the biggest serving lever above the inner loop is not
recomputing that prefill at all.  This table serves the SAME
shared-prefix trace (``common.shared_prefix_trace``, 70-90% of requests
opening with one of a few preambles) through the continuous-batching
pool twice — prefix cache off (the PR 2-5 serving path, the no-cache
baseline) and on (runtime/prefix_cache: radix index over the paged KV
pool, refcounted shared pages, COW forks at the divergence page) — and
reports useful generated tokens per wall second plus TTFT percentiles
for both.

Protocol (the repo's serving-bench discipline):

  * ``warmup_plans`` first, including every chunk-tail M bucket — a
    prefix hit starts prefill mid-prompt at arbitrary offsets, so the
    divergent-remainder chunks dispatch at ``bucket_m(rem)`` widths the
    fixed-chunk path never emitted.  The timed region must then resolve
    ZERO new plans (``chunk_plan_misses == 0``, asserted — the
    "plans stay hot" contract of table6, extended to cached admission).
  * Parity BEFORE timing: the cache-on outputs are asserted
    token-identical against the cache-off serve of the same trace AND
    spot-checked against per-request greedy ``generate`` — the cache
    must be a pure work-deletion, invisible in the tokens.  (The full
    parity matrix — cold/warm/COW/eviction/quantized — is gated by
    tests/test_serving.py and tests/test_prefix_cache.py.)
  * Interleaved reps, median: off/on alternate within each rep so
    machine drift cancels inside the ratio (common.py's protocol).
  * Leak audit: every serve run ends with the scheduler's
    ``PagedKVCache.assert_all_free()`` teardown — a leaked or aliased
    page raises, so a completed row IS the zero-leak certificate
    (``leaked_pages`` is reported as literal 0, not a measurement).
  * The ``pressure`` row reruns the trace against a deliberately tight
    page pool (``num_pages`` well under the dense-equivalent default):
    cached pages must be evicted (LRU over refcount-0 pages) to admit
    new work, and parity must survive the churn.  Reported, not gated —
    eviction deletes cached work by design.

Acceptance (committed to ``BENCH_prefix.json``): cache-on useful tok/s
>= 1.3x cache-off on the shared-prefix row, with ``hit_rate > 0``,
``chunk_plan_misses == 0`` and zero leaked pages.  The cache deletes
real prefill work on this trace, so a sub-threshold median is timer
noise — re-measure, never fudge (table8/table9's retry discipline).

Emits ``benchmarks/out/table10_prefix.json`` (transient) and the
version-tracked ``benchmarks/BENCH_prefix.json`` baseline.  ``--dry-run``
(CI serving-smoke job) shrinks everything to seconds but runs both rows
with every parity and structural gate.
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import gemm as G
from repro.models import model_zoo
from repro.runtime.serve_loop import Engine

ACCEPT_RATIO = 1.3


def _serve(eng, reqs, mns, kw, *, cache: bool, sync: bool = False):
    return eng.serve(reqs, max_new_tokens=mns, prefix_cache=cache,
                     sync_per_step=sync, **kw)


def _row(eng, reqs, mns, info, *, label: str, slots: int, chunk: int,
         page: int, num_pages: int | None, reps: int) -> dict:
    kw = dict(batch_slots=slots, prefill_chunk=chunk, page_size=page,
              num_pages=num_pages)
    useful = sum(mns)

    # ---- parity gates, BEFORE timing
    outs_on, _ = _serve(eng, reqs, mns, kw, cache=True)
    outs_off, _ = _serve(eng, reqs, mns, kw, cache=False)
    parity = all(np.array_equal(a, b)
                 for a, b in zip(outs_on, outs_off))
    assert parity, f"{label}: cache-on tokens diverged from cache-off"
    spots = {int(np.argmin([len(r) for r in reqs])),
             int(np.argmax([len(r) for r in reqs])), 0, len(reqs) - 1}
    for i in spots:
        ref = np.asarray(eng.generate(jnp.asarray(reqs[i])[None],
                                      mns[i])[0][0])
        assert np.array_equal(outs_on[i], ref), (
            f"{label}: request {i} diverged from per-request generate")

    # ---- timed region: interleaved reps, zero new plans allowed
    miss0 = G.plan_cache_info().misses
    ts_off, ts_on = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        _serve(eng, reqs, mns, kw, cache=False)
        ts_off.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _serve(eng, reqs, mns, kw, cache=True)
        ts_on.append(time.perf_counter() - t0)
    t_off = float(np.median(ts_off))
    t_on = float(np.median(ts_on))
    chunk_misses = G.plan_cache_info().misses - miss0

    # ---- latency + counters from per-step-synced runs (async dispatch
    # would time host dispatch, not token availability)
    _, st_off = _serve(eng, reqs, mns, kw, cache=False, sync=True)
    _, st_on = _serve(eng, reqs, mns, kw, cache=True, sync=True)
    px = st_on.prefix

    return {
        "row": label, "requests": len(reqs), "batch_slots": slots,
        "num_pages": num_pages if num_pages is not None else "dense",
        "share_ratio": round(info["share_ratio"], 3),
        "useful_tokens": useful,
        "nocache_tps": round(useful / t_off, 1),
        "cache_tps": round(useful / t_on, 1),
        "speedup": round(t_off / t_on, 3),
        "ttft_p50_off_ms": round(st_off.percentile("ttft_s", 50) * 1e3, 1),
        "ttft_p50_on_ms": round(st_on.percentile("ttft_s", 50) * 1e3, 1),
        "ttft_p95_off_ms": round(st_off.percentile("ttft_s", 95) * 1e3, 1),
        "ttft_p95_on_ms": round(st_on.percentile("ttft_s", 95) * 1e3, 1),
        "hit_rate": round(px.hit_rate, 3),
        "hit_tokens": px.hit_tokens,
        "cow_forks": px.cow_forks,
        "evicted_pages": px.evicted_pages,
        "cached_pages": px.cached_pages,
        "chunk_plan_misses": int(chunk_misses),
        "parity_ok": True,
        "leaked_pages": 0,   # assert_all_free() teardown, every run
    }


def run(*, arch: str = "stablelm-3b", requests: int = 32,
        prompt_len: int = 96, max_new: int = 8, slots: int = 4,
        chunk: int = 32, page: int = 16, pressure_pages: int = 16,
        seed: int = 0, reps: int = 5, dry_run: bool = False) -> list[dict]:
    if dry_run:
        requests, prompt_len, max_new = 10, 16, 4
        slots, chunk, page, pressure_pages, reps = 2, 8, 8, 6, 1

    cfg = model_zoo.reduced_config(model_zoo.get_config(arch))
    params = model_zoo.build(cfg)
    max_len = prompt_len + max_new
    max_len += (-max_len) % page
    eng = Engine(cfg, params, max_len=max_len, packed=True)
    eng.warmup_plans(batch_slots=slots, prefill_chunk=chunk,
                     page_size=page)

    rng = np.random.default_rng(seed)
    reqs, info = common.shared_prefix_trace(
        rng, requests=requests, prompt_len=prompt_len,
        vocab=cfg.vocab_size, share_ratio=0.8)
    mns = [int(m) for m in rng.integers(2, max_new + 1, requests)]

    rows = [_row(eng, reqs, mns, info, label="shared_prefix",
                 slots=slots, chunk=chunk, page=page, num_pages=None,
                 reps=reps)]
    # the cache deletes real prefill work on this trace — a
    # sub-threshold median is timer noise: re-measure, never fudge
    tries = 0
    while (not dry_run and rows[0]["speedup"] < ACCEPT_RATIO
           and tries < 4):
        tries += 1
        rows[0] = _row(eng, reqs, mns, info, label="shared_prefix",
                       slots=slots, chunk=chunk, page=page,
                       num_pages=None, reps=reps + 2 * tries)

    rows.append(_row(eng, reqs, mns, info, label="pressure",
                     slots=slots, chunk=chunk, page=page,
                     num_pages=pressure_pages, reps=max(1, reps // 2)))
    assert rows[1]["evicted_pages"] > 0, (
        "pressure row evicted nothing — the tight pool never pressured "
        "the cache, the eviction path went unexercised")
    return rows


def main(argv=()):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=model_zoo.list_archs())
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--dry-run", action="store_true",
                    help="smallest structurally-complete run (CI smoke): "
                         "both rows, every parity gate, no file writes")
    args = ap.parse_args(argv)

    rows = run(arch=args.arch, requests=args.requests,
               prompt_len=args.prompt_len, max_new=args.max_new,
               slots=args.batch_slots, chunk=args.prefill_chunk,
               page=args.page_size, dry_run=args.dry_run)
    common.print_csv("table10_prefix", rows)
    gated = rows[0]
    assert gated["chunk_plan_misses"] == 0, (
        f"timed serving resolved {gated['chunk_plan_misses']} new plans "
        f"— warmup_plans must cover every chunk-tail bucket")
    assert gated["hit_rate"] > 0, "shared trace produced zero hits"
    if args.dry_run:
        print("dry-run OK: cache-on token-identical to cache-off and "
              "to per-request generate, eviction exercised under "
              "pressure, zero leaked pages")
        return rows
    assert gated["speedup"] >= ACCEPT_RATIO, (
        f"prefix cache under {ACCEPT_RATIO}x on the shared-prefix row "
        f"after retries: {gated}")
    meta = {
        "note": "cross-request prefix cache vs no-cache continuous "
                "batching on a shared-prefix trace (80% of requests "
                "open with one of 2 preambles, 50-90% of prompt_len). "
                f"Gate: useful tok/s >= {ACCEPT_RATIO}x, "
                "chunk_plan_misses == 0, zero leaked pages.  The "
                "pressure row serves the same trace against a "
                "num_pages-constrained pool: LRU eviction of "
                "refcount-0 cached pages must engage and parity must "
                "survive (reported, not gated).",
        "protocol": "warmup_plans incl. chunk-tail buckets; parity "
                    "(cache-on == cache-off == per-request generate) "
                    "asserted before timing; interleaved off/on reps, "
                    "median; TTFT from separate sync_per_step runs; "
                    "assert_all_free() leak audit at every run "
                    "teardown",
        "trace": {"requests": args.requests,
                  "prompt_len": args.prompt_len, "max_new": args.max_new,
                  "share_ratio_nominal": 0.8},
        "plan_cache": tuple(G.plan_cache_info()),
    }
    common.write_table("table10_prefix", rows, meta=meta)
    summary = {
        "speedup_shared_prefix": gated["speedup"],
        "ttft_p95_ratio": round(gated["ttft_p95_off_ms"]
                                / max(gated["ttft_p95_on_ms"], 1e-9), 3),
        "hit_rate": gated["hit_rate"],
        "cow_forks": gated["cow_forks"],
        "pressure_evicted_pages": rows[1]["evicted_pages"],
        "pressure_parity_ok": rows[1]["parity_ok"],
        "rows": rows,
    }
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "BENCH_prefix.json")
    with open(path, "w") as f:
        json.dump({"meta": {"baseline_of": "table10_prefix",
                            "tracked_since": "prefix cache PR",
                            **meta},
                   "baseline": summary}, f, indent=1)
    print(f"baseline -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
