"""Paper Table 5 + Fig. 2 — panel granularity is THE lever.

The paper sweeps the column-panel width Nc: at Nc=512 the QKV GEMM makes
4 panels (one AMX block reachable, ~630 GFLOPS); at Nc=64 it makes 32
panels (both blocks fed, ~1200 GFLOPS) — a ~1.9x swing from one knob.

TPU form, two granularity scales (DESIGN.md §2):
  (a) kernel grid: (M/bm)·(N/bn) output panels vs compute cores — the
      occupancy model from core/scheduler.plan, swept over block_n for
      the paper's QKV shape.  Too-coarse panels leave cores idle (the
      idle-second-block failure); too-fine panels blow operand re-reads.
  (b) mesh: N-panels per model shard vs the all-gather⇄matmul overlap —
      a shard must hold >= 1 kernel panel or the collective serializes
      (scheduler.mesh_panels).

The sweep result is gated on bit-exactness via core/autotune (the
paper's reject-if-not-bit-identical protocol) and the deployed
(block_n, block_k) pair is asserted to be the sweep's winner.
"""
from __future__ import annotations

from benchmarks import common
from repro import gemm as G
from repro.core import autotune, scheduler
from repro.kernels.panel_gemm import DEFAULT_BLOCK_K, DEFAULT_BLOCK_N
from repro.models.model_zoo import PAPER_GEMM_SHAPES, PAPER_M

QKV = (PAPER_M, 2048, 2048)          # the paper's Fig. 2 shape


def sweep_rows(num_cores: int = 8) -> list[dict]:
    m, n, k = QKV
    rows = []
    for bn in (64, 128, 256, 512, 1024, 2048):
        p = scheduler.plan(m, n, k, block_m=128, block_n=bn, block_k=512,
                           num_cores=num_cores)
        mesh = scheduler.mesh_panels(n, model_shards=16, block_n=bn)
        rows.append({
            "block_n": bn,
            "panels": p.panels,
            "occupancy": round(p.occupancy, 3),
            "pred_ms": round(p.t_pred * 1e3, 4),
            "vmem_kb": p.vmem // 1024,
            "vmem_ok": p.vmem_ok,
            "panels_per_model_shard": mesh["kernel_panels_per_shard"],
            "overlap_feasible": mesh["overlap_feasible"],
        })
    return rows


def policy_rows() -> list[dict]:
    """The dispatch policy's lever resolution over the paper's twelve
    shapes — what `gemm.plan` deploys, next to the raw sweep above."""
    shapes = [(PAPER_M, n, k) for _, _, n, k in PAPER_GEMM_SHAPES]
    return G.policy_table(shapes, num_cores=num_cores_for_sweep())


def main():
    rows = sweep_rows()
    common.print_csv("table5_panel_sweep (QKV 128x2048x2048)", rows)

    # plan-policy resolution: K >= N shapes must come out fine-panelled,
    # N > K shapes pre-packed (the paper's two levers, per shape)
    prows = policy_rows()
    common.print_csv("policy_resolution (twelve paper shapes)", prows)
    for r in prows:
        want = (G.LEVER_FINE_PANELS if r["K"] >= r["N"]
                else G.LEVER_PREPACK)
        assert r["lever"] == want, (r, want)

    # the ~2x mis-tuning cliff, as an assertion (paper Fig. 2):
    ok = {r["block_n"]: r for r in rows if r["vmem_ok"]}
    fine, coarse = ok[128], ok[2048]
    swing = coarse["pred_ms"] / fine["pred_ms"]
    print(f"coarse/fine predicted swing: {swing:.2f}x "
          f"(paper measures ~1.9x)")
    assert swing > 1.5, swing

    # autotune: bit-exact-gated deployed pair over the twelve shapes
    shapes = [(PAPER_M, n, k) for _, _, n, k in PAPER_GEMM_SHAPES]
    ranked = autotune.sweep(shapes, num_cores=num_cores_for_sweep(),
                            validate=True)
    best = ranked[0]
    print(f"autotune deployed pair: block_n={best.block_n} "
          f"block_k={best.block_k} (defaults: {DEFAULT_BLOCK_N}, "
          f"{DEFAULT_BLOCK_K}); all candidates bit-exact-gated")
    assert (best.block_n, best.block_k) == (DEFAULT_BLOCK_N,
                                            DEFAULT_BLOCK_K), (
        "deployed defaults are stale vs the sweep winner")
    common.write_table("table5_panel_sweep", rows, meta={
        "swing": swing,
        "deployed_pair": [best.block_n, best.block_k],
        "policy_resolution": prows})
    return rows


def num_cores_for_sweep() -> int:
    return 8


if __name__ == "__main__":
    main()
