"""Paper Table 2 analogue — the inner-loop bound, TPU form.

The paper's T2 interleaves operand loads with the AMX FMA32 stream and
shows a ~610–680 GFLOPS floor regardless of arrangement: the inner loop
is ISSUE-bound and nothing inside it helps.  The TPU MXU has no shared
load/FMA issue port; the fixed budget is HBM bandwidth against MXU
FLOP/s, so the structural analogue is ARITHMETIC INTENSITY per BlockSpec:
below the ridge point (peak_flops / hbm_bw ≈ 240 FLOP/byte at bf16) a
tile is bandwidth-bound and no in-kernel rearrangement escapes it — the
same "the levers are above the inner loop" conclusion, derived statically
from the kernel's own block model (kernels/panel_gemm.vmem_bytes +
core/scheduler.plan).

Rows mirror the paper's: the deployed tile, load-halving (paired-load
analogue = wider block_k), tile-shape changes, and batching — all at the
same intensity class, all leaving the bound unmoved.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import scheduler
from repro.kernels.panel_gemm import vmem_bytes

# (label, block_m, block_n, block_k) — paper T2 row analogues
VARIANTS = [
    ("deployed 128x512x512", 128, 512, 512),
    ("paired-load analogue (bk x2)", 128, 512, 1024),
    ("tile 128x256 (32x32 analogue)", 128, 256, 512),
    ("tile 128x1024 (16x64 analogue)", 128, 1024, 512),
    ("phase batch (bm x2)", 256, 512, 512),
    ("skinny N panel", 128, 128, 512),
]


def rows(m: int = 128, n: int = 8192, k: int = 2048,
         dtype_bytes: int = 4) -> list[dict]:
    out = []
    ridge = scheduler.PEAK_FLOPS_F32 / scheduler.HBM_BW
    for label, bm, bn, bk in VARIANTS:
        p = scheduler.plan(m, n, k, block_m=bm, block_n=bn, block_k=bk,
                           dtype_bytes=dtype_bytes)
        # per-tile arithmetic intensity: FLOPs per HBM byte moved
        tile_flops = 2.0 * bm * bn * bk
        tile_bytes = dtype_bytes * (bm * bk + bk * bn + bm * bn / (k / bk))
        ai = tile_flops / tile_bytes
        out.append({
            "variant": label,
            "block": f"{bm}x{bn}x{bk}",
            "vmem_kb": vmem_bytes(bm, bn, bk) // 1024,
            "vmem_ok": p.vmem_ok,
            "arith_intensity_flop_per_byte": round(ai, 1),
            "ridge_flop_per_byte": round(ridge, 1),
            "bound": "compute" if ai >= ridge else "memory",
            "t_compute_ms": round(p.t_compute * 1e3, 4),
            "t_memory_ms": round(p.t_memory * 1e3, 4),
            "t_bound_ms": round(max(p.t_compute, p.t_memory) * 1e3, 4),
        })
    return out


def main():
    rs = rows()
    common.print_csv("table2_issue_bound (static, see docstring)", rs)
    common.write_table("table2_issue_bound", rs, meta={
        "note": "TPU analogue of paper T2: per-BlockSpec arithmetic "
                "intensity vs the HBM ridge point; every feasible variant "
                "lands in the same bound class — the inner loop is fixed, "
                "the levers are above it."})
    # the paper's conclusion, as an assertion over the table:
    bounds = {r["bound"] for r in rs if r["vmem_ok"]}
    assert len(bounds) == 1, bounds
    return rs


if __name__ == "__main__":
    main()
