"""Paper Table 3 — twelve LLM prefill GEMMs, three dispatch plans, measured.

Each shape is dispatched through the plan/execute API (``repro.gemm``)
three ways, mapping to the paper's backends:

  xla      — ``pack=PACK_NONE``: one shape-agnostic dot (the
             Accelerate-dispatch analogue)
  percall  — ``pack=PACK_PERCALL``: weight handed over as W[N, K]
             (llama.cpp convention) and transposed + padded INSIDE every
             call (cblas_sgemm/BNNSMatMul analogue)
  packed   — ``pack_for_plan`` once at load; per call only the compute
             loop (the paper's proposed kernel)

The policy column records which lever the dispatch policy resolves for
the shape (K >= N -> fine panels, N > K -> pre-pack).  Wall-clock is real
on this host because the per-call pack is real work in any runtime; the
compute loop itself runs through XLA's dot (Pallas numerics are validated
separately in interpret mode — timing interpret mode would benchmark the
Python emulator, not the kernel).  Default shapes are the paper's twelve
scaled by 1/4 per dim (CPU budget); --full runs the exact ones.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks import common
from repro import gemm as G
from repro.core import packing
from repro.models.model_zoo import PAPER_GEMM_SHAPES, PAPER_M


def run(scale: int = 4, trials: int = 3, block_n: int = 512,
        block_k: int = 512) -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    for model, op, n_full, k_full in PAPER_GEMM_SHAPES:
        m = PAPER_M
        n, k = n_full // scale, k_full // scale
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w_nk = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)

        bn, bk = min(block_n, n), min(block_k, k)
        # one policy-resolved plan per shape records the lever; the three
        # timed plans pin blocks so the comparison isolates the pack cost
        policy_plan = G.plan(m, n, k)
        p_xla = G.plan(m, n, k, backend="xla", pack=G.PACK_NONE,
                       transposed=True)
        p_percall = G.plan(m, n, k, backend="xla", pack=G.PACK_PERCALL,
                           block_n=bn, block_k=bk, transposed=True)
        # model-load phase (untimed): pack once, plan adopts the pack
        pw = packing.pack(w_nk, transposed=True, block_n=bn, block_k=bk)
        p_packed = G.plan_for_packed(m, pw, backend="xla")

        t_xla = common.time_fn(
            lambda x, w: G.execute(p_xla, x, w), x, w_nk, trials=trials)
        t_percall = common.time_fn(
            lambda x, w: G.execute(p_percall, x, w), x, w_nk,
            trials=trials)
        t_packed = common.time_fn(
            lambda x, pw=pw: G.execute(p_packed, x, pw), x, trials=trials)

        rows.append({
            "model": model, "op": op, "N": n, "K": k, "M": m,
            "policy_lever": policy_plan.lever,
            "xla_gflops": round(common.gflops(m, n, k, t_xla), 2),
            "percall_gflops": round(common.gflops(m, n, k, t_percall), 2),
            "packed_gflops": round(common.gflops(m, n, k, t_packed), 2),
            "packed_over_percall": round(t_percall / t_packed, 3),
            "packed_over_xla": round(t_xla / t_packed, 3),
        })
    return rows


def geomean(rows, key):
    vals = np.array([r[key] for r in rows], float)
    return float(np.exp(np.mean(np.log(vals))))


def main(full: bool = False):
    rs = run(scale=1 if full else 4)
    common.print_csv("table3_prefill_gemms", rs)
    gm_pc = geomean(rs, "packed_over_percall")
    gm_xla = geomean(rs, "packed_over_xla")
    print(f"geomean packed/percall {gm_pc:.3f}  packed/xla {gm_xla:.3f} "
          f"(paper: 1.58x over BNNSMatMul, ~2.0x over cblas)")
    common.write_table("table3_prefill_gemms", rs, meta={
        "geomean_packed_over_percall": gm_pc,
        "geomean_packed_over_xla": gm_xla,
        "scale": 1 if full else 4})
    return rs


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
