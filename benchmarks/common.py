"""Shared benchmark protocol — the paper's §4.1 discipline, scaled to CPU.

Paper: median over eleven isolated invocations; within an invocation
median over >= fifteen trials after warm-up.  Here (1-core CPU container)
the defaults shrink to reps×trials that finish in minutes, and every
table records the protocol it used.  Ratios are formed within one process
(like the paper's within-invocation ratios, so machine noise largely
cancels); absolute GFLOPS on this host are reported as context only —
the TPU-target numbers live in the §Roofline analysis, not here.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.obs import spans as _spans

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def time_fn(fn, *args, trials: int = 5, warmup: int = 2) -> float:
    """Median seconds per call (blocked until ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gflops(m: int, n: int, k: int, seconds: float) -> float:
    return 2.0 * m * n * k / seconds / 1e9


def retry_on_noise(measure, accept, *, max_retries: int = 4):
    """The suite's retry-on-noise discipline (table8/table9, and the
    plan store's measured autotune), hoisted: when a row that should
    win by construction (the accepted mode does strictly less work)
    measures below threshold, that is timer noise — RE-MEASURE with
    more reps, never fudge the number.

    ``measure(extra_reps)`` produces a row (called first with 0);
    ``accept(row)`` says whether it cleared the threshold.  Each retry
    adds ``2 * tries`` reps.  Returns ``(row, tries)`` — the last row
    stands even if it never cleared, so a real regression still shows.
    """
    with _spans.span("retry_on_noise", max_retries=max_retries) as sp:
        with _spans.span("measure", extra_reps=0):
            row = measure(0)
        tries = 0
        while not accept(row) and tries < max_retries:
            tries += 1
            _spans.instant("noise_retry", tries=tries,
                           extra_reps=2 * tries)
            with _spans.span("measure", extra_reps=2 * tries):
                row = measure(2 * tries)
        sp.set(tries=tries, accepted=bool(accept(row)))
        return row, tries


def shared_prefix_trace(rng, *, requests: int, prompt_len: int, vocab: int,
                        share_ratio: float = 0.8, n_prefixes: int = 2,
                        prefix_frac=(0.5, 0.9)):
    """Seeded shared-prefix request trace — the prefix-cache workload.

    Production prompts open with shared preambles (system prompt,
    few-shot header); ``share_ratio`` of the requests here start with
    one of ``n_prefixes`` shared preambles whose lengths are drawn
    uniformly from ``prefix_frac`` of ``prompt_len``, then append a
    unique suffix (>= 1 token, so the final prompt position always
    differs and the last-token-recomputed cap is exercised) up to
    ``prompt_len`` tokens total.  The rest are fully unique prompts of
    random length.  Deterministic given ``rng``'s seed.

    Returns ``(reqs, info)``: the int32 prompt arrays (arrival order,
    shared/unique interleaved by the rng) and an info dict with the
    realized share — ``shared_requests``, ``shared_tokens`` (prompt
    positions covered by a preamble, the work an ideal cache deletes),
    ``total_tokens``, and ``prefix_lens``.
    """
    lo = max(1, int(prefix_frac[0] * prompt_len))
    hi = max(lo, int(prefix_frac[1] * prompt_len))
    prefixes = [rng.integers(1, vocab, int(rng.integers(lo, hi + 1)))
                .astype(np.int32) for _ in range(n_prefixes)]
    reqs, shared_reqs, shared_toks = [], 0, 0
    for _ in range(requests):
        if rng.random() < share_ratio:
            p = prefixes[int(rng.integers(n_prefixes))]
            sfx = rng.integers(1, vocab, int(rng.integers(
                1, prompt_len - len(p) + 1))).astype(np.int32)
            reqs.append(np.concatenate([p, sfx]))
            shared_reqs += 1
            shared_toks += len(p)
        else:
            reqs.append(rng.integers(1, vocab, int(rng.integers(
                4, prompt_len + 1))).astype(np.int32))
    return reqs, {
        "share_ratio": shared_reqs / max(requests, 1),
        "shared_requests": shared_reqs,
        "shared_tokens": int(shared_toks),
        "total_tokens": int(sum(len(r) for r in reqs)),
        "prefix_lens": [len(p) for p in prefixes],
    }


def write_table(name: str, rows: list[dict], *, meta: dict | None = None):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump({"meta": meta or {}, "rows": rows}, f, indent=1)


def print_csv(name: str, rows: list[dict]):
    if not rows:
        return
    cols = list(rows[0])
    print(f"# {name}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
