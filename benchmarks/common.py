"""Shared benchmark protocol — the paper's §4.1 discipline, scaled to CPU.

Paper: median over eleven isolated invocations; within an invocation
median over >= fifteen trials after warm-up.  Here (1-core CPU container)
the defaults shrink to reps×trials that finish in minutes, and every
table records the protocol it used.  Ratios are formed within one process
(like the paper's within-invocation ratios, so machine noise largely
cancels); absolute GFLOPS on this host are reported as context only —
the TPU-target numbers live in the §Roofline analysis, not here.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def time_fn(fn, *args, trials: int = 5, warmup: int = 2) -> float:
    """Median seconds per call (blocked until ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gflops(m: int, n: int, k: int, seconds: float) -> float:
    return 2.0 * m * n * k / seconds / 1e9


def write_table(name: str, rows: list[dict], *, meta: dict | None = None):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump({"meta": meta or {}, "rows": rows}, f, indent=1)


def print_csv(name: str, rows: list[dict]):
    if not rows:
        return
    cols = list(rows[0])
    print(f"# {name}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
