"""Table 11 — the persistent plan store's two committed claims.

1. MEASURED AUTOTUNE NEVER LOSES: on the paper's twelve prefill GEMMs
   (M = S = 128), the plan the measured sweep deploys is at least as
   fast as the analytic policy's plan, within the retry-on-noise
   tolerance (``tuned_vs_analytic >= 1.0``).  This is the mis-tune
   guard made measurable: a candidate only displaces the analytic plan
   by clearing ``autotune.NOISE_RTOL``, otherwise the analytic plan is
   kept (``analytic_kept``) and the ratio is 1.0 by construction — a
   sub-1.0 median is timer noise and re-measures
   (``common.retry_on_noise``), never a silent regression.  Every
   deployed plan passed the bit-exactness gate first.

2. WARM START IS FREE: a second "process" (fresh in-memory plan cache,
   store reloaded from disk) resolves the full sweep surface with zero
   analytic resolutions and zero gate runs — store hits == plans
   needed — asserted here on the real store file the sweep wrote.

Emits ``benchmarks/out/table11_planstore.json`` (transient) and the
version-tracked ``benchmarks/BENCH_planstore.json`` baseline.
``--dry-run`` runs one tiny shape with both gates.
"""
from __future__ import annotations

import os
import tempfile

from benchmarks import common
from repro import gemm as G
from repro.core import autotune
from repro.gemm import policy as _pol
from repro.models.model_zoo import PAPER_GEMM_SHAPES

S = 128


def _row(store, model, op, m, n, k, *, trials, max_retries):
    with G.use_plan_store(store):
        mp = autotune.measured_autotune(m, n, k, trials=trials,
                                        max_retries=max_retries)
    return {"model": model, "op": op, "M": m, "N": n, "K": k,
            "lever": mp.plan.lever, **mp.row()}


def _warm_start_check(store, shapes, scale):
    """Reload the saved store in a fresh 'process' and re-plan the sweep
    surface THROUGH THE POLICY PATH: every plan must come from the
    store (zero analytic resolves, zero gate runs, hits == plans
    needed)."""
    G.plan_cache_clear()
    warm = G.PlanStore.load(store.path)
    assert warm.invalidated is None, warm.invalidated
    real = _pol._resolve
    calls = []
    _pol._resolve = lambda *a, **kw: (calls.append(1), real(*a, **kw))[1]
    try:
        with G.use_plan_store(warm):
            for _, _, n, k in shapes:
                p = G.plan(S, n // scale, k // scale)
                assert p.validated      # the gate ran in the sweep, once
    finally:
        _pol._resolve = real
    info = warm.info()
    assert not calls, f"warm start ran {len(calls)} analytic resolves"
    # plans NEEDED = unique (n, k) per M: duplicate paper shapes dedupe
    # in the in-memory cache and hit the store exactly once each
    assert info.misses == 0 and info.hits == info.entries
    assert info.entries == len({(n, k) for _, _, n, k in shapes})
    return {"entries": info.entries, "hits": info.hits,
            "misses": info.misses, "analytic_resolves": len(calls)}


def run(scale: int = 4, trials: int = 5, dry_run: bool = False,
        max_retries: int = 3, noise_retries: int = 4):
    G.plan_cache_clear()
    fd, path = tempfile.mkstemp(suffix=".json", prefix="planstore_bench_")
    os.close(fd)
    store = G.PlanStore(path)
    rows = []
    try:
        shapes = ([("dry", "dry", 256, 256)] if dry_run
                  else PAPER_GEMM_SHAPES)
        for model, op, n, k in shapes:
            # acceptance: tuned >= analytic.  The sweep's own mis-tune
            # guard makes this true by construction (analytic kept on a
            # below-noise win), so a sub-1.0 ratio is cross-sweep timer
            # drift — re-measure, never fudge.
            r, _ = common.retry_on_noise(
                lambda extra: _row(store, model, op, S, n // scale,
                                   k // scale, trials=trials + extra,
                                   max_retries=max_retries),
                lambda r: r["tuned_vs_analytic"] >= 1.0,
                max_retries=noise_retries)
            rows.append(r)
        store.save()
        warm = _warm_start_check(store, shapes, scale)
    finally:
        os.unlink(path)
    return rows, warm


def main(argv=()):
    dry = "--dry-run" in argv
    full = "--full" in argv
    rows, warm = run(scale=1 if full else 4, dry_run=dry)
    common.print_csv("table11_planstore", rows)
    bad = [r for r in rows if r["tuned_vs_analytic"] < 1.0]
    assert not bad, f"autotuned plan lost to analytic: {bad}"
    assert all(r["committed"] for r in rows)
    print(f"# warm start: {warm['entries']} entries, "
          f"{warm['hits']} hits / {warm['misses']} misses, "
          f"{warm['analytic_resolves']} analytic resolves")
    if dry:
        print("dry-run OK: sweep committed gate-passed plans, tuned >= "
              "analytic, warm start resolved store-only")
        return rows
    meta = {
        "note": "measured autotune vs the analytic policy per paper "
                "shape (tuned_vs_analytic >= 1.0 gated; analytic_kept "
                "rows are the mis-tune guard declining a below-noise "
                "win) + the warm-start contract on the store the sweep "
                "wrote (hits == plans needed, zero analytic resolves)",
        "protocol": "jitted, interleaved candidate reps, median; "
                    f"scale={1 if full else 4}; "
                    f"noise_rtol={autotune.NOISE_RTOL}; "
                    "retry_on_noise on the committed ratio",
        "schema": G.SCHEMA_VERSION,
        "host": G.host_fingerprint(),
        "warm_start": warm,
        "plan_cache": tuple(G.plan_cache_info()),
    }
    common.write_table("table11_planstore", rows, meta=meta)
    summary = {
        "all_tuned_ge_analytic": all(r["tuned_vs_analytic"] >= 1.0
                                     for r in rows),
        "min_tuned_vs_analytic": min(r["tuned_vs_analytic"]
                                     for r in rows),
        "analytic_kept": sum(1 for r in rows if r["analytic_kept"]),
        "tuned_wins": sum(1 for r in rows if not r["analytic_kept"]),
        "warm_start": warm,
        "rows": rows,
    }
    import json
    path = os.path.join(os.path.dirname(__file__), "BENCH_planstore.json")
    with open(path, "w") as f:
        json.dump({"meta": {"baseline_of": "table11_planstore",
                            "tracked_since": "persistent plan store PR",
                            **meta},
                   "baseline": summary}, f, indent=1)
    print(f"baseline -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
