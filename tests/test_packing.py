"""Packing + bit-exactness + autotune gate tests (paper levers as code)."""
import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro import gemm as G
from repro.core import autotune, bitexact, packing, scheduler
from repro.kernels import ref

RNG = np.random.default_rng(7)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def _packed_gemm(x, pw, backend):
    """Plan/execute on a packed weight (the shim-free legacy idiom)."""
    p = G.plan_for_packed(G.lead_m(x), pw, backend=backend)
    return G.execute(p, x, pw)


def test_pack_roundtrip_layouts():
    w_kn = _rand((300, 200))
    p1 = packing.pack(w_kn, block_n=128, block_k=128)
    p2 = packing.pack(jnp.asarray(np.asarray(w_kn).T), transposed=True,
                      block_n=128, block_k=128)
    assert p1.shape == p2.shape == (300, 200)
    np.testing.assert_array_equal(np.asarray(p1.data), np.asarray(p2.data))
    # padded region is zero, logical region preserved
    np.testing.assert_array_equal(np.asarray(p1.data)[:300, :200],
                                  np.asarray(w_kn))
    assert np.all(np.asarray(p1.data)[300:] == 0)


def test_packed_equals_percall_equals_xla():
    """All three API paths agree; packed/per-call are bit-identical to each
    other (same kernel math), xla within fp32 reorder tolerance."""
    x, w = _rand((128, 384)), _rand((384, 256))
    pw = packing.pack(w, block_n=128, block_k=128)
    y_packed = _packed_gemm(x, pw, "interpret")
    pc = G.plan(128, 256, 384, backend="interpret", block_n=128,
                block_k=128, pack=G.PACK_PERCALL)
    y_percall = G.execute(pc, x, w)
    px = G.plan(128, 256, 384, backend="xla", pack=G.PACK_NONE)
    y_xla = G.execute(px, x, w)
    bitexact.assert_bit_identical(np.asarray(y_packed),
                                  np.asarray(y_percall))
    np.testing.assert_allclose(y_packed, y_xla, rtol=1e-4, atol=1e-4)


def test_packed_gemm_batched_leading_dims():
    x = _rand((2, 64, 384))
    w = _rand((384, 256))
    pw = packing.pack(w, block_n=128, block_k=128)
    y = _packed_gemm(x, pw, "xla")
    np.testing.assert_allclose(
        y, np.einsum("bsk,kn->bsn", np.asarray(x), np.asarray(w)),
        rtol=1e-4, atol=1e-4)


def test_pack_pads_to_blocks():
    w = _rand((130, 70))
    pw = packing.pack(w, block_n=128, block_k=128)
    assert pw.data.shape == (256, 128)
    x = _rand((5, 130))
    y = _packed_gemm(x, pw, "interpret")
    np.testing.assert_allclose(y, np.asarray(x) @ np.asarray(w),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 200), k=st.integers(1, 200),
       seed=st.integers(0, 2**31 - 1))
def test_pack_gemm_property(n, k, seed):
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.standard_normal((k, n)).astype(np.float32))
    x = jnp.asarray(r.standard_normal((8, k)).astype(np.float32))
    pw = packing.pack(w, block_n=128, block_k=128)
    y = _packed_gemm(x, pw, "xla")
    np.testing.assert_allclose(y, np.asarray(x) @ np.asarray(w),
                               rtol=2e-4, atol=2e-4)


def test_bitexact_sampling_matches_paper_protocol():
    a = np.arange(10000, dtype=np.float32)
    b = a.copy()
    b[997 * 3] += 1.0   # lands exactly on the stride sample
    assert bitexact.max_abs_diff_sampled(a, b, 997) == 1.0
    assert not bitexact.bit_identical(a, b)
    assert bitexact.bit_identical(a, a.copy())


def test_scheduler_vmem_gate_and_occupancy():
    p = scheduler.plan(128, 2048, 2048, block_m=128, block_n=512,
                       block_k=512, num_cores=1)
    assert p.vmem_ok and p.aligned and p.occupancy == 1.0
    huge = scheduler.plan(128, 2048, 2048, block_m=512, block_n=2048,
                          block_k=2048)
    assert not huge.vmem_ok and huge.t_pred == float("inf")


def test_scheduler_fine_panels_beat_coarse_when_cores_idle():
    """Paper Fig. 2 analogue: with 8 cores, an Nc so coarse that the grid
    has fewer panels than cores predicts worse time than fine panels."""
    coarse = scheduler.plan(128, 2048, 2048, block_m=128, block_n=1024,
                            block_k=512, num_cores=8)
    fine = scheduler.plan(128, 2048, 2048, block_m=128, block_n=256,
                          block_k=512, num_cores=8)
    assert coarse.panels < 8 <= fine.panels
    assert fine.t_pred < coarse.t_pred


def test_autotune_sweep_bitexact_gate():
    res = autotune.sweep([(128, 512, 512)], validate=True)
    assert res, "sweep returned no bit-exact candidates"
    assert all(r.bit_exact for r in res)
    assert res[0].t_pred <= res[-1].t_pred


def test_mesh_panels_overlap_feasibility():
    good = scheduler.mesh_panels(8192, model_shards=16, block_n=512)
    assert good["overlap_feasible"] and good["kernel_panels_per_shard"] == 1
    bad = scheduler.mesh_panels(2048, model_shards=16, block_n=512)
    assert not bad["overlap_feasible"]
