"""Model-level gates for the fused-epilogue / horizontal-fusion subsystem.

The serving-stack analogue of the kernel's bit-exactness discipline: with
fp32 compute, an engine running the fused path (one QKV GEMM, one
glu gate-up GEMM, residual/softcap epilogues) must generate token-for-
token — and logit-for-logit — what the unfused packed engine and the
raw-weight engine generate, across the test archs (gqa, gelu+post-norm+
softcap+window gemma2, MLA).  Plus: the fused pack tree's structure, the
GenStats/ServeStats fusion flag, and serving parity with fusion on.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import gemm
from repro.core.packing import PackedWeight
from repro.models import model_zoo, transformer
from repro.runtime.serve_loop import Engine


def _fp32(name):
    cfg = model_zoo.reduced_config(model_zoo.get_config(name))
    return dataclasses.replace(cfg, compute_dtype="float32")


def _prompts(cfg, b=2, s=12, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)


@pytest.fixture(scope="module")
def stablelm32():
    cfg = _fp32("stablelm-3b")
    return cfg, model_zoo.build(cfg)


# ----------------------------------------------------- engine parity
@pytest.mark.parametrize("arch", ["stablelm-3b", "gemma2-9b",
                                  "deepseek-7b"])
def test_fused_engine_matches_unfused_and_raw_fp32(arch):
    """Greedy generation is bit-identical across fused / unfused-packed /
    raw engines at fp32 (gemma2 covers the gelu glu combine, post-norms,
    attn softcap, and local/global windows)."""
    cfg = _fp32(arch)
    params = model_zoo.build(cfg)
    prompts = _prompts(cfg)
    outs = {}
    for key, kw in (("fused", dict(packed=True, fuse=True)),
                    ("unfused", dict(packed=True, fuse=False)),
                    ("raw", dict(packed=False))):
        eng = Engine(cfg, params, max_len=64, **kw)
        outs[key], stats = eng.generate(prompts, 6)
        if kw.get("packed"):
            assert stats.fused is kw.get("fuse", True)
    np.testing.assert_array_equal(np.asarray(outs["fused"]),
                                  np.asarray(outs["unfused"]))
    np.testing.assert_array_equal(np.asarray(outs["fused"]),
                                  np.asarray(outs["raw"]))


def test_fused_mla_engine_matches_raw_fp32():
    """MLA arch: the fused w_dq/w_dkv/w_kr down-projection pack."""
    cfg = _fp32("deepseek-v3-671b")
    params = model_zoo.build(cfg)
    eng_f = Engine(cfg, params, max_len=32, packed=True, fuse=True)
    eng_r = Engine(cfg, params, max_len=32, packed=False)
    assert "w_dqkr" in eng_f.params["layers"]["attn"]
    prompts = _prompts(cfg, s=8)
    g_f, _ = eng_f.generate(prompts, 4)
    g_r, _ = eng_r.generate(prompts, 4)
    np.testing.assert_array_equal(np.asarray(g_f), np.asarray(g_r))


def test_fused_logits_bitexact_fp32(stablelm32):
    """Not just argmax: the full prefill logits are bit-identical."""
    cfg, params = stablelm32
    prompts = _prompts(cfg, s=10, seed=3)
    l_f, _ = Engine(cfg, params, max_len=32, packed=True,
                    fuse=True).prefill(prompts)
    l_r, _ = Engine(cfg, params, max_len=32, packed=False).prefill(prompts)
    assert l_f.dtype == l_r.dtype
    np.testing.assert_array_equal(np.asarray(l_f), np.asarray(l_r))


def test_fused_softcap_head_bitexact_fp32():
    """An untied softcap LM head routes the cap through the GEMM's store
    step (packed) — bit-identical to the unfused linear -> softcap."""
    cfg = dataclasses.replace(_fp32("gemma2-9b"), tie_embeddings=False)
    params = model_zoo.build(cfg)
    assert "lm_head" in params and cfg.logit_softcap
    prompts = _prompts(cfg, s=9, seed=5)
    l_f, _ = Engine(cfg, params, max_len=32, packed=True,
                    fuse=True).prefill(prompts)
    l_r, _ = Engine(cfg, params, max_len=32, packed=False).prefill(prompts)
    np.testing.assert_array_equal(np.asarray(l_f), np.asarray(l_r))


# ------------------------------------------------------ pack structure
def test_pack_for_inference_fuses_groups(stablelm32):
    cfg, params = stablelm32
    packed = model_zoo.pack_for_inference(cfg, params)
    attn = packed["layers"]["attn"]
    ffn = packed["layers"]["ffn"]
    assert "wqkv" in attn and "wq" not in attn and "wk" not in attn
    assert isinstance(attn["wqkv"], PackedWeight)
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    assert attn["wqkv"].n_splits == (h * hd, hkv * hd, hkv * hd)
    # stacked per-layer pack: leading L dim rides through
    assert attn["wqkv"].data.ndim == 3
    assert attn["wqkv"].data.shape[0] == cfg.num_layers
    assert "w_gate_up" in ffn and "w_gate" not in ffn
    assert ffn["w_gate_up"].n_splits == (cfg.d_ff, cfg.d_ff)
    # wo / w_down stay single packs
    assert isinstance(attn["wo"], PackedWeight)
    assert not attn["wo"].n_splits


def test_pack_for_inference_no_fusion_escape_hatch(stablelm32):
    cfg, params = stablelm32
    unpacked = model_zoo.pack_for_inference(cfg, params, fuse=False)
    attn = unpacked["layers"]["attn"]
    assert "wq" in attn and "wqkv" not in attn
    assert "w_gate" in unpacked["layers"]["ffn"]


def test_prefill_emits_fewer_gemms_when_fused(stablelm32):
    """The acceptance criterion at HLO level: the fused prefill trace
    contains >= 2 fewer dot ops per transformer block than unfused."""
    cfg, params = stablelm32
    prompts = _prompts(cfg, s=8, seed=7)

    def n_dots(fuse):
        packed = model_zoo.pack_for_inference(cfg, params, fuse=fuse)
        fn = jax.jit(lambda p, t: transformer.prefill(cfg, p, t,
                                                      max_len=16))
        hlo = fn.lower(packed, prompts).as_text()
        return hlo.count("dot_general")

    unfused, fused = n_dots(False), n_dots(True)
    # per (scanned) block: qkv 3->1 and gate+up 2->1 = 3 fewer GEMMs
    assert unfused - fused >= 3, (unfused, fused)


# ---------------------------------------------------- serving with fusion
def test_serve_parity_with_fusion_on(stablelm32):
    """Continuous batching over the fused engine stays bit-identical to
    per-request generate (the test_serving gate, fusion explicitly on),
    and the stats report the fused path."""
    cfg, params = stablelm32
    eng = Engine(cfg, params, max_len=48, packed=True, fuse=True)
    rng = np.random.default_rng(11)
    reqs = [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in (5, 17, 8)]
    mns = [6, 3, 5]
    refs = [np.asarray(eng.generate(jnp.asarray(r)[None], m)[0][0])
            for r, m in zip(reqs, mns)]
    outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                            prefill_chunk=8, page_size=8)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    assert stats.fused is True


def test_serve_chunked_reports_fused_flag(stablelm32):
    """Review fix: the legacy chunked loop must report the engine's
    fusion state like generate/serve do."""
    cfg, params = stablelm32
    eng = Engine(cfg, params, max_len=48, packed=True, fuse=True)
    reqs = [np.arange(1, 6, dtype=np.int32)]
    _, stats = eng.serve_chunked(reqs, batch_slots=1, prompt_len=8,
                                 max_new_tokens=2)
    assert stats.fused is True


def test_near_budget_pack_survives_residual_epilogue():
    """Review fix: the VMEM footprint budgets bias/residual operand
    headroom unconditionally, so a pack that fits cannot be re-clamped
    below its own blocks when the layer attaches a residual epilogue."""
    from repro.core import packing
    from repro.models import layers as L
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((2048, 2944)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 2048)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((8, 2944)), jnp.float32)
    pw = packing.pack(w, block_n=2944, block_k=512)
    y = L.linear(x, pw, residual=r)        # raised PlanMismatchError
    assert y.shape == (8, 2944)


def test_plan_cache_stays_hot_under_fused_serving(stablelm32):
    cfg, params = stablelm32
    eng = Engine(cfg, params, max_len=48, packed=True, fuse=True)
    rng = np.random.default_rng(13)
    reqs = [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in (5, 12, 9)]
    eng.serve(reqs, batch_slots=2, max_new_tokens=4, prefill_chunk=8,
              page_size=8)
    misses = gemm.plan_cache_info().misses
    reqs2 = [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
             for l in (7, 3, 14)]
    eng.serve(reqs2, batch_slots=2, max_new_tokens=3, prefill_chunk=8,
              page_size=8)
    assert gemm.plan_cache_info().misses == misses
