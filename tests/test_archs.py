"""Per-arch smoke tests (deliverable f): every assigned architecture in
reduced form runs one forward + one train step + one prefill/decode on
CPU, asserting output shapes and no NaNs.  Full-scale configs are
exercised only via the dry-run (ShapeDtypeStructs, no allocation)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, TrainConfig
from repro.models import model_zoo, transformer

ARCHS = model_zoo.list_archs()


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = model_zoo.reduced_config(model_zoo.get_config(arch))
            cache[arch] = (cfg, model_zoo.build(cfg))
        return cache[arch]
    return get


def _inputs(cfg, b, s, rng):
    if cfg.modality != "text":
        return jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                           cfg.cdtype)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, built):
    cfg, params = built(arch)
    rng = np.random.default_rng(0)
    b, s = 2, 32
    logits, _, aux = transformer.forward(cfg, params,
                                         _inputs(cfg, b, s, rng),
                                         mode="train")
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(arch, built):
    cfg, _ = built(arch)
    rng = np.random.default_rng(1)
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import train_loop
    mesh = make_host_mesh()
    # warmup_steps=0: lr(step=0) > 0 so one step must move the params
    tc = TrainConfig(steps=2, learning_rate=1e-3, warmup_steps=0)
    step = train_loop.make_train_step(cfg, tc, mesh, donate=False)
    state = jax.device_put(train_loop.init_state(cfg, tc),
                           train_loop.state_shardings(
                               train_loop.abstract_state(cfg, tc), mesh))
    b, s = 4, 32
    batch = {"inputs": _inputs(cfg, b, s, rng),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    new_state, metrics = step(state, batch)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed somewhere (bf16 params may round away tiny
    # updates on ones-initialized norm vectors — check the whole tree)
    moved = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch, built):
    """Decode-cache correctness: prefill(S) then decode(1) must equal the
    logits of a full forward over S+1 tokens (within compute-dtype
    tolerance) — the invariant behind every serve_step cell."""
    cfg, params = built(arch)
    rng = np.random.default_rng(2)
    b, s = 2, 16
    if cfg.modality != "text":
        pytest.skip("stub frontends exercise prefill only")
    if cfg.family == "moe":
        # Expert-capacity drops depend on the ROUTED TOKEN COUNT, so the
        # (s+1)-token forward and the 1-token decode can drop different
        # tokens — that's a batching property, not a cache bug.  Route
        # droplessly so this test isolates the cache invariant.
        import dataclasses
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    logits_p, cache = transformer.prefill(cfg, params,
                                          jnp.asarray(toks[:, :s]),
                                          max_len=s + 8)
    logits_d, _ = transformer.decode_step(cfg, params, cache,
                                          jnp.asarray(toks[:, s:s + 1]))
    logits_full, _, _ = transformer.forward(cfg, params, jnp.asarray(toks),
                                            mode="train")
    tol = 3e-2 if cfg.compute_dtype == "bfloat16" else 2e-4
    np.testing.assert_allclose(
        np.asarray(logits_d, np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("arch", sorted(model_zoo.LONG_CONTEXT_ARCHS))
def test_long_context_archs_have_bounded_cache(arch):
    """long_500k legality: decode state must NOT scale with seq_len."""
    cfg = model_zoo.get_config(arch)
    small = jax.eval_shape(lambda: transformer.init_cache(cfg, 1, 2 ** 15))
    big = jax.eval_shape(lambda: transformer.init_cache(cfg, 1, 2 ** 19))

    def nbytes(t):
        return sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(t))
    assert nbytes(big) == nbytes(small), (
        f"{arch} cache grows with context; long_500k would not fit")


def test_cells_skip_policy():
    cells = model_zoo.cells(include_skipped=True)
    skipped = {(a, s) for a, s, skip in cells if skip}
    assert all(s == "long_500k" for _, s in skipped)
    long_ok = {a for a, s, skip in cells
               if s == "long_500k" and not skip}
    assert long_ok == model_zoo.LONG_CONTEXT_ARCHS


def test_configs_match_assignment():
    """The assigned architecture table, as executable assertions."""
    expect = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for arch, (lyr, d, h, kv, ff, v) in expect.items():
        cfg = model_zoo.get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads,
               cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
        assert got == (lyr, d, h, kv, ff, v), (arch, got)
    assert model_zoo.get_config("deepseek-v3-671b").num_experts == 256
    assert model_zoo.get_config("qwen3-moe-30b-a3b").num_experts == 128
    assert model_zoo.get_config("mamba2-370m").ssm_state == 128
    assert model_zoo.get_config("hymba-1.5b").ssm_state == 16


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_shapes(shape_name):
    shape = SHAPES[shape_name]
    for arch in ("deepseek-7b", "musicgen-medium"):
        cfg = model_zoo.get_config(arch)
        spec = model_zoo.input_specs(cfg, shape_name)
        if shape.kind == "train":
            assert spec["labels"].shape == (shape.global_batch,
                                            shape.seq_len)
        if shape.kind == "decode":
            assert spec["tokens"].shape[:2] == (shape.global_batch, 1)
            assert "cache" in spec
        if cfg.modality != "text" and "inputs" in spec:
            assert spec["inputs"].shape[-1] == cfg.d_model
