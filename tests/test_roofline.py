"""Roofline tests: collective parsing, wire-byte formulas, the loop-aware
HLO cost walker on crafted modules, and model_flops accounting."""
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.models import model_zoo
from repro.roofline import analysis as R
from repro.roofline.hlo_cost import HloCostModel, parse_module

HLO = """HloModule test, num_partitions=8

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(%x, %y)
}

%cond (p: (s32[], f32[64,128])) -> pred[] {
  %p = (s32[], f32[64,128]{1,0}) parameter(0)
  %gte = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %dot.1 = f32[64,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[64,128]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,128]{1,0}) tuple(%ip, %ar)
}

ENTRY %main (a: f32[64,128], b: s32[]) -> f32[64,128] {
  %a = f32[64,128]{1,0} parameter(0)
  %b = s32[] parameter(1)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64,128]{1,0}) tuple(%zero, %a)
  %wh = (s32[], f32[64,128]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[64,128]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_parse_module_structure():
    comps, entry = parse_module(HLO)
    assert entry == "main"
    assert set(comps) >= {"add", "cond", "body", "main"}
    assert comps["cond"].consts == [5]


def test_walker_scales_by_trip_count():
    m = HloCostModel(HLO, total_devices=8)
    c = m.cost()
    # 5 trips x dot(64x128 @ 128x128) = 5 * 2*64*128*128
    assert c.flops == 5 * 2 * 64 * 128 * 128
    # 5 trips x all-reduce over group of 4: 2 * B * 3/4
    ar = 64 * 128 * 4
    assert c.coll_wire_bytes == pytest.approx(5 * 2 * ar * 3 / 4)
    assert m.loops == [{"body": "body", "trips": 5, "in": "main"}]


def test_wire_bytes_formulas():
    assert R._wire_bytes("all-gather", 1000, 4) == pytest.approx(750)
    assert R._wire_bytes("all-reduce", 1000, 4) == pytest.approx(1500)
    assert R._wire_bytes("reduce-scatter", 1000, 4) == pytest.approx(3000)
    assert R._wire_bytes("collective-permute", 1000, 4) == 1000
    assert R._wire_bytes("all-reduce", 1000, 1) == 0.0


def test_parse_collectives_iota_and_list_groups():
    text = (
        "  %ar = f32[128]{0} all-reduce(%x), replica_groups=[4,2]<=[8]\n"
        "  %ag = bf16[256]{0} all-gather(%y), "
        "replica_groups={{0,1,2,3},{4,5,6,7}}\n")
    ops = R.parse_collectives(text, 8)
    assert len(ops) == 2
    assert ops[0].group_size == 2
    assert ops[0].result_bytes == 512
    assert ops[1].group_size == 4
    assert ops[1].result_bytes == 512


def test_async_start_done_counted_once():
    text = (
        "  %s = f32[128]{0} all-gather-start(%x), "
        "replica_groups=[2,4]<=[8]\n"
        "  %d = f32[128]{0} all-gather-done(%s)\n")
    m = HloCostModel("ENTRY %e (p: f32[]) -> f32[] {\n" + text + "}\n",
                     total_devices=8)
    c = m.cost()
    assert c.coll_wire_bytes == pytest.approx(512 * 3 / 4)


def test_model_flops_by_kind():
    cfg = model_zoo.get_config("deepseek-7b")
    n = cfg.active_param_count()
    tr = R.model_flops(cfg, SHAPES["train_4k"])
    pf = R.model_flops(cfg, SHAPES["prefill_32k"])
    dc = R.model_flops(cfg, SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert dc == pytest.approx(2 * n * 128)


def test_moe_active_params_smaller():
    cfg = model_zoo.get_config("qwen3-moe-30b-a3b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
    # ~30B total / ~3B active per the model card
    assert 25e9 < cfg.param_count() < 35e9
    assert 2e9 < cfg.active_param_count() < 4.5e9


def test_param_counts_sane():
    """Analytic param counts near each arch's nameplate size."""
    expect = {
        "deepseek-7b": (6e9, 8e9),
        "gemma2-9b": (8e9, 11e9),
        "internvl2-76b": (68e9, 82e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "mamba2-370m": (0.30e9, 0.45e9),
        "hymba-1.5b": (1.2e9, 1.9e9),
        "stablelm-3b": (2.5e9, 3.6e9),
        "h2o-danube-3-4b": (3.2e9, 4.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = model_zoo.get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_roofline_terms_dominance():
    coll = {"seconds": 0.5, "dcn_seconds": 0.0, "by_kind": {},
            "num_ops": 1, "wire_bytes": 1.0}
    t = R.roofline_terms(flops_per_device=197e12 * 0.1,   # 0.1 s compute
                         bytes_per_device=819e9 * 0.2,    # 0.2 s memory
                         collective=coll, chips=256,
                         model_fl=1e15, dtype="bf16")
    assert t["dominant"] == "collective"
    assert t["bound_s"] == pytest.approx(0.5)
    assert t["compute_s"] == pytest.approx(0.1)
    assert t["memory_s"] == pytest.approx(0.2)
