"""Quantized pre-pack subsystem tests: format laws (quantize/dequantize
bounds, 2-bit pack/unpack, shape laws over odd dims / padding tails /
stacked weights), the dequant-fused kernel's bitwise contract vs the
blocked dequant oracle, epilogue/glu composition, plan/policy/backends
integration, the error-ledger tolerance gate, mixed-precision model
packing, and quantized serve == generate parity.

The round-trip/shape property test runs under hypothesis when installed
and falls back to a deterministic seeded sweep otherwise (so the skip
budget of a bare container does not grow)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import gemm as G
from repro.core import bitexact, packing
from repro.kernels import ref
from repro.quant import formats as F
from repro.quant import ledger

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(23)


def _rand(shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32)
                       * scale)


@pytest.fixture(autouse=True)
def _fresh_cache():
    G.plan_cache_clear()
    yield
    G.plan_cache_clear()


# -------------------------------------------------------- format laws
def _roundtrip_laws(k, n, seed, fmt, stacked=False):
    """The quantize -> dequantize round-trip and shape laws one (k, n,
    seed) instance must satisfy (hypothesis body / fallback sweep)."""
    r = np.random.default_rng(seed)
    shape = (2, k, n) if stacked else (k, n)
    w = jnp.asarray(r.standard_normal(shape).astype(np.float32))
    q, s = F.quantize(w, fmt)
    kg = -(-k // F.GROUP_K)
    assert q.shape == shape and q.dtype == jnp.int8
    assert s.shape == shape[:-2] + (kg, n)
    deq = np.asarray(q.astype(jnp.float32)
                     * F.expand_scales(s, k))
    err = np.abs(deq - np.asarray(w))
    s_row = np.asarray(F.expand_scales(s, k))
    if fmt == "int8":
        assert np.max(np.abs(np.asarray(q))) <= 127
        # per-element bound: half its group's quantization step
        assert np.all(err <= 0.5 * s_row + 1e-6)
    else:
        codes = np.asarray(q)
        assert set(np.unique(codes)) <= {-1, 0, 1}
        # sparse-aware split: zeroed weights are the sub-threshold ones
        packed = F.pack_ternary_codes(
            jnp.asarray(np.pad(codes, [(0, 0)] * (codes.ndim - 2)
                               + [(0, (-k) % 4), (0, 0)])))
        unpacked = np.asarray(F.unpack_ternary_codes(packed))[..., :k, :]
        np.testing.assert_array_equal(unpacked, codes.astype(np.float32))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(k=st.integers(1, 300), n=st.integers(1, 100),
           seed=st.integers(0, 2**31 - 1),
           fmt=st.sampled_from(F.FORMATS),
           stacked=st.booleans())
    def test_quant_roundtrip_property(k, n, seed, fmt, stacked):
        _roundtrip_laws(k, n, seed, fmt, stacked)
else:
    def test_quant_roundtrip_property():
        # deterministic sweep: odd dims, group tails, stacked weights
        cases = [(1, 1), (3, 7), (127, 5), (128, 64), (129, 31),
                 (255, 130), (300, 200), (257, 3)]
        for i, (k, n) in enumerate(cases):
            for fmt in F.FORMATS:
                _roundtrip_laws(k, n, 1000 + i, fmt,
                                stacked=(i % 2 == 0))


@pytest.mark.parametrize("fmt", F.FORMATS)
def test_quantize_pack_shape_laws_odd_dims(fmt):
    """Pack-level shape laws: odd K/N pad to block multiples, scales pad
    to whole groups, padded region dequantizes to exact zero, logical
    dims are preserved."""
    w = _rand((130, 70), 0.02)
    qpw = packing.pack(w, block_n=128, block_k=128, quant=fmt)
    assert (qpw.k, qpw.n) == (130, 70)
    assert qpw.k_pad == 256 and qpw.n_pad == 128
    krows = 64 if fmt == "ternary" else 256
    assert qpw.data.shape == (krows, 128)
    assert qpw.scales.shape == (256 // F.GROUP_K, 128)
    deq = np.asarray(F.dequantize(qpw))
    assert deq.shape == (256, 128)
    assert np.all(deq[130:] == 0) and np.all(deq[:, 70:] == 0)


@pytest.mark.parametrize("fmt", F.FORMATS)
def test_quantize_pack_stacked_and_fused(fmt):
    """Stacked [L, K, N] packs keep the leading dim; fused packs keep
    the static split map with per-part column padding."""
    w3 = _rand((3, 250, 130), 0.02)
    qpw = F.quantize_pack(w3, fmt, block_n=128, block_k=128)
    assert qpw.data.shape[0] == 3 and qpw.scales.shape[0] == 3
    assert (qpw.k, qpw.n) == (250, 130)
    parts = [_rand((256, wn), 0.02) for wn in (192, 64, 64)]
    qf = packing.pack_fused(parts, block_n=128, block_k=128, quant=fmt)
    assert qf.n_splits == (192, 64, 64)
    assert qf.n_pad == 512                     # 256 + 128 + 128
    x = _rand((8, 256))
    p = G.plan_for_packed(8, qf, backend="xla")
    outs = G.split_fused(p, G.execute(p, x, qf))
    for out, part in zip(outs, parts):
        q1 = packing.pack(part, block_n=128, block_k=128, quant=fmt)
        p1 = G.plan_for_packed(8, q1, backend="xla")
        bitexact.assert_bit_identical(np.asarray(out),
                                      np.asarray(G.execute(p1, x, q1)))


# ------------------------------------------- kernel bitwise contract
@pytest.mark.parametrize("fmt", F.FORMATS)
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_quant_execute_vs_blocked_dequant_oracle(fmt, backend):
    """THE structural contract: the dequant-fused path is bit-identical
    (interpret) / allclose (xla) to the blocked oracle over the SAME
    dequantized panels."""
    m, k, n = 16, 300, 200
    w, x = _rand((k, n), 0.02), _rand((m, k))
    qpw = packing.pack(w, block_n=128, block_k=128, quant=fmt)
    p = G.plan_for_packed(m, qpw, backend=backend)
    y = G.execute(p, x, qpw)
    deq = F.dequantize(qpw)
    xp = jnp.pad(x, ((0, 0), (0, qpw.k_pad - k)))
    if backend == "interpret":
        xp = jnp.pad(xp, ((0, p.m_pad - m), (0, 0)))
        oracle = ref.gemm_blocked(xp, deq, p.block_k)[:m, :n]
        bitexact.assert_bit_identical(np.asarray(y), np.asarray(oracle))
    else:
        oracle = jnp.dot(xp, deq)[:m, :n]
        np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-5)
    assert G.validate_plan(p)


QEPI = [
    G.EpilogueSpec(bias=True),
    G.EpilogueSpec(act="silu"),
    G.EpilogueSpec(softcap=30.0),
    G.EpilogueSpec(bias=True, act="gelu", residual=True),
    G.EpilogueSpec(glu="silu"),
    G.EpilogueSpec(glu="gelu", bias=True, residual=True),
]


def _epi_id(s):
    parts = [k for k, v in (("bias", s.bias), ("res", s.residual)) if v]
    if s.act:
        parts.insert(0, s.act)
    if s.glu:
        parts.insert(0, f"glu-{s.glu}")
    if s.softcap:
        parts.append("softcap")
    return "+".join(parts)


@pytest.mark.parametrize("fmt", F.FORMATS)
@pytest.mark.parametrize("spec", QEPI, ids=_epi_id)
def test_quant_epilogue_bitexact_vs_unfused_sequence(fmt, spec):
    """EpilogueSpec composes with the dequant-fused kernel: fused-quant
    is bit-identical to the unfused quant execute -> jnp ops sequence
    (glu two-accumulator variant included)."""
    m, k = 32, 256
    n = 512 if spec.glu else 256
    if spec.glu:
        pw = packing.pack_fused([_rand((k, n // 2), 0.02),
                                 _rand((k, n // 2), 0.02)],
                                block_n=128, block_k=128, quant=fmt)
    else:
        pw = packing.pack(_rand((k, n), 0.02), block_n=128, block_k=128,
                          quant=fmt)
    x = _rand((m, k))
    kw = dict(backend="interpret")
    base = G.plan_for_packed(m, pw, **kw)
    p = G.plan_for_packed(m, pw, epilogue=spec, **kw)
    assert G.validate_plan(p)
    bias = None
    if spec.bias:
        full = _rand((n,))
        # a fused pack takes one bias per part; the unfused reference
        # epilogue takes the concatenated row
        bias = ([full[:n // 2], full[n // 2:]] if spec.glu else full)
    bias_ref = jnp.concatenate(bias) if isinstance(bias, list) else bias
    res = _rand((m, p.n_out)) if spec.residual else None

    @jax.jit
    def fused(x, pw):
        return G.execute(p, x, pw, bias=bias, residual=res)

    @jax.jit
    def unfused(x, pw):
        acc = G.execute(base, x, pw, out_dtype=jnp.float32)
        return G.apply_epilogue(acc, spec, bias=bias_ref,
                                residual=res).astype(jnp.float32)

    bitexact.assert_bit_identical(np.asarray(fused(x, pw)),
                                  np.asarray(unfused(x, pw)))


# ---------------------------------------------- plan / policy / backends
def test_weight_format_is_plan_keyed_and_prepack_only():
    a = G.plan(128, 512, 256)
    b = G.plan(128, 512, 256, weight_format="int8")
    c = G.plan(128, 512, 256, weight_format="ternary")
    assert len({a, b, c}) == 3 and G.plan_cache_info().misses == 3
    assert b.quantized and b.pack == G.PACK_PREPACKED
    assert a.weight_format == "fp32" and not a.quantized
    assert "weight_format=int8" in b.describe()
    with pytest.raises(ValueError):
        G.plan(128, 512, 256, weight_format="int8", pack=G.PACK_PERCALL)
    with pytest.raises(Exception):
        G.plan(128, 512, 256, weight_format="fp8")     # unknown format


def test_quant_vmem_fit_admits_wider_blocks():
    """int8 streams 4x and ternary 16x fewer weight bytes per tile, so a
    block triple that clamps at fp32 stands at reduced precision."""
    from repro.kernels.panel_gemm import VMEM_BUDGET, vmem_bytes
    bm, bn, bk = 128, 2048, 2048
    assert vmem_bytes(bm, bn, bk) > VMEM_BUDGET
    assert vmem_bytes(bm, bn, bk, weight_format="ternary") < \
        vmem_bytes(bm, bn, bk, weight_format="int8") < \
        vmem_bytes(bm, bn, bk)
    pf = G.plan(128, 4096, 8192, block_n=bn, block_k=bk)
    pq = G.plan(128, 4096, 8192, block_n=bn, block_k=bk,
                weight_format="ternary")
    assert pf.vmem_clamped
    assert (pq.block_n, pq.block_k) == (bn, bk) and not pq.vmem_clamped


def test_execute_mismatch_errors():
    w = _rand((256, 128), 0.02)
    qpw = packing.pack(w, block_n=128, block_k=128, quant="int8")
    pw = packing.pack(w, block_n=128, block_k=128)
    x = _rand((8, 256))
    pq = G.plan_for_packed(8, qpw)
    pf = G.plan_for_packed(8, pw)
    with pytest.raises(G.PlanMismatchError):
        G.execute(pq, x, pw)            # quant plan, fp32 pack
    with pytest.raises(G.PlanMismatchError):
        G.execute(pf, x, qpw)           # fp32 plan, quant pack
    with pytest.raises(G.PlanMismatchError):
        G.execute(pq, x, w)             # quant plan, raw weight


def test_custom_backend_without_run_quant_rejects_quant_plans():
    def run(x_p, w_p, *, block_m, block_n, block_k, out_dtype):
        return jnp.dot(x_p, w_p).astype(out_dtype or x_p.dtype)

    G.register_backend("test-noquant", run)
    try:
        w = _rand((256, 128), 0.02)
        qpw = packing.pack(w, block_n=128, block_k=128, quant="int8")
        p = G.plan_for_packed(8, qpw, backend="test-noquant")
        with pytest.raises(G.PlanMismatchError, match="run_quant"):
            G.execute(p, _rand((8, 256)), qpw)
    finally:
        G.unregister_backend("test-noquant")


# --------------------------------------------------------- error ledger
def test_ledger_records_and_enforces_at_pack_time(monkeypatch):
    ledger.clear()
    w = _rand((256, 192), 0.02)
    qpw = packing.pack(w, block_n=128, block_k=128, quant="int8")
    ent = ledger.lookup(192, 256, "int8")
    assert ent is not None and ent.within_tol
    assert ent.max_rel <= ledger.TOLERANCES["int8"]
    assert ent.max_abs > 0                      # real quantization error
    row = ent.row()
    assert row["within_tol"] and row["format"] == "int8"
    # enforcement: an impossible tolerance makes the SAME pack raise
    monkeypatch.setitem(ledger.TOLERANCES, "int8", 1e-12)
    with pytest.raises(ledger.QuantToleranceError):
        packing.pack(w, block_n=128, block_k=128, quant="int8")


def test_validate_plan_rejects_over_tolerance_ledger_entry():
    """The acceptance gate: a quantized plan whose ledger entry exceeds
    tolerance is REJECTED by validate_plan; within tolerance passes."""
    ledger.clear()
    n, k = 320, 128
    p = G.plan(8, n, k, weight_format="int8")
    ledger.record(ledger.LedgerEntry(n=n, k=k, fmt="int8", max_abs=1.0,
                                     max_rel=0.5, tol=1e-2, probe_m=64))
    assert not G.validate_plan(p)
    ledger.record(ledger.LedgerEntry(n=n, k=k, fmt="int8", max_abs=1e-4,
                                     max_rel=1e-3, tol=1e-2, probe_m=64))
    assert G.validate_plan(p)
    ledger.clear()


def test_ledger_tolerances_match_contract():
    assert ledger.TOLERANCES["int8"] <= 1e-2
    assert "ternary" in ledger.TOLERANCES      # documented ceiling
    with pytest.raises(KeyError):
        ledger.tolerance("fp8")


# ------------------------------------------------- model / serving path
def _smoke_engine(quant, **kw):
    from repro.models import model_zoo
    from repro.runtime.serve_loop import Engine
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    params = model_zoo.build(cfg)
    return cfg, Engine(cfg, params, max_len=96, quant=quant, **kw)


def test_pack_for_inference_mixed_precision_tree():
    from repro.models import model_zoo
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    params = model_zoo.build(cfg)
    pp = model_zoo.pack_for_inference(cfg, params, quant="int8")
    layers = pp["layers"]
    assert isinstance(layers["attn"]["wqkv"], F.QuantizedPackedWeight)
    assert layers["attn"]["wqkv"].fmt == "int8"
    assert layers["attn"]["wqkv"].n_splits      # fused + quantized
    assert isinstance(layers["ffn"]["w_gate_up"], F.QuantizedPackedWeight)
    # keep_fp32 defaults pin the head (packed fp32) and the embeddings
    assert isinstance(pp["lm_head"], packing.PackedWeight)
    assert not isinstance(pp["lm_head"], F.QuantizedPackedWeight)
    assert not isinstance(pp["embed"], packing.PackedWeight)
    # literal-name pinning keeps that projection fp32
    pp2 = model_zoo.pack_for_inference(
        cfg, params, quant="int8", keep_fp32=("head", "embed", "wo"))
    assert not isinstance(pp2["layers"]["attn"]["wo"],
                          F.QuantizedPackedWeight)


@pytest.mark.parametrize("quant", ["int8", "ternary"])
def test_quant_engine_serve_matches_generate(quant):
    """Acceptance: pack_for_inference(quant=...) serves through
    Engine.serve with parity to one-shot quantized generate."""
    cfg, eng = _smoke_engine(quant)
    rng = np.random.default_rng(5)
    reqs = [rng.integers(0, cfg.vocab_size, int(ln)).astype(np.int32)
            for ln in (7, 12, 4)]
    mns = [4, 3, 5]
    outs, sstats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns)
    assert sstats.quant == quant
    assert sstats.plan_cache is not None
    for req, mn, out in zip(reqs, mns, outs):
        gen, gstats = eng.generate(jnp.asarray(req[None, :]), mn)
        np.testing.assert_array_equal(out, np.asarray(gen)[0])
    assert gstats.quant == quant
    assert gstats.plan_cache.misses > 0


def test_engine_quant_requires_packed():
    from repro.models import model_zoo
    from repro.runtime.serve_loop import Engine
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    params = model_zoo.build(cfg)
    with pytest.raises(ValueError):
        Engine(cfg, params, packed=False, quant="int8")


# ----------------------------------------------------- vmem warn satellite
def test_vmem_clamp_warns_once_naming_plan_key():
    with pytest.warns(RuntimeWarning, match="VMEM"):
        p = G.plan(128, 4096, 8192, block_n=2048, block_k=4096)
    assert p.vmem_clamped
    assert G.vmem_clamped_count() >= 1
    # one-time per plan key: the second resolution stays silent
    G.plan_cache_clear()            # drop the plan, keep re-resolving
    import warnings as _w
    from repro.gemm import policy as pol
    pol._vmem_warned.add((128, 4096, 8192, "float32", "xla", "fp32"))
    with _w.catch_warnings():
        _w.simplefilter("error", RuntimeWarning)
        G.plan(128, 4096, 8192, block_n=2048, block_k=4096)
