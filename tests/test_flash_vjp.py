"""Flash custom-VJP vs reverse-mode-through-scan: forward and gradients
must agree (fp32) across masking variants — causal, sliding window,
softcap, GQA grouping, ring k_pos, cache offsets."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import attention as A


def _mk(b, s, t, h, hkv, d, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, hkv, d)), jnp.float32)
    return q, k, v


def _both_paths(fn):
    """Run fn once with the flash VJP and once with plain autodiff."""
    old = A.USE_FLASH_VJP
    try:
        A.USE_FLASH_VJP = True
        flash = fn()
        A.USE_FLASH_VJP = False
        ref = fn()
    finally:
        A.USE_FLASH_VJP = old
    return flash, ref


CASES = [
    dict(),                                      # plain causal
    dict(window=7),                              # sliding window
    dict(softcap=8.0),                           # gemma-style softcap
    dict(window=5, softcap=4.0),
    dict(causal=False),
]


@pytest.mark.parametrize("kw", CASES)
def test_forward_and_grads_match(kw):
    q, k, v = _mk(2, 16, 16, 4, 2, 8)

    def loss(q, k, v):
        o = A.blocked_attention(q, k, v, scale=0.35, chunk=8, **kw)
        return jnp.sum(o * jnp.cos(jnp.arange(o.size, dtype=jnp.float32)
                                   .reshape(o.shape)))

    def run():
        val = loss(q, k, v)
        grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        return val, grads

    (vf, gf), (vr, gr) = _both_paths(run)
    np.testing.assert_allclose(np.asarray(vf), np.asarray(vr),
                               rtol=2e-5, atol=2e-5)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_cache_offset_and_kvlen():
    """Decode-style: q is one new row at offset; cache partially filled."""
    q, k, v = _mk(2, 1, 24, 4, 4, 8, seed=3)

    def loss(q, k, v):
        o = A.blocked_attention(q, k, v, scale=0.3, kv_len=17,
                                q_offset=16, chunk=8)
        return jnp.sum(o ** 2)

    def run():
        return loss(q, k, v), jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    (vf, gf), (vr, gr) = _both_paths(run)
    np.testing.assert_allclose(np.asarray(vf), np.asarray(vr), rtol=2e-5)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_ring_kpos_slots():
    """SWA ring cache: unordered slots with explicit positions + holes."""
    rng = np.random.default_rng(4)
    q, k, v = _mk(1, 4, 8, 2, 2, 8, seed=4)
    k_pos = jnp.asarray([[9, 10, 3, -1, 5, 6, 7, 8]], jnp.int32)

    def loss(q, k, v):
        o = A.blocked_attention(q, k, v, scale=0.4, window=6, k_pos=k_pos,
                                q_offset=10, chunk=4)
        return jnp.sum(jnp.abs(o))

    def run():
        return loss(q, k, v), jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    (vf, gf), (vr, gr) = _both_paths(run)
    np.testing.assert_allclose(np.asarray(vf), np.asarray(vr), rtol=2e-5)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_residuals_are_linear_not_quadratic():
    """The point of the exercise: VJP residual bytes must scale with T,
    not S·T.  Counted from the jaxpr of the linearized function."""
    def resid_bytes(s, t):
        q, k, v = _mk(1, s, t, 2, 2, 8, seed=1)

        def f(q, k, v):
            return A.blocked_attention(q, k, v, scale=0.3, chunk=8)
        _, vjp = jax.vjp(f, q, k, v)
        leaves = jax.tree.leaves(vjp)
        return sum(x.size * x.dtype.itemsize for x in leaves
                   if hasattr(x, "size"))

    old = A.USE_FLASH_VJP
    try:
        A.USE_FLASH_VJP = True
        b1 = resid_bytes(32, 32)
        b2 = resid_bytes(64, 64)       # 2x seq: quadratic would give 4x
    finally:
        A.USE_FLASH_VJP = old
    assert b2 < 3.0 * b1, (b1, b2)
