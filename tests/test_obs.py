"""Observability-layer gates (ISSUE 9).

Four contracts:

* **Non-perturbation** — serving with the full obs stack active
  (tracer + flight recorder + metrics) returns bit-identical tokens to
  the un-instrumented per-request ``generate`` reference, and the
  inactive instrumentation adds no measurable overhead to the eager
  GEMM path (the strict <=3% gate lives in benchmarks/table12_obs.py).
* **Schema** — an exported trace is valid Chrome-trace JSON
  (``validate_chrome_trace`` finds nothing) and its synthesized
  ``gemm_dispatch`` spans carry plan key, lever and GFLOPS.
* **Determinism** — two identical seeded serve runs publish
  byte-identical metrics snapshots once wall-clock-valued metrics
  (``_ms`` / ``_seconds`` names) are excluded.
* **Bounded state** — the flight-recorder ring wraps (oldest first),
  the tracer drops oldest past its cap, and the scheduler's audit
  trace is bounded with a ``trace_dropped`` counter.
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro import gemm, obs
from repro.models import model_zoo
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import spans as obs_spans
from repro.runtime.batching import _BoundedTrace
from repro.runtime.serve_loop import Engine

MAX_LEN = 48
PAGE = 8
CHUNK = 8
LENS = [5, 17, 8, 12]
MNS = [6, 3, 8, 5]


def _requests(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in lens]


@pytest.fixture(scope="module")
def engine():
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    params = model_zoo.build(cfg)
    return cfg, Engine(cfg, params, max_len=MAX_LEN, packed=True)


# ------------------------------------------------------ non-perturbation
def test_serve_parity_with_full_obs_active(engine):
    """Tokens with tracer + recorder + metrics all on == un-instrumented
    per-request generate."""
    cfg, eng = engine
    reqs = _requests(cfg, LENS)
    refs = [np.asarray(eng.generate(jnp.asarray(r)[None], m)[0][0])
            for r, m in zip(reqs, MNS)]
    tracer = obs.Tracer()
    rec = obs.FlightRecorder(fence=True)
    reg = obs.MetricsRegistry()
    reg.add_collector(obs.gemm_collector)
    with obs.use_tracer(tracer), obs.use_recorder(rec), \
            obs.use_metrics(reg):
        outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=MNS,
                                prefill_chunk=CHUNK, page_size=PAGE,
                                sync_per_step=True)
    for i, (o, r) in enumerate(zip(outs, refs)):
        np.testing.assert_array_equal(
            o, r, err_msg=f"request {i} diverged under observation")
    # the run actually WAS observed
    assert tracer.events, "no spans collected"
    assert rec.traced > 0 or rec.total > 0, "recorder saw nothing"
    snap = reg.snapshot()
    assert snap["serve_decode_tokens"]["series"]["_"] == sum(MNS)
    assert snap["serve_prefill_tokens"]["series"]["_"] == sum(LENS)
    assert stats.trace_dropped == 0


def test_inactive_obs_overhead_bounded():
    """With no tracer/recorder/metrics active, the execute() hook is one
    int check — eager dispatch time must not regress measurably.
    Generous 1.5x bound with retry-on-noise (the tight 3% gate is
    benchmarks/table12_obs.py, which uses many more reps)."""
    import importlib
    exec_mod = importlib.import_module("repro.gemm.execute")
    assert obs_recorder._HOT == 0 and obs_spans._ANY == 0
    rng = np.random.default_rng(0)
    p = gemm.plan(64, 256, 256)
    x = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    gemm.execute(p, x, w)                 # compile both paths
    exec_mod._execute_impl(p, x, w)

    def best(fn, reps):
        return obs.measure(fn, fence=True, repeats=reps)

    for attempt in range(4):
        reps = 10 * (attempt + 1)
        t_hook = best(lambda: gemm.execute(p, x, w), reps)
        t_bare = best(lambda: exec_mod._execute_impl(p, x, w), reps)
        if t_hook <= t_bare * 1.5:
            return
    pytest.fail(f"inactive obs hook overhead: execute {t_hook * 1e6:.1f}us"
                f" vs bare {t_bare * 1e6:.1f}us")


# ----------------------------------------------------------------- schema
def test_exported_trace_is_valid_and_carries_gemm_spans(engine, tmp_path):
    cfg, eng = engine
    reqs = _requests(cfg, LENS, seed=1)
    tracer = obs.Tracer()
    rec = obs.FlightRecorder()
    with obs.use_tracer(tracer), obs.use_recorder(rec):
        eng.serve(reqs, batch_slots=2, max_new_tokens=MNS,
                  prefill_chunk=CHUNK, page_size=PAGE)
    path = tracer.export_chrome_trace(str(tmp_path / "t.json"),
                                      recorder=rec)
    trace = json.load(open(path))
    assert obs.validate_chrome_trace(trace) == []
    # jitted steps registered manifests; the exporter synthesized
    # apportioned per-GEMM children under the tick spans
    assert trace["gemmManifests"], "no step manifests in trace"
    gemms = obs.gemm_events(trace)
    assert gemms, "no gemm_dispatch spans synthesized"
    for a in gemms[:10]:
        assert a["plan"] and a["lever"] and a["m"] > 0
        assert a["apportioned"] is True
        assert a["gflops"] > 0
    # tick spans carry the step attr linking them to their manifest
    # (plan_resolve spans only appear when plans were not already
    # cached by an earlier run — not asserted here)
    names = {ev["name"] for ev in trace["traceEvents"]}
    assert {"prefill_chunk", "decode_tick"} <= names
    rows = obs.per_shape_table(trace)
    assert rows and all(r["dispatches"] > 0 for r in rows)
    assert any("fine_panels" in r["lever_mix"] or
               "prepack" in r["lever_mix"] for r in rows)


def test_validate_chrome_trace_catches_bad_events():
    assert obs.validate_chrome_trace({}) == ["missing traceEvents key"]
    bad = {"traceEvents": [
        {"name": "a", "ph": "Z", "ts": 0},               # bad phase
        {"name": "b", "ph": "X", "ts": 0},               # missing dur
        {"ph": "i", "ts": 0},                            # missing name
        {"name": "c", "ph": "X", "ts": "soon", "dur": 1},  # bad ts
    ]}
    assert len(obs.validate_chrome_trace(bad)) == 4
    good = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 1.0, "args": {}},
        {"name": "m", "ph": "M", "args": {"name": "p"}},
        {"name": "i", "ph": "i", "ts": 2.0},
    ]}
    assert obs.validate_chrome_trace(good) == []


# ------------------------------------------------------------ determinism
def _strip_timing(snap):
    return {k: v for k, v in snap.items()
            if not (k.endswith("_ms") or k.endswith("_seconds"))}


def test_metrics_snapshot_deterministic_across_identical_runs(engine):
    cfg, eng = engine
    snaps = []
    for _ in range(2):
        reqs = _requests(cfg, LENS, seed=7)
        reg = obs.MetricsRegistry()      # fresh registry, no collectors
        with obs.use_metrics(reg):
            eng.serve(reqs, batch_slots=2, max_new_tokens=MNS,
                      prefill_chunk=CHUNK, page_size=PAGE)
        snaps.append(json.dumps(_strip_timing(reg.snapshot()),
                                sort_keys=True))
    assert snaps[0] == snaps[1], "identical runs published different " \
                                 "non-timing metrics"


def test_prometheus_text_and_histogram_buckets():
    reg = obs.MetricsRegistry()
    reg.counter("reqs_total", help="requests").inc(3, state="DONE")
    reg.gauge("depth").set(4)
    h = reg.histogram("lat_ms", buckets=(1, 10, 100))
    for v in (0.5, 5, 5, 50, 500):
        h.observe(v)
    text = reg.prometheus_text()
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{state="DONE"} 3' in text
    assert 'depth 4' in text
    # cumulative buckets: <=1:1, <=10:3, <=100:4, +Inf:5
    assert 'lat_ms_bucket{le="1.0"} 1' in text
    assert 'lat_ms_bucket{le="10.0"} 3' in text
    assert 'lat_ms_bucket{le="100.0"} 4' in text
    assert 'lat_ms_bucket{le="+Inf"} 5' in text
    assert 'lat_ms_count 5' in text
    snap = reg.snapshot()
    assert snap["lat_ms"]["series"]["_"]["counts"] == [1, 2, 1, 1]
    assert snap["lat_ms"]["series"]["_"]["count"] == 5
    with pytest.raises(TypeError):
        reg.gauge("reqs_total")          # kind collision is an error


# --------------------------------------------------------- bounded state
def test_flight_recorder_ring_wraparound():
    p = gemm.plan(8, 64, 64)
    rec = obs.FlightRecorder(capacity=4)
    for i in range(10):
        rec.record(p, 8, wall_s=1e-3 * (i + 1), fenced=True)
    assert rec.total == 10
    assert rec.wrapped == 6
    dump = rec.dump()
    assert len(dump) == 4
    ts = [r["ts_ms"] for r in dump]
    assert ts == sorted(ts), "dump not chronological"
    # the survivors are the newest four
    assert [r["wall_ms"] for r in dump] == pytest.approx([7, 8, 9, 10])
    # plan-cache proxy: first sighting is a miss, repeats are hits
    assert dump[0]["plan_cache_hit"] is True   # key seen before wrap
    assert all(r["gflops"] > 0 for r in dump)
    assert all(r["fenced"] for r in dump)


def test_recorder_records_eager_dispatches_with_lever_fields():
    rng = np.random.default_rng(1)
    p = gemm.plan(16, 64, 64)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    rec = obs.FlightRecorder(fence=True)
    with obs.use_recorder(rec):
        gemm.execute(p, x, w)
        gemm.execute(p, x, w)
    assert rec.total == 2
    a, b = rec.dump()
    assert a["plan_cache_hit"] is False and b["plan_cache_hit"] is True
    for r in (a, b):
        assert r["m"] == 16 and r["n"] == 64 and r["k"] == 64
        assert r["backend"] and r["lever"]
        assert r["fenced"] and r["gflops"] > 0
        assert 0 < r["roofline_frac"] <= 1


def test_tracer_drops_oldest_past_cap():
    tr = obs.Tracer(max_events=10)
    with obs.use_tracer(tr):
        for i in range(25):
            obs.instant("e", i=i)
    assert len(tr.events) <= 10 and tr.dropped > 0
    kept = [ev["args"]["i"] for ev in tr.events]
    assert kept == sorted(kept) and kept[-1] == 24   # newest survive


def test_scheduler_trace_bounded_with_drop_counter():
    t = _BoundedTrace(cap=8)
    for i in range(20):
        t.append(("ev", i))
    assert len(t) == 8
    assert t.dropped == 12
    assert [ev[1] for ev in t] == list(range(12, 20))
    assert t[0] == ("ev", 12) and t[-1] == ("ev", 19)
    assert t[2:4] == [("ev", 14), ("ev", 15)]


# ------------------------------------------------------- scoping / timer
def test_span_scoping_and_noop_handles():
    assert obs_spans.active_tracer() is None
    with obs.span("outside") as h:
        h.set(x=1)                        # noop handle, no tracer
    tr = obs.Tracer()
    with obs.use_tracer(tr):
        with obs.span("a", k=1) as h:
            h.set(post=2)
            with obs.no_tracer():
                with obs.span("shadowed"):
                    pass
            assert obs.current_span() is h
    names = [ev["name"] for ev in tr.events]
    assert names == ["a"]
    assert tr.events[0]["args"] == {"k": 1, "post": 2}


def test_fenced_timer_reports_fence_cost():
    from repro.obs.timing import FencedTimer
    y = jnp.ones((8, 8)) @ jnp.ones((8, 8))
    with FencedTimer(fence=False) as t:
        t.fence(y)
    assert not t.fenced and t.synced == 0 and t.elapsed_s >= 0
    with FencedTimer(fence=True) as t:
        t.fence(y)
    assert t.fenced and t.synced == 1


def test_gemm_roofline_bound_monotone_in_format():
    from repro.roofline import gemm_roofline
    t32 = gemm_roofline(256, 1024, 1024, weight_format="fp32")
    t8 = gemm_roofline(256, 1024, 1024, weight_format="int8")
    t2 = gemm_roofline(256, 1024, 1024, weight_format="ternary")
    assert t32 > 0 and t32 >= t8 >= t2    # fewer weight bytes, lower bound
