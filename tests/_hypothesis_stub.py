"""Fallback for the optional ``hypothesis`` dev dependency
(requirements-dev.txt): when it is missing, only the property-based
tests skip — the plain tests in the same modules still run."""
import pytest


def given(*_a, **_k):
    def deco(_f):
        return pytest.mark.skip(
            reason="hypothesis not installed "
                   "(pip install -r requirements-dev.txt)")(_f)
    return deco


def settings(*_a, **_k):
    def deco(f):
        return f
    return deco


class _Strategies:
    """Stand-in for ``hypothesis.strategies``: strategy constructors are
    only evaluated inside @given(...) argument lists, whose results the
    skip decorator never uses."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _Strategies()
