"""Chaos gates: deterministic fault injection against the serving stack.

The headline guarantee (ISSUE: request-level fault isolation): under
EVERY injected fault mix, requests that complete return tokens
bit-identical to a fault-free serve ("survivor parity" — batched greedy
decode is row-independent, so quarantining one slot must not move a bit
in any other), failed requests end in a structured
:class:`RequestOutcome`, and the page pool ends every run — success or
error path — with ``assert_all_free`` clean.

Covered fault classes: allocator OOM (``alloc_oom``), poison requests
in prefill and decode dispatch (``prefill_dispatch`` /
``decode_dispatch``, single-victim attribution via ``target_rid``),
dispatch retry + xla-backend fallback (the degradation ladder),
deadline expiry and cooperative cancel, prefix-cache errors (cold-
prefill degradation), plan-resolution faults, scheduler stalls,
straggler ticks (watchdog), bounded-queue rejection, and SIGTERM
graceful drain (subprocess).  benchmarks/chaos_serving.py runs the
same parity gate over larger mixes; CI runs both.
"""
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.models import model_zoo
from repro.runtime import faults as F
from repro.runtime import kv_cache as KV
from repro.runtime.batching import (ContinuousBatchingScheduler,
                                    RejectedError, RequestState,
                                    SchedulerStallError)
from repro.runtime.fault_tolerance import StepWatchdog
from repro.runtime.serve_loop import Engine

MAX_LEN = 48
PAGE = 8
CHUNK = 8
LENS = [5, 17, 8, 23, 3, 12]
MNS = [6, 3, 8, 4, 5, 7]


def _requests(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in lens]


def _refs(eng, reqs, mns):
    return [np.asarray(eng.generate(jnp.asarray(r)[None], m)[0][0])
            for r, m in zip(reqs, mns)]


@pytest.fixture(scope="module")
def stablelm():
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    params = model_zoo.build(cfg)
    return cfg, Engine(cfg, params, max_len=MAX_LEN, packed=False)


def _fake_cfg():
    return model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))


class FakeEngine:
    """Duck-typed engine (scheduling logic only, no tracing) — chaos
    schedules that never need real numerics run on this."""

    def __init__(self, cfg, max_len):
        self.cfg = cfg
        self.max_len = max_len

    def prefill_chunk(self, pages, pt, lens, tokens, logit_index, *,
                      page_size):
        return jnp.zeros((), jnp.int32), pages

    def decode_step(self, pages, pt, lens, mask, last, *, page_size):
        return last, pages


def _assert_survivor_parity(outs, refs, stats, *, expect_failed=()):
    """The chaos gate: DONE requests match the fault-free reference
    bitwise; non-DONE requests carry structured outcomes; the failure
    set is exactly ``expect_failed`` when given."""
    for i, (o, r) in enumerate(zip(outs, refs)):
        oc = stats.outcomes[i]
        if oc.state == RequestState.DONE:
            np.testing.assert_array_equal(
                o, r, err_msg=f"survivor {i} diverged from fault-free run")
        else:
            assert o is None
            assert oc.error is not None
            if oc.state == RequestState.FAILED:   # fault-evicted: typed
                assert oc.error_type is not None
            assert oc.emitted < len(r)
            if oc.tokens is not None:      # salvaged partials match too
                np.testing.assert_array_equal(oc.tokens, r[:len(oc.tokens)])
    if expect_failed:
        bad = {i for i, _ in enumerate(refs)
               if stats.outcomes[i].state != RequestState.DONE}
        assert bad == set(expect_failed)


# ------------------------------------------------- injection registry
def test_fault_plan_is_deterministic_and_scoped():
    spec = F.FaultSpec("alloc_oom", p=0.5)
    seqs = []
    for _ in range(2):
        plan = F.FaultPlan(spec, seed=7)
        fired = []
        for i in range(64):
            try:
                F.maybe_fire("alloc_oom")          # no scope: no-op
                with F.use_faults(plan):
                    F.maybe_fire("alloc_oom", why="grow")
                fired.append(0)
            except F.FaultInjected:
                fired.append(1)
        seqs.append(fired)
    assert seqs[0] == seqs[1], "same seed must fire identically"
    assert 0 < sum(seqs[0]) < 64
    assert plan.fired["alloc_oom"] == sum(seqs[0])
    # outside the scope nothing ever fires
    F.maybe_fire("alloc_oom")


def test_fault_spec_occurrence_and_target_semantics():
    plan = F.FaultPlan(F.FaultSpec("decode_dispatch", at=(1,),
                                   target_rid=3))
    with F.use_faults(plan):
        # rid 3 not involved: not an eligible occurrence, no count
        F.maybe_fire("decode_dispatch", rids=(0, 1))
        F.maybe_fire("decode_dispatch", rids=(1, 3))   # occ 0: no fire
        with pytest.raises(F.FaultInjected) as ei:
            F.maybe_fire("decode_dispatch", rids=(1, 3))   # occ 1
        assert ei.value.rid == 3
        F.maybe_fire("decode_dispatch", rids=(1, 3))   # occ 2: done
    assert [e[1] for e in plan.events] == [1]
    with pytest.raises(ValueError, match="unknown injection point"):
        F.FaultSpec("not_a_point")


def test_fault_error_override_and_delay():
    plan = F.FaultPlan(
        F.FaultSpec("alloc_oom", at=(0,), error=lambda: KV.OutOfPagesError(
            "injected pool exhaustion")),
        F.FaultSpec("slow_tick", at=(0,), delay_s=0.001))
    with F.use_faults(plan):
        with pytest.raises(KV.OutOfPagesError):
            F.maybe_fire("alloc_oom")
        F.maybe_fire("slow_tick")          # sleeps, must not raise
    assert plan.fired == {"alloc_oom": 1, "slow_tick": 1}


# ------------------------------------- survivor parity, real numerics
def test_poison_prefill_quarantined(stablelm):
    """A request whose prefill dispatch always fails (retry exhausted)
    is quarantined; everyone else matches the fault-free run bitwise."""
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS)
    refs = _refs(eng, reqs, MNS)
    # at=(0,1,2): primary, retry, AND the xla-fallback attempt all fail
    plan = F.FaultPlan(F.FaultSpec("prefill_dispatch", at=(0, 1, 2),
                                   target_rid=2))
    with F.use_faults(plan):
        outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=MNS,
                                prefill_chunk=CHUNK, page_size=PAGE)
    _assert_survivor_parity(outs, refs, stats, expect_failed={2})
    assert stats.outcomes[2].error_type == "FaultInjected"
    assert stats.outcomes[2].emitted == 0
    assert stats.dispatch_retries >= 1 and stats.backend_fallbacks >= 1


def test_poison_decode_single_victim(stablelm):
    """A decode-dispatch fault attributed to one rid (the error carries
    ``.rid``) evicts only that request mid-generation — its co-batched
    neighbors keep decoding and stay bit-identical, and its own partial
    tokens are salvaged into the outcome."""
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS)
    refs = _refs(eng, reqs, MNS)
    # rid 1 (max_new=3) is in exactly 2 successful decode dispatches;
    # eligible occurrence 1 is its second one, 2 and 3 the retry and
    # fallback attempts of the same tick — the full ladder fails
    plan = F.FaultPlan(F.FaultSpec("decode_dispatch", at=(1, 2, 3),
                                   target_rid=1))
    with F.use_faults(plan):
        outs, stats = eng.serve(reqs, batch_slots=3, max_new_tokens=MNS,
                                prefill_chunk=CHUNK, page_size=PAGE)
    _assert_survivor_parity(outs, refs, stats, expect_failed={1})
    oc = stats.outcomes[1]
    assert oc.state == RequestState.FAILED and oc.tokens is not None
    assert 0 < len(oc.tokens) < MNS[1]     # partial, salvaged, matching


def test_dispatch_retry_recovers(stablelm):
    """A transient dispatch fault (first attempt only) is absorbed by
    the retry: every request completes with full parity."""
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS[:4])
    refs = _refs(eng, reqs, MNS[:4])
    plan = F.FaultPlan(F.FaultSpec("decode_dispatch", at=(0,)),
                       F.FaultSpec("prefill_dispatch", at=(0,)))
    with F.use_faults(plan):
        outs, stats = eng.serve(reqs, batch_slots=2,
                                max_new_tokens=MNS[:4],
                                prefill_chunk=CHUNK, page_size=PAGE)
    assert stats.completed == 4 and stats.failed == 0
    assert stats.dispatch_retries >= 2
    assert stats.backend_fallbacks == 0
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_backend_fallback_bitwise_parity(stablelm):
    """Both primary attempts fail -> the dispatch lands on the xla
    fallback step set.  All backends pass the same bit-exactness gate,
    so outputs must still match generate exactly."""
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS[:4])
    refs = _refs(eng, reqs, MNS[:4])
    plan = F.FaultPlan(F.FaultSpec("decode_dispatch", at=(0, 1)))
    with F.use_faults(plan):
        outs, stats = eng.serve(reqs, batch_slots=2,
                                max_new_tokens=MNS[:4],
                                prefill_chunk=CHUNK, page_size=PAGE)
    assert stats.completed == 4
    assert stats.backend_fallbacks >= 1
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_alloc_oom_quarantines_not_crashes(stablelm):
    """An injected allocator failure mid-run fails the requesting slot
    only; survivors keep parity and the pool audits clean."""
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS)
    refs = _refs(eng, reqs, MNS)
    plan = F.FaultPlan(F.FaultSpec(
        "alloc_oom", at=(4,),
        error=lambda: KV.OutOfPagesError("injected pool exhaustion")))
    with F.use_faults(plan):
        outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=MNS,
                                prefill_chunk=CHUNK, page_size=PAGE)
    assert 0 < stats.completed < len(reqs) + 1
    assert stats.failed >= 1
    _assert_survivor_parity(outs, refs, stats)
    for oc in stats.outcomes.values():
        if oc.state == RequestState.FAILED:
            assert oc.error_type == "OutOfPagesError"


def test_deadline_expiry_under_load(stablelm):
    """A request with an expired total budget ends TIMED_OUT with a
    structured outcome; the others complete with parity."""
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS[:4])
    refs = _refs(eng, reqs, MNS[:4])
    budgets = [None, 0.0, None, None]      # rid 1: already expired
    outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=MNS[:4],
                            prefill_chunk=CHUNK, page_size=PAGE,
                            total_budget_s=budgets)
    _assert_survivor_parity(outs, refs, stats, expect_failed={1})
    assert stats.outcomes[1].state == RequestState.TIMED_OUT
    assert "budget" in stats.outcomes[1].error


def test_prefix_cache_error_degrades_to_cold_prefill(stablelm):
    """Prefix-cache faults (lookup + admit) must never fail a request:
    the scheduler serves it cold, counts the degradation, and outputs
    stay bit-identical — including for requests that WOULD have hit."""
    cfg, eng = stablelm
    rng = np.random.default_rng(3)
    pre = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    reqs = [np.concatenate([pre, rng.integers(
                1, cfg.vocab_size, 6).astype(np.int32)])
            for _ in range(4)]
    mns = [4, 5, 3, 6]
    refs = _refs(eng, reqs, mns)
    plan = F.FaultPlan(F.FaultSpec("prefix_cache", p=0.6), seed=11)
    with F.use_faults(plan):
        outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                                prefill_chunk=CHUNK, page_size=PAGE,
                                prefix_cache=True)
    assert stats.completed == 4 and stats.failed == 0
    assert sum(stats.degraded.values()) >= 1
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_plan_resolve_fault_releases_inflight_waiters():
    """An injected failure inside gemm.plan()'s miss path must not wedge
    the in-flight dedup (the resolving owner still pops the key and
    sets the event), so a retry resolves cleanly."""
    from repro import gemm
    gemm.plan_cache_clear()
    fplan = F.FaultPlan(F.FaultSpec("plan_resolve", at=(0,)))
    with F.use_faults(fplan):
        with pytest.raises(F.FaultInjected):
            gemm.plan(128, 256, 512)
        p = gemm.plan(128, 256, 512)       # retry: clean resolve
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((128, 512)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    out = np.asarray(gemm.execute(p, a, b))
    np.testing.assert_allclose(out, np.asarray(a) @ np.asarray(b),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------- scheduler-level isolation
def test_bounded_queue_rejects_with_snapshot():
    sched = ContinuousBatchingScheduler(
        FakeEngine(_fake_cfg(), MAX_LEN), batch_slots=1,
        prefill_chunk=CHUNK, page_size=PAGE, max_queue=2)
    for _ in range(2):
        sched.submit(np.arange(1, 6, dtype=np.int32), 2)
    with pytest.raises(RejectedError) as ei:
        sched.submit(np.arange(1, 6, dtype=np.int32), 2)
    snap = ei.value.snapshot
    assert snap["queue_depth"] == 2 and snap["max_queue"] == 2
    assert snap["free_pages"] == snap["num_pages"]
    assert len(sched.outcomes) == 2        # rejected request never enters


def test_cancel_queued_and_running():
    sched = ContinuousBatchingScheduler(
        FakeEngine(_fake_cfg(), MAX_LEN), batch_slots=1,
        prefill_chunk=CHUNK, page_size=PAGE)
    r0 = sched.submit(np.arange(1, 10, dtype=np.int32), 6)
    r1 = sched.submit(np.arange(1, 10, dtype=np.int32), 6)
    while not sched.slots[0].prefill_done:
        sched.step()
    assert sched.cancel(r0) and sched.cancel(r1)
    assert not sched.cancel(999)
    while sched.step():
        pass
    sched._materialize()
    assert sched.outcomes[r0].state == RequestState.CANCELLED  # running
    assert sched.outcomes[r1].state == RequestState.CANCELLED  # queued
    assert sched.outcomes[r1].emitted == 0
    assert sched.kv.free_count == sched.kv.num_pages
    sched.kv.assert_all_free()


def test_deadlines_with_fake_clock():
    """Deterministic deadline semantics on an injected clock: TTFT
    budget trips only before the first token, total budget any time."""
    clk = [0.0]
    sched = ContinuousBatchingScheduler(
        FakeEngine(_fake_cfg(), MAX_LEN), batch_slots=2,
        prefill_chunk=CHUNK, page_size=PAGE, clock=lambda: clk[0])
    r0 = sched.submit(np.arange(1, 20, dtype=np.int32), 8,
                      ttft_budget_s=10.0)   # generous: never trips
    r1 = sched.submit(np.arange(1, 20, dtype=np.int32), 8,
                      total_budget_s=0.5)   # trips after first ticks
    while sched.step():
        clk[0] += 0.3
    sched._materialize()
    assert sched.outcomes[r0].state == RequestState.DONE
    assert sched.outcomes[r1].state == RequestState.TIMED_OUT
    assert "total budget" in sched.outcomes[r1].error
    sched.kv.assert_all_free()


def test_scheduler_stall_is_diagnosable(monkeypatch):
    """A wedged scheduler surfaces SchedulerStallError (a RuntimeError,
    preserving the old contract) with a state snapshot, and the
    exception path still releases every page."""
    sched = ContinuousBatchingScheduler(
        FakeEngine(_fake_cfg(), MAX_LEN), batch_slots=1,
        prefill_chunk=CHUNK, page_size=PAGE)
    monkeypatch.setattr(sched, "_admit", lambda: None)   # never admits
    with pytest.raises(SchedulerStallError, match="no progress") as ei:
        sched.run([np.arange(1, 6, dtype=np.int32)], 2)
    assert isinstance(ei.value, RuntimeError)
    assert ei.value.snapshot["queue_depth"] == 1
    assert sched.outcomes[0].state == RequestState.CANCELLED
    assert sched.kv.free_count == sched.kv.num_pages


def test_run_exception_exit_releases_pages(monkeypatch):
    """Satellite 1: an exception escaping the tick loop still evicts
    live slots, drains the queue to outcomes, and passes the
    assert_all_free audit (the try/finally around run())."""
    sched = ContinuousBatchingScheduler(
        FakeEngine(_fake_cfg(), MAX_LEN), batch_slots=1,
        prefill_chunk=CHUNK, page_size=PAGE)

    def boom():
        raise ZeroDivisionError("scheduler bug")
    monkeypatch.setattr(sched, "_decode_step", boom)
    with pytest.raises(ZeroDivisionError):
        sched.run([np.arange(1, 6, dtype=np.int32),
                   np.arange(1, 40, dtype=np.int32)], [2, 2])
    states = {r: o.state for r, o in sched.outcomes.items()}
    assert states[0] == RequestState.FAILED        # was live in a slot
    assert "run aborted" in sched.outcomes[0].error
    assert sched.kv.free_count == sched.kv.num_pages
    sched.kv.assert_all_free()     # would raise on a refcount leak


def test_slow_tick_error_cleans_up():
    """An error spec on the tick boundary aborts the run through the
    same quarantine path — structured outcomes, clean pool."""
    sched = ContinuousBatchingScheduler(
        FakeEngine(_fake_cfg(), MAX_LEN), batch_slots=2,
        prefill_chunk=CHUNK, page_size=PAGE)
    plan = F.FaultPlan(F.FaultSpec("slow_tick", at=(3,),
                                   error=RuntimeError("tick bomb")))
    with F.use_faults(plan):
        with pytest.raises(RuntimeError, match="tick bomb"):
            sched.run([np.arange(1, 20, dtype=np.int32)] * 3, 6)
    assert all(o.state in (RequestState.FAILED, RequestState.CANCELLED)
               for o in sched.outcomes.values())
    assert sched.kv.free_count == sched.kv.num_pages


# ----------------------------------------------------------- watchdog
def test_watchdog_flags_injected_straggler_tick():
    """Satellite 2: a delay-injected tick lands in ServeStats.stragglers.
    Factor 8 over sub-millisecond stub ticks vs a 60ms injected delay
    keeps this deterministic without flagging warmup."""
    sched = ContinuousBatchingScheduler(
        FakeEngine(_fake_cfg(), MAX_LEN), batch_slots=2,
        prefill_chunk=CHUNK, page_size=PAGE, watchdog_factor=8.0)
    plan = F.FaultPlan(F.FaultSpec("slow_tick", at=(8,), delay_s=0.06))
    with F.use_faults(plan):
        _, stats = sched.run([np.arange(1, 20, dtype=np.int32)] * 4, 8)
    assert stats.completed == 4
    assert len(stats.stragglers) >= 1
    assert max(ev.dt for ev in stats.stragglers) >= 0.06


def test_watchdog_warmup_never_flags():
    wd = StepWatchdog(factor=3.0, warmup=2)
    assert wd.record(10.0) is False        # warmup observed, not flagged
    assert wd.record(10.0) is False
    assert wd.record(11.0) is False        # in family with the EMA
    assert wd.record(100.0) is True        # genuine straggler
    assert len(wd.events) == 1


# -------------------------------------------------- graceful shutdown
_SHUTDOWN_SCRIPT = textwrap.dedent("""
    import sys
    import numpy as np
    import jax.numpy as jnp
    from repro.models import model_zoo
    from repro.runtime import faults as F
    from repro.runtime.batching import (ContinuousBatchingScheduler,
                                        RequestState)
    from repro.runtime.fault_tolerance import GracefulShutdown

    class FakeEngine:
        def __init__(self, cfg, max_len):
            self.cfg, self.max_len = cfg, max_len
        def prefill_chunk(self, pages, pt, lens, tokens, li, *,
                          page_size):
            return jnp.zeros((), jnp.int32), pages
        def decode_step(self, pages, pt, lens, mask, last, *, page_size):
            return last, pages

    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    gs = GracefulShutdown().install()
    sched = ContinuousBatchingScheduler(
        FakeEngine(cfg, 48), batch_slots=2, prefill_chunk=8,
        page_size=8, shutdown=gs)
    for _ in range(40):
        sched.submit(np.arange(1, 12, dtype=np.int32), 6)
    # announce READY only once a request has finished, so the parent's
    # SIGTERM always lands mid-stream with completions on the books;
    # slow ticks keep the run alive long past the signal
    plan = F.FaultPlan(F.FaultSpec("slow_tick", delay_s=0.02))
    ready = False
    with F.use_faults(plan):
        while sched.step():
            if not ready and sched.stats.completed >= 1:
                print("READY", flush=True)
                ready = True
    sched._materialize()
    assert gs.requested, "SIGTERM never observed"
    done = sum(1 for o in sched.outcomes.values()
               if o.state == RequestState.DONE)
    cancelled = [o for o in sched.outcomes.values()
                 if o.state == RequestState.CANCELLED]
    assert done > 0, "drain must finish in-flight requests"
    assert cancelled, "drain must cancel the queue"
    assert all(o.error == "shutdown" for o in cancelled)
    assert sched.kv.free_count == sched.kv.num_pages
    sched.kv.assert_all_free()
    print(f"DRAINED done={done} cancelled={len(cancelled)}", flush=True)
""")


@pytest.mark.skipif(sys.platform == "win32", reason="POSIX signals")
def test_graceful_shutdown_drains_on_sigterm(tmp_path):
    """Satellite 3, end to end in a subprocess: SIGTERM mid-run finishes
    in-flight requests, cancels queued ones with structured outcomes,
    and exits 0 inside the grace window."""
    script = tmp_path / "serve_victim.py"
    script.write_text(_SHUTDOWN_SCRIPT)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen([sys.executable, str(script)],
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, env=env, text=True)
    try:
        assert proc.stdout.readline().strip() == "READY"
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
    except Exception:
        proc.kill()
        raise
    assert proc.returncode == 0, f"victim failed:\n{out}"
    assert "DRAINED done=" in out
