"""Checkpoint store tests: atomicity, keep-k, async, bf16 round-trip,
elastic restore, and the resume protocol."""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.checkpoint import store as S


@pytest.fixture()
def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
                   "c": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path, tree):
    d = str(tmp_path)
    save(d, 5, tree, metadata={"step": 5})
    out, meta = restore(d, 5, jax.eval_shape(lambda: tree))
    assert meta == {"step": 5}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bfloat16_no_pickle(tmp_path, tree):
    d = str(tmp_path)
    save(d, 1, tree)
    for f in os.listdir(os.path.join(d, "step_00000001")):
        if f.endswith(".npy"):
            arr = np.load(os.path.join(d, "step_00000001", f),
                          allow_pickle=False)   # must not need pickle
            assert arr.dtype == np.uint8


def test_atomic_publish_ignores_partial(tmp_path, tree):
    d = str(tmp_path)
    save(d, 1, tree)
    # simulate a crash mid-write at step 2: tmp dir exists, no manifest
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    # and a torn final dir without manifest
    os.makedirs(os.path.join(d, "step_00000003"))
    assert latest_step(d) == 1


def test_keep_k_gc(tmp_path, tree):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2, async_write=False)
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    assert S.steps(d) == [3, 4]


def test_async_save_and_wait(tmp_path, tree):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=3, async_write=True)
    mgr.save(10, tree)
    mgr.wait()
    assert mgr.latest_step() == 10
    out, _ = mgr.restore(10, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_restore_rejects_structure_change(tmp_path, tree):
    d = str(tmp_path)
    save(d, 1, tree)
    bad = dict(tree)
    bad["extra"] = jnp.zeros((1,))
    with pytest.raises(ValueError, match="structure changed"):
        restore(d, 1, jax.eval_shape(lambda: bad))


def test_restore_rejects_shape_change(tmp_path, tree):
    d = str(tmp_path)
    save(d, 1, tree)
    bad = dict(tree)
    bad["a"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="shape"):
        restore(d, 1, jax.eval_shape(lambda: bad))


def test_elastic_restore_with_shardings(tmp_path, tree):
    """Restore onto explicit shardings for the *current* mesh (here 1
    device, but the code path is the elastic one)."""
    d = str(tmp_path)
    save(d, 1, tree)
    from repro import compat
    auto = compat.axis_type_auto()
    mesh = compat.make_mesh((1,), ("data",),
                            axis_types=auto and (auto,))
    sh = jax.tree.map(
        lambda x: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        jax.eval_shape(lambda: tree))
    out, _ = restore(d, 1, jax.eval_shape(lambda: tree), shardings=sh)
    assert out["a"].sharding.mesh.shape == {"data": 1}


def test_resume_or_init(tmp_path, tree):
    from repro.runtime import fault_tolerance as ft
    d = str(tmp_path)
    mgr = CheckpointManager(d, async_write=False)
    state, start = ft.resume_or_init(mgr, lambda: tree,
                                     jax.eval_shape(lambda: tree))
    assert start == 0                       # fresh init
    mgr.save(7, tree, metadata={"step": 7})
    state, start = ft.resume_or_init(mgr, lambda: tree,
                                     jax.eval_shape(lambda: tree))
    assert start == 7
