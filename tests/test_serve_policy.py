"""Serving placement policy + pack block-fitting tests (§Perf C1)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

import jax
from jax.sharding import PartitionSpec as P

from repro.core.packing import fit_block
from repro.models import model_zoo
from repro.parallel import sharding as Sh


class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.size = int(np.prod(list(axes.values())))


MESH = FakeMesh(data=16, model=16)


def _has_data_axis(specs):
    out = []
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for entry in s:
            names = entry if isinstance(entry, tuple) else (entry,)
            if any(n in ("data", "pod") for n in names if n):
                out.append(True)
                break
        else:
            out.append(False)
    return any(out)


def test_small_arch_replicates_over_data():
    cfg = model_zoo.get_config("deepseek-7b")     # 6.9B fp32 / 16 ≈ 1.7GB
    params = model_zoo.abstract_params(cfg)
    specs = Sh.serve_param_specs(params, MESH)
    assert not _has_data_axis(specs), "should be TP-only for serving"


def test_huge_arch_keeps_fsdp():
    cfg = model_zoo.get_config("deepseek-v3-671b")  # 84GB/chip TP-only
    params = model_zoo.abstract_params(cfg)
    specs = Sh.serve_param_specs(params, MESH)
    assert _has_data_axis(specs), "671B must stay sharded over data"


def test_budget_knob():
    cfg = model_zoo.get_config("deepseek-7b")
    params = model_zoo.abstract_params(cfg)
    tight = Sh.serve_param_specs(params, MESH, hbm_budget=2 ** 28)
    assert _has_data_axis(tight), "tiny budget must force FSDP"


# ------------------------------------------------------------- fit_block
@settings(max_examples=200, deadline=None)
@given(dim=st.integers(1, 70000), want=st.sampled_from([128, 512, 2048]))
def test_fit_block_properties(dim, want):
    b = fit_block(dim, want)
    padded = max(128, ((dim + 127) // 128) * 128)
    assert b <= max(want, 128)
    assert padded % b == 0, (dim, want, b)
    assert b >= 128


def test_fit_block_examples():
    assert fit_block(2048, 2048) == 2048
    assert fit_block(5632, 2048) == 512    # 5632 = 44*128; 44 % 16 != 0
    assert fit_block(1600, 2048) == 1664   # hymba: whole padded dim (13*128)
    assert fit_block(11008, 2048) == 256   # 11008 = 86*128
    assert fit_block(60000, 512) == 128    # LM head padding stays light
