"""Persistent plan/autotune store tests (the plan-store acceptance
grid): disk round-trip fidelity, corruption tolerance (a broken store
file NEVER crashes a server — it degrades to the analytic policy),
schema/host invalidation, atomic concurrent writes, the warm-start
contract (a second process booting from a populated store resolves its
whole plan surface with ZERO analytic resolutions and ZERO
bit-exactness gate runs — store hits == plans needed), and the
measured-autotune commit path (gate-checked winners only)."""
import json
import os
import threading

import pytest

from repro import gemm as G
from repro.core import autotune
from repro.gemm import plan_store as PS
from repro.gemm import policy as pol
from repro.models.model_zoo import PAPER_GEMM_SHAPES, PAPER_M


@pytest.fixture(autouse=True)
def _fresh(monkeypatch):
    G.plan_cache_clear()
    monkeypatch.setattr(PS, "_default_store", None, raising=False)
    yield
    G.plan_cache_clear()


def _resolve_surface(shapes=PAPER_GEMM_SHAPES):
    """One process's plan surface: the paper's twelve prefill GEMMs at
    M = PAPER_M plus the decode ladder (every DECODE_M_BUCKETS width,
    decode policy arm) per shape."""
    plans = []
    for _, _, n, k in shapes:
        plans.append(G.plan(PAPER_M, n, k))
        for bucket in G.DECODE_M_BUCKETS:
            plans.append(G.plan(bucket, n, k, decode=True))
    return plans


# -------------------------------------------------------------- round-trip
def test_store_roundtrip(tmp_path):
    path = tmp_path / "plans.json"
    store = PS.PlanStore(path)
    with G.use_plan_store(store):
        plans = _resolve_surface(PAPER_GEMM_SHAPES[:3])
    info = store.info()
    assert info.misses == len(plans) and info.hits == 0
    assert info.entries == len(plans)
    saved = store.save()
    assert saved == os.fspath(path) and os.path.exists(path)

    fresh = PS.PlanStore.load(path)
    assert fresh.invalidated is None
    assert len(fresh) == len(plans)
    for key in store.keys():
        assert fresh.lookup(key) == store.entry(key)["plan"]


def test_store_roundtrip_preserves_plan_detail(tmp_path):
    """Every plan facet the executor dispatches on survives the disk
    round-trip: blocks, lever, pack mode, epilogue, quant format,
    decode/split-K, validated."""
    path = tmp_path / "plans.json"
    store = PS.PlanStore(path)
    epi = G.EpilogueSpec(glu="silu", residual=True)
    with G.use_plan_store(store):
        a = G.plan(128, 1024, 2048, epilogue=epi,
                   fused_n_splits=(512, 512))
        b = G.plan(8, 2048, 2048, decode=True)
        c = G.plan(128, 2048, 1024, weight_format="int8")
        d = G.plan(64, 512, 512, validate=True)
    store.save()
    fresh = PS.PlanStore.load(path)
    keyed = [
        (a, G.store_key(128, 1024, 2048, epilogue=epi,
                        fused_n_splits=(512, 512))),
        (b, G.store_key(8, 2048, 2048, decode=True)),
        (c, G.store_key(128, 2048, 1024, weight_format="int8")),
        (d, G.store_key(64, 512, 512, validate=True)),
    ]
    for p, skey in keyed:
        q = fresh.lookup(skey)
        assert q == p, (p, q)
        assert q.validated == p.validated
    assert fresh.lookup("no-such-key") is None
    assert fresh.info().misses == 1


# ----------------------------------------------------- corruption tolerance
@pytest.mark.parametrize("blob", [
    b"this is not json {",                       # garbage
    b'{"schema": 1, "plans"',                    # truncated mid-write
    b"",                                         # empty file
    b'{"schema": 1}',                            # missing sections
    b'[1, 2, 3]',                                # wrong top-level type
])
def test_store_load_tolerates_corruption(tmp_path, blob):
    """A corrupt store file NEVER raises: load returns an empty store
    with the reason recorded, and the process runs on the analytic
    policy."""
    path = tmp_path / "plans.json"
    path.write_bytes(blob)
    store = PS.PlanStore.load(path)
    assert store.invalidated is not None
    assert len(store) == 0
    # ...and a server still plans fine on top of it
    with G.use_plan_store(store):
        p = G.plan(128, 256, 512)
    assert p.shape == (128, 256, 512)
    store.save()                       # and can re-persist over the wreck
    assert PS.PlanStore.load(path).invalidated is None


@pytest.mark.parametrize("blob", [
    b"this is not json {",                       # garbage
    b'{"schema": 1, "plans"',                    # truncated mid-write
    b'[1, 2, 3]',                                # wrong top-level type
])
def test_corrupt_store_into_live_serve(tmp_path, blob):
    """Degradation end to end (the chaos-suite contract at the plan
    layer): a server booted on a corrupt plan store must SERVE — the
    store degrades to empty, the engine plans analytically, continuous
    batching completes with bit-exact outputs, and save() re-persists
    a clean store over the wreck."""
    import numpy as np
    import jax.numpy as jnp
    from repro.models import model_zoo
    from repro.runtime.serve_loop import Engine

    path = tmp_path / "plans.json"
    path.write_bytes(blob)
    store = PS.PlanStore.load(path)
    assert store.invalidated is not None
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    eng = Engine(cfg, model_zoo.build(cfg), max_len=48, packed=True,
                 plan_store=store)
    rng = np.random.default_rng(0)
    reqs = [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in (5, 17, 8)]
    mns = [4, 3, 5]
    refs = [np.asarray(eng.generate(jnp.asarray(r)[None], m)[0][0])
            for r, m in zip(reqs, mns)]
    outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                            prefill_chunk=8, page_size=8)
    assert stats.completed == 3
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    store.save()
    fresh = PS.PlanStore.load(path)
    assert fresh.invalidated is None and len(fresh) > 0


def test_store_skips_bad_entries_keeps_good(tmp_path):
    """Per-entry tolerance: one undecodable entry is dropped, the rest
    of the store survives."""
    path = tmp_path / "plans.json"
    store = PS.PlanStore(path)
    with G.use_plan_store(store):
        G.plan(128, 256, 512)
        G.plan(128, 512, 256)
    store.save()
    doc = json.loads(path.read_text())
    keys = list(doc["plans"])
    doc["plans"][keys[0]]["plan"]["block_n"] = -7   # implausible geometry
    path.write_text(json.dumps(doc))
    fresh = PS.PlanStore.load(path)
    assert fresh.invalidated is None
    assert len(fresh) == 1
    assert fresh.lookup(keys[1]) is not None


def test_store_invalidated_on_schema_bump(tmp_path):
    path = tmp_path / "plans.json"
    store = PS.PlanStore(path)
    with G.use_plan_store(store):
        G.plan(128, 256, 512)
    store.save()
    doc = json.loads(path.read_text())
    doc["schema"] = PS.SCHEMA_VERSION + 1
    path.write_text(json.dumps(doc))
    fresh = PS.PlanStore.load(path)
    assert fresh.invalidated and "schema" in fresh.invalidated
    assert len(fresh) == 0


def test_store_invalidated_on_host_mismatch(tmp_path):
    """Plans tuned on one host (kernel VMEM budget, core count, jax
    version) must not deploy on another: the fingerprint gates the
    whole file."""
    path = tmp_path / "plans.json"
    store = PS.PlanStore(path)
    with G.use_plan_store(store):
        G.plan(128, 256, 512)
    store.save()
    doc = json.loads(path.read_text())
    doc["host"] = "arm64|Darwin|cpu:m1|jax 0.0.1|VMEM 1"
    path.write_text(json.dumps(doc))
    fresh = PS.PlanStore.load(path)
    assert fresh.invalidated and "host" in fresh.invalidated
    assert len(fresh) == 0
    assert PS.host_fingerprint() != doc["host"]


# --------------------------------------------------------- atomic writes
def test_concurrent_writers_atomic(tmp_path):
    """N threads saving interleaved with N readers: every observed file
    state is complete, valid JSON (tempfile + os.replace — a reader
    never sees a half-written store)."""
    path = tmp_path / "plans.json"
    stores = []
    for i in range(4):
        st = PS.PlanStore(path)
        with G.use_plan_store(st):
            G.plan(128, 256 * (i + 1), 512)
        stores.append(st)
    errs = []
    stop = threading.Event()

    def writer(st):
        for _ in range(20):
            try:
                st.save()
            except Exception as e:           # pragma: no cover
                errs.append(e)

    def reader():
        while not stop.is_set():
            try:
                if path.exists():
                    loaded = PS.PlanStore.load(path)
                    assert loaded.invalidated is None, loaded.invalidated
            except Exception as e:           # pragma: no cover
                errs.append(e)

    rs = [threading.Thread(target=reader) for _ in range(2)]
    ws = [threading.Thread(target=writer, args=(st,)) for st in stores]
    for t in rs + ws:
        t.start()
    for t in ws:
        t.join()
    stop.set()
    for t in rs:
        t.join()
    assert not errs
    final = PS.PlanStore.load(path)
    assert final.invalidated is None and len(final) == 1
    assert not [f for f in os.listdir(tmp_path)
                if f != "plans.json"], "leaked temp files"


# ------------------------------------------------------ warm-start contract
def test_two_process_warm_start_zero_resolves(tmp_path, monkeypatch):
    """THE acceptance contract: process 1 resolves the full serving
    plan surface (twelve paper shapes at M=128 + the decode-bucket
    ladder) into a store; process 2 boots from that file and plans the
    same surface with ZERO analytic resolutions, ZERO gate runs, and
    store hits == plans needed."""
    path = tmp_path / "plans.json"
    store = PS.PlanStore(path)
    with G.use_plan_store(store):
        plans1 = _resolve_surface()
    store.save()
    # plans NEEDED = the unique plan keys of the surface (duplicate
    # (n, k) pairs across models dedupe in the in-memory cache and
    # never reach the store)
    n_needed = len({id(p) for p in plans1})
    info1 = store.info()
    assert info1.entries == n_needed and info1.misses == n_needed

    # "process 2": fresh in-memory cache, fresh store handle, and an
    # analytic policy that EXPLODES if consulted
    G.plan_cache_clear()
    warm = PS.PlanStore.load(path)
    assert warm.invalidated is None

    def boom(*a, **kw):                      # pragma: no cover
        raise AssertionError("warm start ran an analytic _resolve")

    monkeypatch.setattr(pol, "_resolve", boom)
    with G.use_plan_store(warm):
        plans2 = _resolve_surface()
    info = warm.info()
    assert info.hits == n_needed and info.misses == 0
    assert [p.shape for p in plans2] == [p.shape for p in plans1]
    assert plans2 == plans1


def test_store_validate_gate_not_skipped_for_ungated_entries(tmp_path):
    """A validate=True request only adopts a stored plan that actually
    passed the gate (validated=True) — an analytic (ungated) entry for
    the same shape is NOT good enough, the gate runs."""
    path = tmp_path / "plans.json"
    store = PS.PlanStore(path)
    with G.use_plan_store(store):
        G.plan(64, 256, 256)                      # ungated entry
        G.plan_cache_clear()
        p = G.plan(64, 256, 256, validate=True)   # must run the gate
    assert p.validated


def test_use_plan_store_scoping():
    """Scope semantics mirror use_backend: use_plan_store(None)
    inherits, no_plan_store() blanks even over a process default."""
    store = PS.PlanStore()
    assert PS.active_plan_store() is None
    with G.use_plan_store(store):
        assert PS.active_plan_store() is store
        with G.use_plan_store(None):              # inherit, not clear
            assert PS.active_plan_store() is store
        with G.no_plan_store():
            assert PS.active_plan_store() is None
        assert PS.active_plan_store() is store
    assert PS.active_plan_store() is None
    old = G.set_plan_store(store)
    try:
        assert old is None and PS.active_plan_store() is store
        with G.no_plan_store():
            assert PS.active_plan_store() is None
    finally:
        G.set_plan_store(old)


# -------------------------------------------------------- measured autotune
def test_measured_autotune_commits_gated_winner(tmp_path):
    """The sweep commits ONLY a plan that passed the bit-exactness
    gate, records provenance (t_meas, autotuned), and a warm process
    adopts the winner pre-validated."""
    path = tmp_path / "plans.json"
    store = PS.PlanStore(path)
    with G.use_plan_store(store):
        mp = autotune.measured_autotune(32, 128, 128, trials=2,
                                        warmup=1, max_retries=0)
    assert mp.committed and mp.plan.validated
    assert mp.candidates >= 1
    skey = pol.store_key(32, 128, 128)
    ent = store.entry(skey)
    assert ent is not None and ent["autotuned"]
    assert ent["t_meas"] == pytest.approx(mp.t_measured)
    # same-process adoption: the in-memory cache serves the winner
    assert G.plan(32, 128, 128) == mp.plan
    # cross-process adoption: reload and plan, no re-sweep, no gate
    store.save()
    G.plan_cache_clear()
    warm = PS.PlanStore.load(path)
    with G.use_plan_store(warm):
        p = G.plan(32, 128, 128)
    assert p == mp.plan and p.validated
    assert warm.info().hits == 1


def test_measured_autotune_never_commits_gate_failure(monkeypatch):
    """If every candidate fails the gate the sweep raises instead of
    deploying an unverified plan; the store stays clean."""
    store = PS.PlanStore()
    monkeypatch.setattr(G, "validate_plan", lambda p: False)
    with G.use_plan_store(store):
        with pytest.raises(RuntimeError, match="bit-exactness gate"):
            autotune.measured_autotune(32, 64, 64, trials=1, warmup=0,
                                       max_retries=0)
    assert len(store) == 0


def test_measured_autotune_ignores_store_while_sweeping(tmp_path):
    """Self-isolation: the sweep's candidate resolutions run under
    no_plan_store() — a stale store entry neither short-circuits the
    sweep nor gets overwritten by reads."""
    store = PS.PlanStore()
    with G.use_plan_store(store):
        mp = autotune.measured_autotune(32, 96, 96, trials=1, warmup=0,
                                        max_retries=0)
    info = store.info()
    assert info.hits == 0 and info.misses == 0   # sweep never read it
    assert info.entries == 1 and mp.committed
